"""Training launcher — end-to-end driver (deliverable (b)).

Runs real optimization on CPU (smoke config) or TPU (full config):
deterministic data pipeline, AdamW, checkpoint/restart, straggler
monitor, optional gradient compression. `--steps 300 --arch qwen3_8b
--smoke` trains a ~10M-param model for a few hundred steps.

Fault tolerance in action:
  * auto-resume from the newest valid checkpoint (corrupt ones skipped),
  * stateless data pipeline resumes at the exact step,
  * per-step deadline monitor flags stragglers (logs + counter; on a real
    cluster this hooks the preemption/replacement RPC — documented in
    DESIGN.md §5).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import configs
from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.core.policy import ArithmeticPolicy
from repro.data import DataConfig, make_batch
from repro.launch import steps as stepslib
from repro.models import model
from repro.optim import OptimizerConfig, adamw_init


def train(arch: str = "qwen3_8b", smoke: bool = True, steps: int = 100,
          seq_len: int = 128, global_batch: int = 8,
          policy_mode: str = "exact", ckpt_dir: str | None = None,
          save_every: int = 50, log_every: int = 10,
          straggler_factor: float = 3.0, lr: float = 3e-4) -> dict:
    cfg = configs.get_config(arch, smoke=smoke)
    policy = ArithmeticPolicy(mode=policy_mode)
    opt_cfg = OptimizerConfig(lr=lr, total_steps=steps,
                              warmup_steps=max(steps // 20, 5))
    dcfg = DataConfig(seq_len=seq_len, global_batch=global_batch)

    params = model.init(jax.random.PRNGKey(0), cfg)
    opt_state = adamw_init(params)
    start_step = 0

    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(CheckpointConfig(
            directory=ckpt_dir, save_every=save_every))
        step0, restored = mgr.restore_latest(
            {"params": params, "opt": opt_state})
        if step0 is not None:
            params, opt_state = restored["params"], restored["opt"]
            start_step = step0
            print(f"[train] resumed from step {step0}")

    step_fn = jax.jit(stepslib.make_train_step(cfg, opt_cfg, policy))

    losses = []
    ema = None
    stragglers = 0
    for step in range(start_step, steps):
        batch = make_batch(cfg, dcfg, step)
        t0 = time.time()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        # straggler monitor: steps beyond straggler_factor x EMA are
        # flagged (cluster hook point: replace/requeue the slow worker)
        if ema is not None and dt > straggler_factor * ema and step > 3:
            stragglers += 1
            print(f"[straggler] step {step}: {dt:.2f}s vs ema {ema:.2f}s")
        ema = dt if ema is None else 0.9 * ema + 0.1 * dt
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1000:6.0f}ms")
        if mgr and (step + 1) % save_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.save(steps, {"params": params, "opt": opt_state})
        mgr.wait()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "losses": losses, "stragglers": stragglers,
            "params": params}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs TPU); default smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--policy", default="exact",
                    choices=["exact", "int8", "artemis", "artemis_mxu"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    out = train(arch=args.arch, smoke=not args.full, steps=args.steps,
                seq_len=args.seq_len, global_batch=args.global_batch,
                policy_mode=args.policy, ckpt_dir=args.ckpt_dir,
                lr=args.lr)
    print(f"\nfinal loss {out['final_loss']:.4f} "
          f"(from {out['first_loss']:.4f}); "
          f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
