"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

THE FIRST TWO LINES must run before ANY other import (jax locks the device
count on first backend init) — brief MULTI-POD DRY-RUN §0.
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_DRYRUN_XLA_FLAGS",
                   "--xla_force_host_platform_device_count=512"))

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.launch import mesh as meshlib
from repro.launch import specs as specslib
from repro.launch import steps as stepslib
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig
from repro.parallel import sharding as sh
from repro.roofline import analyze, model_flops, parse_collectives
from repro.roofline.model import HW_V5E

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun_cache.json")


def _cache_key(arch, shape, mesh_tag, rules: sh.ShardingRules,
               policy_mode: str) -> str:
    return f"{arch}|{shape}|{mesh_tag}|{dataclasses.asdict(rules)}|" \
           f"{policy_mode}"


def _load_cache() -> dict:
    try:
        with open(CACHE_PATH) as f:
            return json.load(f)
    except Exception:
        return {}


def _save_cache(cache: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(CACHE_PATH)), exist_ok=True)
    tmp = CACHE_PATH + ".tmp"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=1, sort_keys=True)
    os.replace(tmp, CACHE_PATH)


# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------


def cost_analysis_dict(compiled) -> dict:
    """Version-compatible `compiled.cost_analysis()`: jax <= 0.4.x returns
    a list with one dict per partitioned program, newer jax returns the
    dict directly."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def lower_cell(cfg: ModelConfig, cell: configs.ShapeCell, mesh,
               rules: sh.ShardingRules = sh.ShardingRules(),
               policy: ArithmeticPolicy = ArithmeticPolicy(),
               donate: bool = True, unroll: int | bool = True):
    """Returns the lowered computation for one cell on one mesh.

    unroll=True fully unrolls the layer scan so `cost_analysis()` counts
    every layer (XLA counts a while-loop body once regardless of trip
    count — EXPERIMENTS.md §Dry-run methodology). Inner SSM chunk scans
    stay rolled; `inner_scan_correction` fixes their accounting.
    """
    if cell.kind != "train":
        # serving wants TP-resident weights: FSDP's per-layer all-gather
        # costs ICI + a gathered copy every step — §Perf H3. But only
        # when the TP-sharded bf16 residency actually fits: dbrx-132b at
        # 16.5 GiB/device must keep FSDP (H3 iteration 2).
        tp = mesh.shape.get("model", 1)
        resident_gib = cfg.param_count() * 2 / tp / 2**30
        if resident_gib <= 4.0:
            rules = dataclasses.replace(rules, fsdp=False)
    ins = specslib.input_specs(cfg, cell)
    pspecs = sh.param_specs(cfg, ins["params"], mesh, rules)
    psh = sh.named(mesh, pspecs)

    if cell.kind == "train":
        opt_specs = {"m": pspecs, "v": pspecs,
                     "step": sh.replicated_spec()}
        osh = sh.named(mesh, opt_specs)
        bsh = sh.named(mesh, sh.batch_specs(cfg, mesh, cell.global_batch))
        metrics_sh = sh.named(mesh, sh.replicated_spec())
        step = stepslib.make_train_step(
            cfg, OptimizerConfig(), policy, mesh=mesh, rules=rules,
            unroll=unroll)
        jitted = jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, metrics_sh),
            donate_argnums=(0, 1) if donate else ())
        lowered = jitted.lower(ins["params"], ins["opt_state"], ins["batch"])

    elif cell.kind == "prefill":
        csh = sh.named(mesh, sh.cache_specs(cfg, mesh, cell.global_batch,
                                            rules))
        bspecs = sh.batch_specs(cfg, mesh, cell.global_batch)
        bspecs.pop("labels", None)
        bsh = sh.named(mesh, bspecs)
        bax = sh.batch_axes(mesh)
        lead = (bax if cell.global_batch >= meshlib.mesh_chips(mesh) //
                mesh.shape["model"] else None,)
        if cfg.modality == "audio":   # last-token logits: (B, C, V)
            lead = lead + (None,)
        logits_sh = sh.named(mesh, sh.logits_spec(lead))
        step = stepslib.make_prefill_step(cfg, policy, mesh=mesh,
                                          rules=rules, unroll=unroll)
        jitted = jax.jit(
            step,
            in_shardings=(psh, bsh, csh),
            out_shardings=(logits_sh, csh),
            donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(ins["params"], ins["batch"], ins["cache"])

    else:  # decode
        csh = sh.named(mesh, sh.cache_specs(cfg, mesh, cell.global_batch,
                                            rules))
        bspecs = sh.batch_specs(cfg, mesh, cell.global_batch)
        tok_sh = sh.named(mesh, bspecs["tokens"])
        bax = sh.batch_axes(mesh)
        lead = (bax if cell.global_batch > 1 else None,)
        if cfg.modality == "audio":   # last-token logits: (B, C, V)
            lead = lead + (None,)
        logits_sh = sh.named(mesh, sh.logits_spec(lead))
        step = stepslib.make_decode_step(cfg, policy, mesh=mesh,
                                         rules=rules, unroll=unroll)
        jitted = jax.jit(
            step,
            in_shardings=(psh, tok_sh, csh),
            out_shardings=(logits_sh, csh),
            donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(ins["params"], ins["tokens"], ins["cache"])

    return lowered


def inner_scan_correction(cfg: ModelConfig, cell: configs.ShapeCell,
                          chips: int) -> dict:
    """Analytic flop/byte correction for ROLLED inner chunk scans.

    rwkv6/mamba2 evaluate their recurrences as a lax.scan over sequence
    chunks; with the layer scan unrolled, each layer contributes its chunk
    body ONCE to cost_analysis while the real trip count is nc = ceil(S /
    chunk). We add (nc-1)/nc of the analytic per-layer chunk-scan work.
    Chunk bodies contain no collectives (token-local by construction), so
    only flops/bytes need correcting. Per-device values (divided by chips,
    matching cost_analysis units).
    """
    if cfg.family not in ("rwkv6", "zamba2") or cell.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    s = cell.seq_len
    b = cell.global_batch
    chunk = cfg.chunk_size
    nc = -(-s // chunk)
    if nc <= 1:
        return {"flops": 0.0, "bytes": 0.0}
    lch = chunk
    if cfg.family == "rwkv6":
        h = cfg.d_model // cfg.ssm_head_dim
        n = cfg.ssm_head_dim
        # per chunk: amat 2·B·H·L²·N (einsum) ×2 (score+apply)
        #          + bonus/inter/state ≈ 6·B·L·H·N·N
        per_chunk = (4.0 * b * h * lch * lch * n
                     + 6.0 * b * lch * h * n * n)
        layers = cfg.n_layers
    else:  # zamba2 / mamba2 SSD
        h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        # scores 2·B·L²·N + y 2·B·L²·H·P + inter/state ≈ 6·B·L·H·N·P
        per_chunk = (2.0 * b * lch * lch * n
                     + 2.0 * b * lch * lch * h * p
                     + 6.0 * b * lch * h * n * p)
        layers = cfg.n_layers
    mult = 3.0 if cell.kind == "train" else 1.0   # fwd+bwd
    extra_flops = per_chunk * (nc - 1) * layers * mult / chips
    # byte traffic of the chunk body ~ flops / 8 (einsum-dominated,
    # operands revisited once per contraction) — a coarse but bounded-
    # error estimate, recorded separately in the row
    return {"flops": extra_flops, "bytes": extra_flops / 8.0}


def _probe_layers(cfg: ModelConfig) -> tuple:
    """(L1, L2, unit) reduced layer counts for the cost probes. For zamba2
    the differencing unit is one GROUP (shared block + period mamba
    layers), so probes are whole multiples of the period."""
    if cfg.family == "zamba2":
        p = cfg.shared_attn_period
        return p, 2 * p, "group"
    return 2, 4, "layer"


def _probe_cost(cfg: ModelConfig, cell, mesh, rules, policy,
                n_layers: int):
    """Compile a reduced-L FULLY-UNROLLED probe; return (cost, coll)."""
    pcfg = dataclasses.replace(cfg, n_layers=n_layers)
    lowered = lower_cell(pcfg, cell, mesh, rules, policy, donate=True,
                         unroll=True)
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    coll = parse_collectives(compiled.as_text())
    return cost, coll


def run_cell(arch: str, shape: str, mesh, mesh_tag: str,
             rules: sh.ShardingRules = sh.ShardingRules(),
             policy: ArithmeticPolicy = ArithmeticPolicy(),
             cache: dict | None = None, verbose: bool = True,
             force: bool = False, probes: bool = True) -> dict:
    """Lower + compile + analyze one cell.

    Accounting methodology (EXPERIMENTS.md §Dry-run):
      1. FULL-config ROLLED compile — the deliverable (proves the cell
         lowers+compiles on this mesh) + realistic peak memory.
      2. Two reduced-layer FULLY-UNROLLED probes (L1, L2); their cost
         difference is the exact per-layer flops/bytes/collectives
         (XLA counts a while body once regardless of trip count, so the
         rolled compile alone undercounts the layer loop L-fold).
      3. total = probe(L1) + (L_units - L1_units) · per_unit
         (+ analytic correction for rolled inner SSM chunk scans).
    """
    key = _cache_key(arch, shape, mesh_tag, rules, policy.mode)
    if cache is not None and key in cache and not force \
            and cache[key].get("status") == "ok":   # errors retry
        if verbose:
            print(f"[cached] {arch} × {shape} × {mesh_tag}")
        return cache[key]

    cfg = configs.get_config(arch)
    cell = configs.SHAPES[shape]
    t0 = time.time()
    row: dict = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                 "status": "ok"}
    try:
        lowered = lower_cell(cfg, cell, mesh, rules, policy, unroll=1)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        coll = parse_collectives(compiled.as_text())
        chips = meshlib.mesh_chips(mesh)

        if probes:
            l1, l2, unit = _probe_layers(cfg)
            cost_a, coll_a = _probe_cost(cfg, cell, mesh, rules, policy, l1)
            cost_b, coll_b = _probe_cost(cfg, cell, mesh, rules, policy, l2)
            if unit == "group":
                p = cfg.shared_attn_period
                units1, units2 = l1 / p, l2 / p
                total_units = cfg.n_layers / p   # tail ~ fractional group
            else:
                units1, units2 = l1, l2
                total_units = cfg.n_layers
            du = units2 - units1

            def _extrap(a, b):
                per_unit = (b - a) / du
                return a + (total_units - units1) * per_unit

            cost = {
                "flops": _extrap(cost_a.get("flops", 0.0),
                                 cost_b.get("flops", 0.0)),
                "bytes accessed": _extrap(
                    cost_a.get("bytes accessed", 0.0),
                    cost_b.get("bytes accessed", 0.0)),
            }
            coll = dataclasses.replace(
                coll_a,
                raw_bytes=_extrap(coll_a.raw_bytes, coll_b.raw_bytes),
                wire_bytes=_extrap(coll_a.wire_bytes, coll_b.wire_bytes),
                ops={k: int(_extrap(coll_a.ops.get(k, 0),
                                    coll_b.ops.get(k, 0)))
                     for k in set(coll_a.ops) | set(coll_b.ops)},
                bytes_by_kind={
                    k: _extrap(coll_a.bytes_by_kind.get(k, 0),
                               coll_b.bytes_by_kind.get(k, 0))
                    for k in set(coll_a.bytes_by_kind)
                    | set(coll_b.bytes_by_kind)})
            row["probe"] = f"{unit}:{l1}/{l2}"

        corr = inner_scan_correction(cfg, cell, chips)
        cost["flops"] = cost.get("flops", 0.0) + corr["flops"]
        cost["bytes accessed"] = (cost.get("bytes accessed", 0.0)
                                  + corr["bytes"])
        n_tokens = (cell.global_batch if cell.kind == "decode"
                    else cell.global_batch * cell.seq_len)
        mflops = model_flops(cfg, n_tokens, cell.kind,
                             kv_len=cell.seq_len)
        peak_bytes = (mem.argument_size_in_bytes
                      + mem.temp_size_in_bytes
                      + mem.output_size_in_bytes
                      - mem.alias_size_in_bytes)
        rep = analyze(arch, shape, mesh_tag, chips, cost, coll, mflops,
                      peak_bytes)
        row.update(rep.row())
        row.update({
            "collectives": coll.summary(),
            "coll_ops": coll.ops,
            "arg_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "out_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "fits_hbm": bool(peak_bytes < HW_V5E.hbm_gib * 2**30),
        })
        if verbose:
            print(f"[ok] {arch} × {shape} × {mesh_tag}: "
                  f"dom={row['dominant']} "
                  f"t=({row['t_compute_s']:.2e},{row['t_memory_s']:.2e},"
                  f"{row['t_collective_s']:.2e})s "
                  f"mem/dev={row['bytes_per_device_gib']:.2f}GiB "
                  f"lower={t_lower:.0f}s compile={t_compile:.0f}s")
    except Exception as e:
        row["status"] = "error"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[ERR] {arch} × {shape} × {mesh_tag}: {row['error']}")

    if cache is not None:
        cache[key] = row
        _save_cache(cache)
    return row


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--force", action="store_true", help="ignore cache")
    ap.add_argument("--policy", default="exact",
                    choices=["exact", "int8", "artemis_mxu"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="compile-success check only (multi-pod pass)")
    args = ap.parse_args()

    rules = sh.ShardingRules(fsdp=not args.no_fsdp,
                             seq_parallel=args.seq_parallel)
    policy = ArithmeticPolicy(mode=args.policy)
    cache = _load_cache()

    meshes = []
    if args.both_meshes or not args.multi_pod:
        meshes.append((meshlib.make_production_mesh(multi_pod=False),
                       "pod1_16x16"))
    if args.both_meshes or args.multi_pod:
        meshes.append((meshlib.make_production_mesh(multi_pod=True),
                       "pod2_2x16x16"))

    archs = [configs.canon(args.arch)] if args.arch else list(configs.ARCHS)
    n_ok = n_err = n_skip = 0
    for arch in archs:
        shapes = ([args.shape] if args.shape
                  else list(configs.SHAPES))
        runnable = set(configs.runnable_shapes(arch))
        for shape in shapes:
            if shape not in runnable:
                print(f"[skip] {arch} × {shape}: documented skip "
                      f"(DESIGN.md §Arch-applicability)")
                n_skip += 1
                continue
            for mesh, tag in meshes:
                # the multi-pod pass proves the `pod` axis shards; the
                # roofline table is single-pod only (brief §Dry-run 3)
                probes = not args.no_probes and tag.startswith("pod1")
                row = run_cell(arch, shape, mesh, tag, rules, policy,
                               cache=cache, force=args.force,
                               probes=probes)
                if row["status"] == "ok":
                    n_ok += 1
                else:
                    n_err += 1
    print(f"\ndry-run complete: {n_ok} ok, {n_err} errors, "
          f"{n_skip} documented skips")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
