"""Serving launcher — batched prefill + decode with the KV cache
(the paper is inference-oriented; this is the serve_step driver).

Continuous-batching-lite: requests with different prompt lengths are
left-padded into one batch, prefilled once, then decoded token-by-token
with greedy sampling. The ARTEMIS arithmetic policy applies to every
matmul in both phases.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.launch import steps as stepslib
from repro.models import frontend, model


def serve(arch: str = "qwen3_8b", smoke: bool = True,
          batch: int = 4, prompt_len: int = 32, gen_len: int = 16,
          policy_mode: str = "exact", seed: int = 0,
          params=None) -> dict:
    cfg = configs.get_config(arch, smoke=smoke)
    policy = ArithmeticPolicy(mode=policy_mode)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed), cfg)

    prefill = jax.jit(stepslib.make_prefill_step(cfg, policy))
    decode = jax.jit(stepslib.make_decode_step(cfg, policy))

    key = jax.random.PRNGKey(seed + 1)
    tokens = jax.random.randint(
        key, frontend.token_shape(cfg, batch, prompt_len), 2,
        cfg.vocab_size, dtype=jnp.int32)
    max_len = prompt_len + gen_len + frontend.n_prefix_tokens(cfg)
    cache = model.init_cache(cfg, batch, max_len, dtype=jnp.float32)

    bt = {"tokens": tokens}
    if cfg.modality == "vlm":
        bt["prefix_embeds"] = frontend.synth_prefix_embeds(
            jax.random.PRNGKey(seed + 2), cfg, batch)

    t0 = time.time()
    logits, cache = prefill(params, bt, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = []
    nxt = stepslib.greedy_sample(logits)
    t0 = time.time()
    for _ in range(gen_len):
        step_tok = nxt[:, None] if cfg.modality != "audio" else nxt[:, None]
        logits, cache = decode(params, step_tok, cache)
        nxt = stepslib.greedy_sample(logits)
        out_tokens.append(nxt)
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0

    gen = jnp.stack(out_tokens, axis=1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * gen_len / max(t_decode, 1e-9),
        "cache_index": int(cache["index"]),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--policy", default="exact",
                    choices=["exact", "int8", "artemis", "artemis_mxu"])
    args = ap.parse_args()
    out = serve(arch=args.arch, smoke=not args.full, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                policy_mode=args.policy)
    print(f"prefill {out['prefill_s']*1e3:.0f}ms | decode "
          f"{out['decode_tok_per_s']:.1f} tok/s | "
          f"generated shape {out['generated'].shape}")


if __name__ == "__main__":
    main()
