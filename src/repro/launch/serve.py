"""Serving launcher — static batch or the continuous-batching engine.

Two modes (the paper is inference-oriented; this is the serve driver):

  --mode static   the original continuous-batching-lite path: requests
                  with different prompt lengths are left-padded into one
                  batch, prefilled once, then decoded in lockstep with
                  greedy sampling against the dense KV cache.
  --mode engine   the `repro.serve` engine: per-request lifecycles with
                  chunked+batched prefill composed with decode into
                  mixed steps by the ARTEMIS-cost-aware scheduler,
                  driven by a synthetic Poisson trace (`--prefill-chunk`
                  sets the chunk size, `--seed` the trace/params seed).
                  EVERY family routes through the same engine: the
                  attention archs (dense/moe) serve over the paged KV
                  backend (COW prefix sharing, `--prefix-groups` et
                  al.), the recurrent archs (rwkv6/zamba2) over the
                  state-slot backend (`--n-slots` sizes its pool) — see
                  repro.serve.backend. `--temperature/--top-k/--top-p/
                  --sample-seed` switch the trace to stochastic decode
                  on per-request RNG lanes (`--sampled-fraction` mixes
                  greedy and sampled requests) — deterministic for a
                  fixed seed, independent of batch composition.
                  `--mesh-shards N` (attention archs) serves tensor-
                  parallel over the sharded paged backend; on CPU set
                  XLA_FLAGS=--xla_force_host_platform_device_count=N.

The ARTEMIS arithmetic policy applies to every matmul in both modes.

Wall-clock use here is intentional (the CLI reports real prefill /
decode / drain seconds next to the virtual-clock metrics) and carries
`repro: allow[wall-clock-in-serve]` markers — the virtual-clock
contract applies to serve-layer logic, not to the driver timing it.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.launch import steps as stepslib
from repro.models import frontend, model


def serve(arch: str = "qwen3_8b", smoke: bool = True,
          batch: int = 4, prompt_len: int = 32, gen_len: int = 16,
          policy_mode: str = "exact", seed: int = 0,
          params=None) -> dict:
    """Static-batch serving: one prefill, lockstep decode."""
    cfg = configs.get_config(arch, smoke=smoke)
    policy = ArithmeticPolicy(mode=policy_mode)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed), cfg)

    prefill = jax.jit(stepslib.make_prefill_step(cfg, policy))
    decode = jax.jit(stepslib.make_decode_step(cfg, policy))

    key = jax.random.PRNGKey(seed + 1)
    tokens = jax.random.randint(
        key, frontend.token_shape(cfg, batch, prompt_len), 2,
        cfg.vocab_size, dtype=jnp.int32)
    max_len = prompt_len + gen_len + frontend.n_prefix_tokens(cfg)
    cache = model.init_cache(cfg, batch, max_len, dtype=jnp.float32)

    bt = {"tokens": tokens}
    if cfg.modality == "vlm":
        bt["prefix_embeds"] = frontend.synth_prefix_embeds(
            jax.random.PRNGKey(seed + 2), cfg, batch)

    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator
    logits, cache = prefill(params, bt, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator

    out_tokens = []
    nxt = stepslib.greedy_sample(logits)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator
    for _ in range(gen_len):
        # (B,) -> (B, 1); audio's (B, C) broadcasts to (B, 1, C) the
        # same way, so one expression covers both modalities
        step_tok = nxt[:, None]
        logits, cache = decode(params, step_tok, cache)
        nxt = stepslib.greedy_sample(logits)
        out_tokens.append(nxt)
    jax.block_until_ready(out_tokens[-1])
    t_decode = time.time() - t0  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator

    gen = jnp.stack(out_tokens, axis=1)
    return {
        "generated": gen,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * gen_len / max(t_decode, 1e-9),
        "cache_index": int(cache["index"]),
    }


def serve_engine(arch: str = "qwen3_8b", smoke: bool = True,
                 n_requests: int = 16, arrival_rate: float = 200.0,
                 prompt_len: int = 32, gen_len: int = 16,
                 policy_mode: str = "exact", seed: int = 0,
                 page_size: int = 8, n_pages: int = 256,
                 max_batch: int = 8, scheduler: str = "cost",
                 prefill_chunk: int = 32, prefix_sharing: bool = True,
                 prefix_groups: int = 0, prefix_len: int = 0,
                 n_slots: int = 0, sampled_fraction: float = 0.0,
                 temperature: float = 0.8, top_k: int = 0,
                 top_p: float = 1.0, sample_seed: int = -1,
                 observability: str = "metrics",
                 trace_json: str | None = None,
                 mesh_shards: int = 1, attn_impl: str = "gather",
                 params=None) -> dict:
    """Continuous-batching serving over a synthetic Poisson trace (any
    family — the engine routes to the right sequence backend). With
    `sampled_fraction > 0` that share of requests decodes stochastic
    (temperature/top-k/top-p on per-request RNG lanes, deterministic
    for a fixed trace seed); the rest stay greedy. `trace_json` (which
    implies observability="trace") exports the run's structured event
    log as Chrome trace-event JSON — open it at https://ui.perfetto.dev
    over the virtual ARTEMIS clock."""
    from repro.serve import (EngineConfig, ServeEngine, TrafficConfig,
                             export_chrome_trace, synth_trace)
    from repro.serve.traffic import trace_stats
    cfg = configs.get_config(arch, smoke=smoke)
    policy = ArithmeticPolicy(mode=policy_mode)
    if trace_json is not None:
        observability = "trace"
    max_len = prefix_len + prompt_len + gen_len
    ecfg = EngineConfig(
        page_size=page_size, n_pages=n_pages, max_batch=max_batch,
        max_pages_per_seq=max(1, -(-max_len // page_size)) + 1,
        prefill_chunk=prefill_chunk, scheduler=scheduler,
        prefix_sharing=prefix_sharing, n_slots=n_slots,
        max_seq_len=max(max_len + 1, 2), observability=observability,
        mesh_shards=mesh_shards, attn_impl=attn_impl)
    eng = ServeEngine(cfg, params=params, policy=policy, ecfg=ecfg,
                      seed=seed)
    trace = synth_trace(TrafficConfig(
        n_requests=n_requests, arrival_rate=arrival_rate,
        prompt_len_min=max(1, prompt_len // 2), prompt_len_max=prompt_len,
        gen_len_min=max(1, gen_len // 2), gen_len_max=gen_len,
        vocab_size=cfg.vocab_size, seed=seed,
        n_prefix_groups=prefix_groups, prefix_len=prefix_len,
        sampled_fraction=sampled_fraction, temperature=temperature,
        top_k=top_k, top_p=top_p, sample_seed=sample_seed))
    eng.submit_trace(trace)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator
    eng.drain()
    wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- real demo wall time, printed for the operator
    m = eng.metrics()
    m["wall_s"] = wall
    m["wall_tok_per_s"] = m["n_generated_tokens"] / max(wall, 1e-9)
    if trace_json is not None:
        export_chrome_trace(
            eng.events, trace_json,
            metadata={"arch": arch, "seed": seed,
                      "scheduler": scheduler, **trace_stats(trace)})
    return {"metrics": m, "results": eng.results(),
            "events": eng.events, "attribution": eng.attribution()}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mode", default="static",
                    choices=["static", "engine"])
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / engine decode lanes")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--policy", default="exact",
                    choices=["exact", "int8", "artemis", "artemis_mxu"])
    ap.add_argument("--n-requests", type=int, default=16,
                    help="engine: synthetic trace length")
    ap.add_argument("--arrival-rate", type=float, default=200.0,
                    help="engine: Poisson arrivals per virtual second")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--n-pages", type=int, default=256)
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="engine: prompt tokens per prefill chunk")
    ap.add_argument("--scheduler", default="cost",
                    choices=["cost", "fcfs"])
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="engine: disable COW prefix/page sharing "
                         "(paged-KV backend)")
    ap.add_argument("--n-slots", type=int, default=0,
                    help="engine: state-slot pool size for recurrent "
                         "archs (0 = auto: batch lanes + 1)")
    ap.add_argument("--prefix-groups", type=int, default=0,
                    help="engine: shared-prefix trace groups (0 = "
                         "independent prompts)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="engine: tokens shared within a prefix group")
    ap.add_argument("--seed", type=int, default=0,
                    help="params + synthetic trace seed")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine: sampling temperature for sampled "
                         "requests (0 = all-greedy trace)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="engine: top-k truncation for sampled "
                         "requests (0 = none)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="engine: nucleus mass for sampled requests "
                         "(1.0 = none)")
    ap.add_argument("--sample-seed", type=int, default=-1,
                    help="engine: fixed RNG-lane seed for every "
                         "sampled request (-1 = per-request seeds "
                         "from the trace rng)")
    ap.add_argument("--sampled-fraction", type=float, default=None,
                    help="engine: fraction of requests decoded "
                         "stochastically (default: 1.0 when "
                         "--temperature > 0, else 0)")
    ap.add_argument("--observability", default="metrics",
                    choices=["metrics", "trace"],
                    help="engine: 'trace' retains the structured "
                         "event log (span assembly / Perfetto export)")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help="engine: export the run as Chrome trace-event "
                         "JSON to PATH (implies --observability trace)")
    ap.add_argument("--mesh-shards", type=int, default=1,
                    help="engine: tensor-parallel degree — >1 serves "
                         "attention archs over the sharded paged "
                         "backend (on CPU, simulate devices with "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--attn-impl", default="gather",
                    choices=["gather", "fused"],
                    help="engine: paged attention core — 'fused' walks "
                         "the block table inside the Pallas kernel "
                         "(exact policy, mesh_shards=1; interpreted "
                         "off-TPU)")
    args = ap.parse_args()
    sampled_fraction = args.sampled_fraction
    if sampled_fraction is None:
        sampled_fraction = 1.0 if args.temperature > 0 else 0.0
    elif sampled_fraction > 0 and args.temperature <= 0:
        ap.error("--sampled-fraction > 0 requires --temperature > 0")

    if args.mode == "static":
        out = serve(arch=args.arch, smoke=not args.full, batch=args.batch,
                    prompt_len=args.prompt_len, gen_len=args.gen_len,
                    policy_mode=args.policy, seed=args.seed)
        print(f"prefill {out['prefill_s']*1e3:.0f}ms | decode "
              f"{out['decode_tok_per_s']:.1f} tok/s | "
              f"generated shape {out['generated'].shape}")
        return

    out = serve_engine(
        arch=args.arch, smoke=not args.full, n_requests=args.n_requests,
        arrival_rate=args.arrival_rate, prompt_len=args.prompt_len,
        gen_len=args.gen_len, policy_mode=args.policy, seed=args.seed,
        page_size=args.page_size, n_pages=args.n_pages,
        max_batch=args.batch, scheduler=args.scheduler,
        prefill_chunk=args.prefill_chunk,
        prefix_sharing=not args.no_prefix_sharing,
        prefix_groups=args.prefix_groups, prefix_len=args.prefix_len,
        n_slots=args.n_slots, sampled_fraction=sampled_fraction,
        temperature=args.temperature, top_k=args.top_k,
        top_p=args.top_p, sample_seed=args.sample_seed,
        observability=args.observability, trace_json=args.trace_json,
        mesh_shards=args.mesh_shards, attn_impl=args.attn_impl)
    m = out["metrics"]
    line = (f"engine: {m['n_done']} requests, "
            f"{m['n_generated_tokens']} tokens "
            f"({m['n_sampled_tokens']} sampled) | "
            f"{m['wall_tok_per_s']:.1f} tok/s wall | "
            f"p50 {m['p50_latency_s']*1e3:.3f}ms "
            f"p99 {m['p99_latency_s']*1e3:.3f}ms "
            f"p99-ttft {m['p99_ttft_s']*1e3:.3f}ms (virtual) | "
            f"cache util {m['cache_utilization']:.2f} "
            f"(logical {m['logical_cache_utilization']:.2f})")
    if "prefix_hit_rate" in m:       # paged-KV backend extras
        line += (f" | prefix hits {m['n_prefix_hits']} "
                 f"(rate {m['prefix_hit_rate']:.2f}) | "
                 f"{m['n_cow_forks']} COW forks")
    if "n_state_slots" in m:         # state-slot backend extras
        line += f" | {m['n_state_slots']} state slots"
    print(line + f" | {m['n_preemptions']} preemptions")
    print(f"energy: {m['total_energy_J']*1e6:.2f} uJ total "
          f"({m['energy_per_token_J']*1e9:.2f} nJ/token) | "
          f"prefill {m['prefill_energy_J']*1e6:.2f} uJ / "
          f"decode {m['decode_energy_J']*1e6:.2f} uJ | "
          f"busy {m['busy_virtual_s']*1e3:.3f} of "
          f"{m['virtual_time_s']*1e3:.3f} virtual ms")
    if args.trace_json:
        print(f"trace: wrote {args.trace_json} "
              f"({m['n_events']} counted events) — open at "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
