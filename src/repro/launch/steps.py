"""Step functions: train_step / prefill_step / decode_step builders.

Each builder returns a pure function suitable for jax.jit with explicit
in/out shardings (launch.dryrun wires those). The ARTEMIS arithmetic
policy and sharding rules are closed over — policy changes recompile,
exactly like a production config push.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import OptimizerConfig, adamw_update
from repro.parallel.context import use_sharding


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    policy: ArithmeticPolicy = ArithmeticPolicy(),
                    mesh=None, rules=None, remat: bool = True,
                    unroll: int | bool = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            inputs = {"tokens": batch["tokens"]}
            if "prefix_embeds" in batch:
                inputs["prefix_embeds"] = batch["prefix_embeds"]
            logits, aux, _ = model.apply(p, cfg, inputs, policy=policy,
                                         remat=remat, unroll=unroll)
            if "prefix_embeds" in batch:
                logits = logits[:, -batch["tokens"].shape[1]:]
            loss = model.lm_loss(logits, batch["labels"])
            return loss + aux, (loss, aux)

        def run():
            (total, (loss, aux)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_params, new_opt, om = adamw_update(
                params, grads, opt_state, opt_cfg)
            metrics = {"loss": loss, "aux_loss": aux, "total_loss": total,
                       **om}
            return new_params, new_opt, metrics

        if mesh is not None and rules is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return train_step


def make_prefill_step(cfg: ModelConfig,
                      policy: ArithmeticPolicy = ArithmeticPolicy(),
                      mesh=None, rules=None, unroll: int | bool = 1):
    """(params, batch, cache) -> (last_logits, cache). Writes the prompt
    into the cache and returns the next-token logits."""

    def prefill_step(params, batch, cache):
        inputs = {"tokens": batch["tokens"]}
        if "prefix_embeds" in batch:
            inputs["prefix_embeds"] = batch["prefix_embeds"]

        def run():
            logits, _, new_cache = model.apply(
                params, cfg, inputs, policy=policy, cache=cache,
                remat=False, unroll=unroll)
            return logits[:, -1], new_cache

        if mesh is not None and rules is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return prefill_step


def make_decode_step(cfg: ModelConfig,
                     policy: ArithmeticPolicy = ArithmeticPolicy(),
                     mesh=None, rules=None, unroll: int | bool = 1):
    """(params, tokens, cache) -> (logits, cache) — ONE new token against
    the populated KV cache (the brief's serve_step for decode_* cells)."""

    def decode_step(params, tokens, cache):
        def run():
            logits, _, new_cache = model.apply(
                params, cfg, {"tokens": tokens}, policy=policy,
                cache=cache, remat=False, unroll=unroll)
            return logits[:, -1], new_cache

        if mesh is not None and rules is not None:
            with use_sharding(mesh, rules):
                return run()
        return run()

    return decode_step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
