"""Production meshes (brief: MULTI-POD DRY-RUN §1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (device counts lock on first backend init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """single pod: (data=16, model=16) = 256 chips (one v5e pod);
    multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # why: the launch layer's production mesh factory — the serve seam
    # (repro/serve/mesh.py) covers serving; this covers training runs
    # repro: allow[mesh-discipline]
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 2, model: int = 2):
    """Tiny mesh for CPU integration tests (requires forced host devices)."""
    # why: test-only mesh factory, same ownership story as the
    # production factory above
    # repro: allow[mesh-discipline]
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.shape.values():
        n *= s
    return n


def mesh_name(mesh) -> str:
    return "x".join(f"{k}{v}" for k, v in mesh.shape.items())
