"""input_specs() — ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation). The dry-run lowers
against these; nothing is ever materialized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ShapeCell
from repro.models import frontend, model
from repro.models.config import ModelConfig
from repro.optim import adamw_init


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def param_shapes(cfg: ModelConfig, dtype=None):
    """dtype: override float-leaf dtype (serving casts params to bf16 at
    load; decode/prefill cells lower against the cast shapes — §Perf H3)."""
    shapes = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0), cfg))
    if dtype is None:
        return shapes
    def cast(l):
        if jnp.issubdtype(l.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(l.shape, jnp.dtype(dtype))
        return l
    return jax.tree.map(cast, shapes)


def opt_shapes(cfg: ModelConfig):
    p = param_shapes(cfg)
    return jax.eval_shape(adamw_init, p)


def batch_shapes(cfg: ModelConfig, batch: int, seq: int,
                 with_labels: bool = True) -> dict:
    tok = _sds(frontend.token_shape(cfg, batch, seq), jnp.int32)
    out = {"tokens": tok}
    if with_labels:
        out["labels"] = tok
    if cfg.modality == "vlm":
        out["prefix_embeds"] = frontend.prefix_embed_spec(cfg, batch)
    return out


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: model.init_cache(cfg, batch, max_len, dtype))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """All step inputs for one (arch × shape) cell, as ShapeDtypeStructs.

    train:   {params, opt_state, batch{tokens, labels[, prefix_embeds]}}
    prefill: {params, batch{tokens[, prefix_embeds]}, cache(empty, max_len)}
    decode:  {params, tokens(B, 1[, C]), cache(populated shape, seq_len)}
    """
    b, s = cell.global_batch, cell.seq_len
    prefix = frontend.n_prefix_tokens(cfg)
    if cell.kind == "train":
        return {
            "params": param_shapes(cfg),
            "opt_state": opt_shapes(cfg),
            "batch": batch_shapes(cfg, b, s),
        }
    # serving: params are loaded in the compute dtype (bf16) — halves the
    # per-step weight traffic and kills fp32->bf16 convert copies (H3)
    params = param_shapes(cfg, dtype=cfg.compute_dtype)
    if cell.kind == "prefill":
        return {
            "params": params,
            "batch": batch_shapes(cfg, b, s, with_labels=False),
            "cache": cache_shapes(cfg, b, s + prefix),
        }
    assert cell.kind == "decode"
    return {
        "params": params,
        "tokens": _sds(frontend.token_shape(cfg, b, 1), jnp.int32),
        "cache": cache_shapes(cfg, b, s + prefix),
    }
