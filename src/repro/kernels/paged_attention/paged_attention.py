"""Fused Pallas paged-attention kernel — block-table walk in-kernel.

The serving stack's gather path (`serve.paged_model._paged_attn_block`)
materializes every request's block table into a contiguous
(B, Smax, KV, Dh) view before attending — the data-movement cost paged
kernels exist to eliminate.  This kernel walks the per-request block
table INSIDE the kernel instead: the K/V operands are the raw page
pools (P, page, KV, Dh), and their `BlockSpec` index maps read the
scalar-prefetched block-table operand to fetch page
`block_tables[b, pi]` at grid step (b, h, pi) — a block-sparse gather
the compiler pipelines against compute, with nothing contiguous ever
built (paper §III.C.2/§III.D.3: attention streamed bank-by-bank out of
the arrays with the online LSE softmax).

Softmax is the same online (m, l) running-statistics scheme as
`kernels.flash_attention`: m/l live in f32 revisited output blocks
accumulated across the page axis (innermost grid dim), finalized
(o /= l) at the last page.  GQA folds q head h onto kv head h // group
in the index map, exactly like the flash kernel.

Masking reproduces `serve.paged_model._attn_core` bit-for-bit in
semantics: table slot pi covers absolute kv positions
[pi*page, (pi+1)*page), so the kv position of slot s in grid step pi IS
pi*page + s; a query at absolute position p keeps kv positions t with
t <= p (causal over the whole written prefix) and, under a sliding
window, t > p - window.  Trash-page and padding slots all sit at
t > p for every valid query, so per-lane length masking falls out of
`positions` alone — no separate length operand.

Page skipping: pages whose first kv position exceeds the row's maximum
query position carry only trash/unwritten slots and are skipped (no
FLOPs — the grid visits them, `pl.when` gates the body); with a window,
pages entirely below every query's window are skipped from the other
side.  Query positions within a row must be monotone non-decreasing
(the serve builders emit start_pos + arange), which makes row position
0 the min and row position S-1 the max.

Grid: (B, H, Pmax), pages innermost.  The whole (S, Dh) query block
rides along every page step; S is the prefill chunk (or 1 for decode),
so one kernel covers both step shapes — the serve layer selects it per
`EngineConfig.attn_impl` with zero engine/scheduler branches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention.flash_attention import (
    NEG_INF,
    _interpret_default,
)


def _paged_kernel(bt_ref, posq_ref, q_ref, pos_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, *, scale: float,
                  window: int | None, page: int, pmax: int, s: int):
    bi = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # page skip off the scalar-prefetched positions: row positions are
    # monotone, so [bi, 0] / [bi, s-1] bound the row's query window.
    # A page whose first kv position is past the max query position
    # holds only trash/unwritten slots; with a sliding window, a page
    # whose last kv position is at or below (min position - window) is
    # invisible to every query.
    q_hi = posq_ref[bi, s - 1]
    visit = pi * page <= q_hi
    if window is not None:
        q_lo = posq_ref[bi, 0]
        visit &= (pi + 1) * page - 1 > q_lo - window

    @pl.when(visit)
    def _update():
        q = q_ref[0, :, 0].astype(jnp.float32)        # (S, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)        # (page, Dh)
        v = v_ref[0, :, 0].astype(jnp.float32)        # (page, Dh)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # (S, page)
        qpos = pos_ref[0]                              # (S,) i32
        kvpos = pi * page + jax.lax.broadcasted_iota(
            jnp.int32, (s, page), 1)
        keep = kvpos <= qpos[:, None]
        if window is not None:
            keep &= kvpos > qpos[:, None] - window
        sc = jnp.where(keep, sc, NEG_INF)

        m_prev = m_ref[0, 0]                           # (S,)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        l_ref[0, 0] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[0, 0] = m_new
        o_ref[0, :, 0] = (o_ref[0, :, 0] * alpha[:, None]
                          + jax.lax.dot_general(
                              p, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32))

    @pl.when(pi == pmax - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0, 0], 1e-30)
        o_ref[0, :, 0] = o_ref[0, :, 0] / l[:, None]


@functools.partial(
    jax.jit, static_argnames=("window", "scale", "interpret"),
)
def paged_attention(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    positions: jax.Array,
    *,
    window: int | None = None,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused paged attention over one layer's page pool.

    q:            (B, S, H, Dh) queries (S = chunk, or 1 for decode)
    k/v_pages:    (P, page, KV, Dh) the layer's page pool, H % KV == 0
    block_tables: (B, Pmax) i32 page ids per row, trash page 0 in
                  unused slots
    positions:    (B, S) i32 absolute query positions, monotone
                  non-decreasing within a row

    Returns the context tensor (B, S, H, Dh) f32.  A query at position
    p attends to kv positions t <= p (and t > p - window when set) of
    its own row's table — `_attn_core` semantics, computed without ever
    materializing the gathered view.  `interpret=None` resolves via the
    shared `_interpret_default()` platform probe.
    """
    if interpret is None:
        interpret = _interpret_default()
    b, s, h, hd = q.shape
    npages, page, kvh, hd_k = k_pages.shape
    if hd_k != hd or v_pages.shape != k_pages.shape:
        raise ValueError(
            f"pool/query shape mismatch: q {q.shape}, k_pages "
            f"{k_pages.shape}, v_pages {v_pages.shape}")
    if h % kvh:
        raise ValueError(f"H={h} not a multiple of KV={kvh}")
    group = h // kvh
    pmax = block_tables.shape[1]
    if block_tables.shape[0] != b or positions.shape != (b, s):
        raise ValueError(
            f"batch mismatch: q {q.shape}, block_tables "
            f"{block_tables.shape}, positions {positions.shape}")
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if scale is None:
        scale = 1.0 / (hd**0.5)

    kernel = functools.partial(
        _paged_kernel, scale=scale, window=window, page=page,
        pmax=pmax, s=s,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        # block_tables drives the K/V index maps; positions backs the
        # scalar page-skip predicate (its vector copy rides in VMEM)
        num_scalar_prefetch=2,
        grid=(b, h, pmax),
        in_specs=[
            pl.BlockSpec((1, s, 1, hd),
                         lambda bi, hi, pi, bt, pq: (bi, 0, hi, 0)),
            pl.BlockSpec((1, s),
                         lambda bi, hi, pi, bt, pq: (bi, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, hi, pi, bt, pq:
                         (bt[bi, pi], 0, hi // group, 0)),
            pl.BlockSpec((1, page, 1, hd),
                         lambda bi, hi, pi, bt, pq:
                         (bt[bi, pi], 0, hi // group, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, s, 1, hd),
                         lambda bi, hi, pi, bt, pq: (bi, 0, hi, 0)),
            pl.BlockSpec((1, 1, s),
                         lambda bi, hi, pi, bt, pq: (bi, hi, 0)),
            pl.BlockSpec((1, 1, s),
                         lambda bi, hi, pi, bt, pq: (bi, hi, 0)),
        ],
    )
    o, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),  # m (scratch-ish)
            jax.ShapeDtypeStruct((b, h, s), jnp.float32),  # l (scratch-ish)
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), positions.astype(jnp.int32),
      q, positions.astype(jnp.int32), k_pages, v_pages)
    return o
