"""Pure-jnp oracle for the paged-attention kernel.

Materializes the gathered view and applies exactly the masked softmax
of `serve.paged_model._attn_core` — the kernel-level parity tests pin
the fused kernel against this, and the serve-level tests pin the whole
fused forward against the gather path itself.
"""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, positions,
                        *, window=None, scale=None):
    """Same signature/semantics as `paged_attention` (q: (B, S, H, Dh),
    pools (P, page, KV, Dh), block_tables (B, Pmax), positions (B, S));
    returns (B, S, H, Dh) f32 via the explicit gather."""
    b, s, h, hd = q.shape
    _, page, kvh, _ = k_pages.shape
    group = h // kvh
    if scale is None:
        scale = 1.0 / (hd**0.5)
    smax = block_tables.shape[1] * page
    kall = k_pages[block_tables].reshape(b, smax, kvh, hd)
    vall = v_pages[block_tables].reshape(b, smax, kvh, hd)
    kf = jnp.repeat(kall, group, axis=2).astype(jnp.float32)  # (B,Smax,H,Dh)
    vf = jnp.repeat(vall, group, axis=2).astype(jnp.float32)
    sc = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf) * scale
    t = jnp.arange(smax, dtype=jnp.int32)[None, None, :]      # (1, 1, Smax)
    keep = t <= positions[:, :, None]                         # (B, S, Smax)
    if window is not None:
        keep = keep & (t > positions[:, :, None] - window)
    sc = jnp.where(keep[:, None], sc, -1e30)
    probs = jnp.exp(sc - jnp.max(sc, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhst,bthd->bshd", probs, vf)
