"""Fused Pallas paged-attention kernel (see paged_attention.py)."""
from repro.kernels.paged_attention.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

__all__ = ["paged_attention", "paged_attention_ref"]
