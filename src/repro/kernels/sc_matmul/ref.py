"""Pure-jnp oracle for the sc_matmul kernel.

Reuses the independently-tested repro.core primitives (closed-form TCU
multiply, MOMCAP readout), so the kernel and the oracle share no code path
beyond those pinned-by-exhaustive-test scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.analog import MomcapConfig, readout_quantize
from repro.core.quantization import SC_LEVELS, magnitude_sign
from repro.core.stochastic import sc_multiply

ACC_DEPTH = 20


def sc_matmul_ref(
    aq: jax.Array,
    bq: jax.Array,
    *,
    mode: str = "artemis",
    readout_bits: int | None = 8,
    rbar: float = 63.5,
) -> jax.Array:
    """Oracle over pre-quantized int8 operands; same output units as the
    kernel (int32 dot units for int8 mode, SC product units otherwise)."""
    a = aq.astype(jnp.int32)
    b = bq.astype(jnp.int32)
    if mode == "int8":
        return jnp.matmul(a, b)
    if mode == "artemis_mxu":
        value = jnp.matmul(a, b).astype(jnp.float32)
        signs = jnp.matmul(jnp.sign(a), jnp.sign(b)).astype(jnp.float32)
        return (value - rbar * signs) / SC_LEVELS
    assert mode == "artemis", mode

    ma, sa = magnitude_sign(aq)
    mb, sb = magnitude_sign(bq)
    k = ma.shape[-1]
    assert k % ACC_DEPTH == 0
    ngroups = k // ACC_DEPTH
    cfg = MomcapConfig(acc_depth=ACC_DEPTH, readout_bits=readout_bits)

    # (M, ngroups, g, N) products — small shapes only (it's an oracle)
    p = sc_multiply(ma[:, :, None], mb[None, :, :]).astype(jnp.float32)
    s = (sa[:, :, None] * sb[None, :, :]).astype(jnp.float32)
    p = p.reshape(ma.shape[0], ngroups, ACC_DEPTH, mb.shape[1])
    s = s.reshape(ma.shape[0], ngroups, ACC_DEPTH, mb.shape[1])
    pos = jnp.sum(jnp.where(s > 0, p, 0.0), axis=2)
    neg = jnp.sum(jnp.where(s < 0, p, 0.0), axis=2)
    return jnp.sum(
        readout_quantize(pos, cfg) - readout_quantize(neg, cfg), axis=1
    )
