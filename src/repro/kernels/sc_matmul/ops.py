"""jit'd public wrapper around the sc_matmul Pallas kernel.

Owns quantization (per ArithmeticPolicy), block padding, dequantization and
the CPU-interpret/TPU-compiled switch.  `sc_linear` is the drop-in matmul
used by repro.models when a policy routes a layer through the kernel path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as q
from repro.core.policy import ArithmeticPolicy
from repro.core.quantization import SC_LEVELS
from repro.kernels.sc_matmul.sc_matmul import sc_matmul_quantized


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def sc_matmul(
    a: jax.Array,
    b: jax.Array,
    policy: ArithmeticPolicy = ArithmeticPolicy(mode="artemis"),
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """ARTEMIS matmul through the Pallas kernel. a: (M, K), b: (K, N) float.

    Semantically equivalent to repro.core.artemis_matmul for 2-D operands
    (modulo sigma_analog, which is emulation-only) — pinned by
    tests/test_kernels.py.
    """
    if interpret is None:
        interpret = _interpret_default()
    if bk is None:
        bk = 160 if policy.mode == "artemis" else 256
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    m, k = a.shape
    _, n = b.shape
    sa = q.quant_scale(a, 8, policy.act_quant_axis)
    sb = q.quant_scale(b, 8, policy.weight_quant_axis)
    aq = _pad_to(_pad_to(q.quantize(a, sa), 0, bm), 1, bk)
    bq = _pad_to(_pad_to(q.quantize(b, sb), 0, bk), 1, bn)
    out = sc_matmul_quantized(
        aq, bq, mode=policy.mode, readout_bits=policy.readout_bits,
        rbar=policy.rbar, bm=bm, bn=bn, bk=bk, interpret=interpret,
    )[:m, :n]
    if policy.mode == "int8":
        out = out.astype(jnp.float32) * sa * sb
    else:
        out = out.astype(jnp.float32) * SC_LEVELS * sa * sb
    if policy.ste:
        exact = jnp.matmul(a, b)
        out = exact + jax.lax.stop_gradient(out - exact)
    return out
