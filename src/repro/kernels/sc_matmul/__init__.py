from repro.kernels.sc_matmul.ops import sc_matmul
from repro.kernels.sc_matmul.ref import sc_matmul_ref
from repro.kernels.sc_matmul.sc_matmul import sc_matmul_quantized

__all__ = ["sc_matmul", "sc_matmul_ref", "sc_matmul_quantized"]
