"""Pallas TPU kernel for the ARTEMIS stochastic-analog MAC (paper §III.A).

TPU adaptation of the in-DRAM pipeline (DESIGN.md §2):

  * the DRAM bit-line AND over 128-bit TCU streams becomes the closed-form
    floor(m_a*m_b/128) evaluated on the VPU over VMEM-resident blocks;
  * the MOMCAP group-of-20 analog accumulation + quantizing A_to_B readout
    happens inside the K-loop, per group, exactly as the tiles do it;
  * the NSC partial-sum reduction is the revisited f32 output block
    accumulated across the K grid axis (K is the innermost grid dimension,
    the standard TPU matmul accumulation pattern);
  * sign handling mirrors §III.C.1: positive and negative product
    magnitudes are accumulated separately and subtracted after readout.

Three modes:
  artemis      faithful pipeline (VPU element work, O(bm*bk*bn) per block)
  int8         plain int8 MXU matmul, int32 accumulation (Q(8-bit) ladder)
  artemis_mxu  beyond-paper fast path: value-dot minus rbar * sign-dot —
               two MXU matmuls approximating the floor-truncation bias
               (error analysis in benchmarks/table5_calibration.py)

Block shapes: bm/bn default 128 (MXU/VREG lane alignment); bk must be a
multiple of the MOMCAP depth (20) in artemis mode so analog groups never
straddle VMEM blocks — default 160 (8 groups; sublane-aligned for f32/int8).
Operands arrive pre-quantized int8 (ops.py owns scales); outputs are in "SC
product units" (x128 smaller than integer dot units for artemis modes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACC_DEPTH = 20  # MOMCAP consecutive accumulations (paper §III.A.2)


def _readout(x: jax.Array, readout_bits: int | None) -> jax.Array:
    """Inline A_to_B quantizing readout (analog.readout_quantize, no noise)."""
    if readout_bits is None:
        return x
    levels = float(2**readout_bits - 1)
    full_scale = float(ACC_DEPTH * 127)
    delta = full_scale / levels
    return jnp.clip(jnp.round(x * (1.0 / delta)), 0.0, levels) * delta


def _sc_matmul_kernel(a_ref, b_ref, o_ref, *, nk: int, mode: str,
                      readout_bits: int | None, rbar: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)   # (bm, bk) signed
    b = b_ref[...].astype(jnp.int32)   # (bk, bn) signed

    if mode == "int8":
        # exact int8 dot; int32 accumulation on the MXU
        o_ref[...] += jax.lax.dot_general(
            a_ref[...], b_ref[...],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        return

    if mode == "artemis_mxu":
        value = jax.lax.dot_general(
            a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
        sgn_a = jnp.sign(a).astype(jnp.int8)
        sgn_b = jnp.sign(b).astype(jnp.int8)
        signs = jax.lax.dot_general(
            sgn_a, sgn_b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        ).astype(jnp.float32)
        o_ref[...] += (value - rbar * signs) * (1.0 / 128.0)
        return

    assert mode == "artemis", mode
    bk = a.shape[1]
    assert bk % ACC_DEPTH == 0, "bk must be a multiple of the MOMCAP depth"
    ma = jnp.abs(a).astype(jnp.float32)
    mb = jnp.abs(b).astype(jnp.float32)
    sa = jnp.sign(a).astype(jnp.float32)
    sb = jnp.sign(b).astype(jnp.float32)

    acc = jnp.zeros_like(o_ref, dtype=jnp.float32)
    for g in range(bk // ACC_DEPTH):
        sl = slice(g * ACC_DEPTH, (g + 1) * ACC_DEPTH)
        # one MOMCAP group: (bm, 20, bn) floor products on the VPU
        p = jnp.floor(ma[:, sl, None] * mb[None, sl, :] * (1.0 / 128.0))
        s = sa[:, sl, None] * sb[None, sl, :]
        pos = jnp.sum(jnp.where(s > 0, p, 0.0), axis=1)
        neg = jnp.sum(jnp.where(s < 0, p, 0.0), axis=1)
        acc += _readout(pos, readout_bits) - _readout(neg, readout_bits)
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("mode", "readout_bits", "rbar", "bm", "bn", "bk",
                     "interpret"),
)
def sc_matmul_quantized(
    aq: jax.Array,
    bq: jax.Array,
    *,
    mode: str = "artemis",
    readout_bits: int | None = 8,
    rbar: float = 63.5,
    bm: int = 128,
    bn: int = 128,
    bk: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Blocked ARTEMIS matmul over pre-quantized int8 operands.

    aq: (M, K) int8, bq: (K, N) int8; M, N, K must be multiples of the block
    shapes (ops.py pads).  Returns (M, N): int32 for mode="int8" (integer
    dot units), float32 in SC product units otherwise.
    """
    if bk is None:
        bk = 160 if mode == "artemis" else 256
    m, k = aq.shape
    k2, n = bq.shape
    assert k == k2, (aq.shape, bq.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    out_dtype = jnp.int32 if mode == "int8" else jnp.float32

    kernel = functools.partial(
        _sc_matmul_kernel, nk=nk, mode=mode, readout_bits=readout_bits,
        rbar=rbar,
    )
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(aq, bq)
