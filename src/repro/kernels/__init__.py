"""Pallas TPU kernels for ARTEMIS compute hot spots.

sc_matmul         the stochastic-analog MAC pipeline (paper SIII.A)
flash_attention   LSE online-softmax attention (paper Eq. 5 + SIII.D.3)
paged_attention   fused block-table-walking attention for the paged
                  serving stack (no gathered KV view; SIII.C.2)

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
validated on CPU with interpret=True against pure-jnp oracles (ref.py).
"""
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref)
from repro.kernels.sc_matmul import sc_matmul, sc_matmul_ref

__all__ = [
    "sc_matmul",
    "sc_matmul_ref",
    "flash_attention",
    "attention_ref",
    "paged_attention",
    "paged_attention_ref",
]
