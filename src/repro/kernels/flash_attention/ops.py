"""jit'd public wrapper for the flash-attention kernel.

Pads sequence lengths to block multiples (padding keys are masked off via
the causal structure or an explicit -inf length mask), restores shapes, and
picks interpret mode off the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_kernel,
)


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    return_lse: bool = False,
):
    """Fused LSE attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D)."""
    if interpret is None:
        interpret = _interpret_default()
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    bq_eff = min(bq, max(8, sq)) if sq < bq else bq
    bk_eff = min(bk, max(8, sk)) if sk < bk else bk
    pad_q = (-sq) % bq_eff
    pad_k = (-sk) % bk_eff
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded keys sit at the END of the sequence; with causal attention
        # real queries never see them. For non-causal, push them to -inf by
        # padding k with a huge negative magnitude on one channel instead —
        # simpler and exact: pad v with zeros and k with zeros, then rely on
        # an explicit mask baked into the scores via a length-mask pass.
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    if pad_k and not causal:
        raise NotImplementedError(
            "non-causal flash path requires Sk % bk == 0 (got "
            f"Sk={sk}, bk={bk_eff}) — pass a smaller bk")
    o, lse = flash_attention_kernel(
        q, k, v, causal=causal, scale=scale, bq=bq_eff, bk=bk_eff,
        interpret=interpret,
    )
    o = o[:, :, :sq]
    lse = lse[:, :, :sq]
    if return_lse:
        return o, lse
    return o
