"""jit'd public wrapper for the flash-attention kernel.

Pads sequence lengths to block multiples, restores shapes, and picks
interpret mode off the backend (`_interpret_default`, shared with the
kernel module and the paged kernel).  Padded keys sit at the END of the
sequence and are masked exactly: under a causal mask real queries never
see them, and otherwise the kernel's explicit `kv_len` mask pins their
scores to -inf — so neither Sq nor Sk needs to be a block multiple.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    _interpret_default,
    flash_attention_kernel,
)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
    return_lse: bool = False,
):
    """Fused LSE attention. q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D)."""
    if interpret is None:
        interpret = _interpret_default()
    # validate BEFORE any padding mutates the operands
    if window is not None and not causal:
        raise ValueError("window masking requires causal=True")
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    bq_eff = min(bq, max(8, sq)) if sq < bq else bq
    bk_eff = min(bk, max(8, sk)) if sk < bk else bk
    pad_q = (-sq) % bq_eff
    pad_k = (-sk) % bk_eff
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    o, lse = flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        kv_len=sk if pad_k else None, scale=scale,
        bq=bq_eff, bk=bk_eff, interpret=interpret,
    )
    o = o[:, :, :sq]
    lse = lse[:, :, :sq]
    if return_lse:
        return o, lse
    return o
