"""Pure-jnp oracle for the flash-attention kernel (GQA + causal + LSE)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (o, lse).

    `window` (causal only) keeps keys in (pos - window, pos] per query,
    where query row r sits at absolute position r + (Sk - Sq) — the
    same sliding-window semantics as the kernel and `_attn_core`."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if window is not None and not causal:
        raise ValueError("window masking requires causal=True")
    if scale is None:
        scale = 1.0 / (d**0.5)
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * scale
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        if window is not None:
            pos = jnp.arange(sq)[:, None] + (sk - sq)
            mask &= jnp.arange(sk)[None, :] > pos - window
        s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / l, vf)
    lse = (m + jnp.log(l))[..., 0]
    return o, lse
