"""Pallas TPU flash-attention kernel with the ARTEMIS LSE softmax.

Paper §III.C.2 + §III.D.3: ARTEMIS computes softmax in the division-free
log-sum-exp form (Eq. 5) and tracks y_max *online* with a comparator while
the QK^T MatMul streams out of the subarrays, overlapping softmax with the
S*V MatMul.  On TPU the idiomatic realization of exactly that dataflow is a
fused attention kernel with an online-softmax K/V stream — this kernel.

Features: causal masking, GQA/MQA (q-head -> kv-head folding via the
BlockSpec index map), and an LSE output per query — the LSE is what makes
the token-dataflow distributed merges (ring attention, split-KV decode)
exact, because Eq. 5 is associative across shards.

Grid: (batch, q_heads, Sq/bq, Sk/bk), K innermost; the output and the
(m, l) running statistics are revisited blocks accumulated across the K
axis.  m/l are carried in f32 output refs of shape (..., bq) — lane-dim
aligned.  Finalization (o /= l, lse = m + log l) happens at the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, nk: int, bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)

        m_prev = m_ref[0, 0]                          # (bq,)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[0, 0] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[0, 0] = m_new
        o_ref[0, 0] = (o_ref[0, 0] * alpha[:, None]
                       + jax.lax.dot_general(
                           p, v, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32))

    if causal:
        # skip fully-masked K blocks (the block is strictly above the
        # diagonal) — the TPU grid still visits them, but no FLOPs issue
        pl.when(ki * bk <= qi * bq + bq - 1)(_update)
    else:
        _update()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0, 0], 1e-30)
        o_ref[0, 0] = o_ref[0, 0] / l[:, None]
        lse_ref[0, 0] = m_ref[0, 0] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "bq", "bk", "interpret"),
)
def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.

    Returns (o: (B, Hq, Sq, D) f32, lse: (B, Hq, Sq) f32).
    Sq/Sk must be multiples of bq/bk (ops.py pads).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nq, nk = sq // bq, sk // bk
    if scale is None:
        scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, nk=nk, bq=bq, bk=bk,
    )
    o, lse, _, _ = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),  # m (scratch-ish)
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),  # l (scratch-ish)
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse
