"""Pallas TPU flash-attention kernel with the ARTEMIS LSE softmax.

Paper §III.C.2 + §III.D.3: ARTEMIS computes softmax in the division-free
log-sum-exp form (Eq. 5) and tracks y_max *online* with a comparator while
the QK^T MatMul streams out of the subarrays, overlapping softmax with the
S*V MatMul.  On TPU the idiomatic realization of exactly that dataflow is a
fused attention kernel with an online-softmax K/V stream — this kernel.

Features: causal masking, sliding-window masking (a query at row r keeps
keys in (r - window, r], matching `serve.paged_model._attn_core`), an
explicit key-length mask so the wrapper can pad Sk to a block multiple
without changing non-causal results, GQA/MQA (q-head -> kv-head folding
via the BlockSpec index map), and an LSE output per query — the LSE is
what makes the token-dataflow distributed merges (ring attention,
split-KV decode) exact, because Eq. 5 is associative across shards.

Grid: (batch, q_heads, Sq/bq, Sk/bk), K innermost; the output and the
(m, l) running statistics are revisited blocks accumulated across the K
axis.  m/l are carried in f32 output refs of shape (..., bq) — lane-dim
aligned.  Finalization (o /= l, lse = m + log l) happens at the last K step.

Block skipping: under a causal mask, K blocks strictly above the
diagonal are skipped; with a sliding window, K blocks that fall entirely
below every query row's window are skipped too, and blocks entirely past
the key-length mask never run.  The `nvis` output counts the K blocks
that actually executed per (batch, head, q-row) — the interpret-mode
tests read it to assert skipped blocks issue no FLOPs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _interpret_default() -> bool:
    """Single source of truth for Pallas interpret-mode resolution:
    compiled Mosaic on TPU, the interpreter everywhere else.  Shared by
    `flash_attention_kernel`, `ops.flash_attention`, and the paged
    kernel (`kernels.paged_attention`)."""
    return jax.default_backend() != "tpu"


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                  nvis_ref, *, scale: float, causal: bool,
                  window: int | None, kv_len: int | None,
                  nk: int, bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        nvis_ref[...] = jnp.zeros_like(nvis_ref)

    def _update():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        if causal or kv_len is not None:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            keep = jnp.full((bq, bk), True)
            if causal:
                keep &= rows >= cols
                if window is not None:
                    keep &= cols > rows - window
            if kv_len is not None:
                keep &= cols < kv_len
            s = jnp.where(keep, s, NEG_INF)

        m_prev = m_ref[0, 0]                          # (bq,)
        l_prev = l_ref[0, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[0, 0] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[0, 0] = m_new
        nvis_ref[0, 0] = nvis_ref[0, 0] + 1.0
        o_ref[0, 0] = (o_ref[0, 0] * alpha[:, None]
                       + jax.lax.dot_general(
                           p, v, (((1,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32))

    # two-sided block skip: drop K blocks that are fully masked for the
    # whole q block — strictly above the diagonal (causal), entirely
    # below every row's sliding window (a block is below row r's window
    # iff its last col <= r - window; fully below ALL rows iff that
    # holds for the block's FIRST row qi*bq), or entirely past the
    # valid key length.  The TPU grid still visits them, but no FLOPs
    # issue — the nvis counter output is the proof the tests pin.
    visit = None
    if causal:
        visit = ki * bk <= qi * bq + bq - 1
        if window is not None:
            visit &= ki * bk + bk - 1 > qi * bq - window
    if kv_len is not None and kv_len < nk * bk:
        below_len = ki * bk < kv_len
        visit = below_len if visit is None else visit & below_len
    if visit is None:
        _update()
    else:
        pl.when(visit)(_update)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[0, 0], 1e-30)
        o_ref[0, 0] = o_ref[0, 0] / l[:, None]
        lse_ref[0, 0] = m_ref[0, 0] + jnp.log(l)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "kv_len", "scale", "bq", "bk",
                     "interpret"),
)
def _flash_attention_all(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Kernel entry returning every output: (o, lse, nvis) where nvis
    counts the K blocks that executed per (b, h, q-row) — see
    `flash_attention_block_counts`."""
    if interpret is None:
        interpret = _interpret_default()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    nq, nk = sq // bq, sk // bk
    if window is not None and not causal:
        raise ValueError("window masking requires causal=True")
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if scale is None:
        scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        kv_len=kv_len, nk=nk, bq=bq, bk=bk,
    )
    o, lse, _, _, nvis = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
            pl.BlockSpec((1, 1, bq), lambda bi, hi, qi, ki: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),  # m (scratch-ish)
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),  # l (scratch-ish)
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),  # visited K blocks
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse, nvis


def flash_attention_kernel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D), Hq % Hkv == 0.

    Returns (o: (B, Hq, Sq, D) f32, lse: (B, Hq, Sq) f32).
    Sq/Sk must be multiples of bq/bk (ops.py pads; `kv_len` masks keys
    at positions >= kv_len so padded Sk stays exact for non-causal).
    `window` keeps keys in (row - window, row] per query row (causal
    only).  `interpret=None` resolves via `_interpret_default()`:
    compiled on TPU, interpreted elsewhere.
    """
    o, lse, _ = _flash_attention_all(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        scale=scale, bq=bq, bk=bk, interpret=interpret)
    return o, lse


def flash_attention_block_counts(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_len: int | None = None,
    scale: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Number of K blocks that actually executed per (B, Hq, Sq) row —
    every row of a q block shares one count.  The block-skip tests pin
    this against the analytic visit set to prove fully-masked blocks
    issue no FLOPs."""
    _, _, nvis = _flash_attention_all(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        scale=scale, bq=bq, bk=bk, interpret=interpret)
    return nvis
