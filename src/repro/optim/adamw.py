"""AdamW + cosine schedule + global-norm clipping, from scratch.

Written as pure functions over pytrees so the optimizer state inherits the
parameters' PartitionSpecs verbatim (repro.parallel.sharding maps param
specs onto (m, v) 1:1 — the optimizer is sharding-transparent).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_init(params):
    def zeros(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _is_matrix(p: jax.Array) -> bool:
    return p.ndim >= 2


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                     state["v"], grads)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1**t
    bc2 = 1.0 - b2**t

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + cfg.eps)
        if _is_matrix(p) and cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    new_state = {"m": m, "v": v, "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm,
               "param_norm": global_norm(new_params)}
    return new_params, new_state, metrics
