from repro.optim.adamw import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    global_norm,
)

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm"]
