"""gemma-2b [dense] — 18L d_model=2048 8H (GQA kv=1, i.e. MQA)
d_ff=16384 vocab=256000, GeGLU, head_dim=256 [arXiv:2403.08295; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    glu=True,                 # GeGLU
    tie_embeddings=True,      # gemma ties the LM head to the embedding
    scale_embeddings=True,    # embed * sqrt(d_model)
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    act="gelu",
    glu=True,
    tie_embeddings=True,
    scale_embeddings=True,
    vocab_round_to=16,
)
