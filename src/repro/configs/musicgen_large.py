"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32, i.e. MHA)
d_ff=8192 vocab=2048 — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

EnCodec frontend is stubbed (brief): the backbone consumes 4 parallel
codebook token streams (B, S, 4); codebook embeddings are summed, and the
head predicts all 4 codebooks (delay-pattern bookkeeping is a data-layer
concern, not a backbone one).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-large",
    family="dense",
    modality="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    act="gelu",
    glu=False,                # plain 2-layer MLP (T5/BART-style)
    n_codebooks=4,
    vocab_round_to=128,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke",
    family="dense",
    modality="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    act="gelu",
    glu=False,
    n_codebooks=4,
    vocab_round_to=16,
)
