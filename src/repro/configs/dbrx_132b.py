"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    act="silu",
    glu=True,
    n_experts=16,
    top_k=4,
    d_ff_expert=10752,
    expert_round_to=16,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="silu",
    glu=True,
    n_experts=4,
    top_k=2,
    d_ff_expert=128,
    expert_round_to=4,
    # generous capacity so smoke prefill/decode consistency is exact
    # (capacity drops are a batch-statistics behavior, exercised at the
    # FULL config's 1.25 in the dry-run, not in unit tests)
    capacity_factor=8.0,
    vocab_round_to=16,
)
