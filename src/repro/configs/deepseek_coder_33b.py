"""deepseek-coder-33b [dense] — 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256, llama-arch [arXiv:2401.14196; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    act="silu",
    glu=True,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=160,
    vocab_size=256,
    act="silu",
    glu=True,
    vocab_round_to=16,
)
