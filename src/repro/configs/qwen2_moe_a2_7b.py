"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) d_ff=1408,
MoE 60 routed experts top-4 + 4 shared [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

60 routed experts are padded to 64 (expert_round_to=16) so the expert
axis divides the model-parallel degree; the 4 pad experts are masked in
the router (zero routing mass) — repro.models.moe.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    act="silu",
    glu=True,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_ff_expert=1408,
    expert_round_to=16,      # 60 -> 64
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    act="silu",
    glu=True,
    n_experts=6,
    top_k=2,
    n_shared_experts=2,
    d_ff_expert=96,
    expert_round_to=4,       # 6 -> 8
    # generous capacity so smoke prefill/decode consistency is exact
    capacity_factor=8.0,
    vocab_round_to=16,
)
