"""Config registry: 10 assigned architectures + 5 paper workloads.

Each `<arch>.py` exports:
  FULL   — the exact assigned configuration (ModelConfig)
  SMOKE  — a reduced same-family config for CPU tests (few layers, narrow)

`SHAPES` defines the per-arch input-shape cells (brief: train_4k,
prefill_32k, decode_32k, long_500k). `cells(arch)` yields the runnable
(arch, shape) pairs — long_500k only for sub-quadratic archs, per
DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "qwen3_14b",
    "deepseek_coder_33b",
    "qwen3_8b",
    "gemma_2b",
    "internvl2_1b",
    "musicgen_large",
    "zamba2_7b",
    "rwkv6_3b",
    "dbrx_132b",
    "qwen2_moe_a2_7b",
)

# paper Table II workloads (for the hwsim benchmarks)
PAPER_WORKLOADS = ("transformer_base", "bert_base", "albert_base",
                   "vit_base", "opt_350")

# archs with a sub-quadratic long-context path (run long_500k)
SUBQUADRATIC = ("zamba2_7b", "rwkv6_3b")


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.SMOKE if smoke else mod.FULL


def list_archs() -> tuple[str, ...]:
    return ARCHS


def runnable_shapes(arch: str) -> tuple[str, ...]:
    """Shape cells that lower for this arch (others are documented skips)."""
    arch = canon(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        out.append("long_500k")
    return tuple(out)


def all_cells() -> list[tuple[str, str, str]]:
    """All 40 (arch, shape, status) cells; status 'run' or 'skip'."""
    cells = []
    for a in ARCHS:
        run = set(runnable_shapes(a))
        for s in SHAPES:
            cells.append((a, s, "run" if s in run else "skip"))
    return cells
