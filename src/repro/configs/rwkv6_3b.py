"""rwkv6-3b [ssm] — 32L d_model=2560 (attn-free) d_ff=8960 vocab=65536
— Finch, data-dependent decay [arXiv:2404.05892; hf]."""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="rwkv6-3b",
    family="rwkv6",
    n_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    ssm_head_dim=64,          # 40 wkv heads
    chunk_size=128,
    act="relu2",
    glu=False,
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke",
    family="rwkv6",
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=256,
    ssm_head_dim=32,
    chunk_size=16,
    act="relu2",
    glu=False,
    vocab_round_to=16,
)
