"""Paper Table II transformer workloads (ARTEMIS' own evaluation set).

These drive the hwsim benchmarks (Figs 2, 8-12) and — in reduced form —
the Table IV accuracy ladder. N is the paper's input token count.
"""
from repro.models.config import ModelConfig


def _enc(name, layers, n, heads, d_model, d_ff, vocab=30522, params=0):
    return ModelConfig(
        name=name,
        family="dense",
        n_layers=layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=heads,
        d_ff=d_ff,
        vocab_size=vocab,
        act="gelu",
        glu=False,
        vocab_round_to=2,
    )


# name -> (config, N tokens, params as reported)
TABLE_II = {
    "transformer_base": (_enc("transformer-base", 2, 128, 8, 512, 2048,
                              37000), 128, 52e6),
    "bert_base": (_enc("bert-base", 12, 128, 12, 768, 3072), 128, 108e6),
    "albert_base": (_enc("albert-base", 12, 128, 12, 768, 3072), 128, 12e6),
    "vit_base": (_enc("vit-base", 12, 256, 12, 768, 3072, 1000), 256, 86e6),
    "opt_350": (_enc("opt-350", 12, 2048, 12, 768, 3072, 50272), 2048,
                350e6),
}


def get_workload(name: str):
    cfg, n_tokens, params = TABLE_II[name]
    return cfg, n_tokens, params
