"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + Qwen2-0.5B LM backbone [arXiv:2404.16821; hf].

Modality frontend (InternViT-300M + pixel-shuffle + MLP projector) is a
STUB per the brief: `input_specs()` provides 256 precomputed patch
embeddings as `prefix_embeds` (repro.models.frontend).
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="internvl2-1b",
    family="dense",
    modality="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    rope_theta=1_000_000.0,
    act="silu",
    glu=True,
    tie_embeddings=True,      # Qwen2-0.5B ties embeddings
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke",
    family="dense",
    modality="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="silu",
    glu=True,
    tie_embeddings=True,
    vocab_round_to=16,
)
