"""zamba2-7b [hybrid] — 81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
[arXiv:2411.15242; unverified].

The shared transformer block is applied every 6 Mamba2 layers (13
invocations over 81 layers, 3-layer tail), weights reused across
invocations — the Zamba2 parameter-sharing scheme. Sliding-window
attention (4096) bounds the shared block's KV for the long_500k cell.
"""
from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b",
    family="zamba2",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    chunk_size=128,
    shared_attn_period=6,
    attn_window=4096,
    act="gelu",
    glu=True,
)

SMOKE = ModelConfig(
    name="zamba2-7b-smoke",
    family="zamba2",
    n_layers=5,               # 2 invocations of the shared block + tail
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=32,
    ssm_expand=2,
    conv_width=4,
    chunk_size=16,
    shared_attn_period=2,
    attn_window=32,
    act="gelu",
    glu=True,
    vocab_round_to=16,
)
