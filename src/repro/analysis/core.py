"""Rule framework: base class, registry, and the analysis driver.

A rule is a stateless object with an `id`, a `description`, a path
`applies()` filter, and a `check(file, project)` that yields
`Finding`s. Rules register themselves at import time via `@register`
(importing `repro.analysis.rules` loads the whole set), so the CLI and
the tests always agree on what the rule set is.

`analyze_project` runs every applicable rule over every parsed file,
honors `# repro: allow[rule-id]` suppressions, and reports files that
failed to parse as `parse-error` findings instead of crashing — broken
source must fail the CI gate, not the analyzer.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

PARSE_ERROR_RULE = "parse-error"


class Rule:
    """One statically-checked contract. Subclasses set `id` and
    `description`, narrow `applies` to the paths the contract governs,
    and implement `check`."""

    id: str = ""
    description: str = ""

    def applies(self, f: FileInfo) -> bool:
        return True

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------

    def finding(self, f: FileInfo, node, message: str) -> Finding:
        return Finding(path=f.path, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), rule=self.id,
                       message=message)


RULES: dict[str, Rule] = {}


def register(rule_cls: type[Rule]) -> type[Rule]:
    if not rule_cls.id:
        raise ValueError(f"{rule_cls.__name__} has no id")
    if rule_cls.id in RULES:
        raise ValueError(f"duplicate rule id {rule_cls.id!r}")
    RULES[rule_cls.id] = rule_cls()
    return rule_cls


def all_rules() -> list[Rule]:
    """The registered rule set (importing the rules package as a side
    effect, so callers never see a half-loaded registry)."""
    import repro.analysis.rules  # noqa: F401  (registration side effect)
    return [RULES[k] for k in sorted(RULES)]


# -- path scopes shared by several rules -------------------------------------


def in_serve(path: str) -> bool:
    """Under the serve layer (`repro/serve/` wherever it is rooted)."""
    return "repro/serve/" in path


def is_backend_module(path: str) -> bool:
    """A serve backend module — only the `backend/` registry namespace
    is allowed there (the PR 6 constraint)."""
    name = path.rsplit("/", 1)[-1]
    return in_serve(path) and name.startswith("backend")


# Files where wall-clock use is governed: the serve layer itself plus
# the serve-facing launchers/benchmarks that drive it (bench timing is
# the one legitimate use there, annotated with explicit suppressions).
_WALL_CLOCK_EXTRA = ("benchmarks/serve_throughput.py", "benchmarks/run.py",
                     "repro/launch/serve.py")


def in_virtual_clock_scope(path: str) -> bool:
    return (in_serve(path)
            or any(path.endswith(p) for p in _WALL_CLOCK_EXTRA))


# -- driver ------------------------------------------------------------------


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]        # unsuppressed, sorted
    suppressed: list[Finding]      # matched a `# repro: allow[...]`
    n_files: int = 0


def analyze_project(project: Project,
                    rules: list[Rule] | None = None) -> AnalysisResult:
    rules = rules if rules is not None else all_rules()
    findings: list[Finding] = []
    suppressed: list[Finding] = []
    for f in project.files.values():
        if f.tree is None:
            findings.append(Finding(
                path=f.path, line=1, col=0, rule=PARSE_ERROR_RULE,
                message=f"file does not parse: {f.parse_error}"))
            continue
        for rule in rules:
            if not rule.applies(f):
                continue
            for fd in rule.check(f, project):
                ids = f.suppressions.get(fd.line, set())
                if fd.rule in ids or "*" in ids:
                    suppressed.append(fd)
                else:
                    findings.append(fd)
    return AnalysisResult(findings=sorted(findings),
                          suppressed=sorted(suppressed),
                          n_files=len(project.files))
