"""SARIF 2.1.0 rendering — the interchange format GitHub code
scanning ingests, so analyzer findings annotate PR diffs instead of
living in a CI log. New findings are `error` (they fail the gate);
baselined ones are `note` (grandfathered, visible but not failing).
Stdlib-only like the rest of the package.
"""
from __future__ import annotations

import json

SARIF_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/"
                "sarif-spec/master/Schemata/sarif-schema-2.1.0.json")
SARIF_VERSION = "2.1.0"
TOOL_NAME = "repro.analysis"


def _result(finding, rule_index: dict[str, int], level: str) -> dict:
    return {
        "ruleId": finding.rule,
        "ruleIndex": rule_index[finding.rule],
        "level": level,
        "message": {"text": finding.message},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {
                    "uri": finding.path,
                    "uriBaseId": "%SRCROOT%",
                },
                "region": {
                    "startLine": finding.line,
                    "startColumn": finding.col + 1,
                },
            },
        }],
    }


def render_sarif(new, baselined, rules) -> str:
    """One SARIF run over the analyzed tree. `rules` drives the
    driver's rule table; results reference it by index."""
    rule_index = {r.id: i for i, r in enumerate(rules)}
    results = ([_result(f, rule_index, "error") for f in new]
               + [_result(f, rule_index, "note") for f in baselined])
    return json.dumps({
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {
                "driver": {
                    "name": TOOL_NAME,
                    "rules": [{
                        "id": r.id,
                        "shortDescription": {"text": r.description},
                        "defaultConfiguration": {"level": "error"},
                    } for r in rules],
                },
            },
            "columnKind": "utf16CodeUnits",
            "results": results,
        }],
    }, indent=2)
