"""Forward dataflow over per-function CFGs, plus the shared
cross-function indexes the dataflow rules plug into.

Layers (bottom up):

  * a generic worklist solver for MAY forward analyses: states are
    frozensets of abstract facts, join is set union, each rule supplies
    a `transfer(state, atom)` — `solve` returns per-block in-states and
    `atom_states` replays them per atom so rules can attach findings to
    exact lines;
  * the taint lattice `TaintAnalysis`: which local names may hold
    parameter-derived (traced) values, flow-sensitively — a rebind from
    a static expression (`x = y.shape[0]`) KILLS the taint that the old
    flow-insensitive fixpoint in host_sync kept forever;
  * the function index + interprocedural call graph grown from the
    project's jit surface (moved here from rules/host_sync.py so every
    rule can ask "is this function jit-reachable, and via which root");
  * the donation index: which callables donate which positional
    arguments (`donate_argnums`), resolved through decorators, local
    `jax.jit(...)` bindings, donating factories (functions returning
    jitted steps — the serve idiom), and instance attributes bound from
    factory results (`self._prefill_fn, self._decode_fn = self._steps(p)`).

Everything here is stdlib-`ast` only, like the rest of the package.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.cfg import (CFG, SCOPE_BOUNDARY, atom_bindings,
                                shallow_walk)
from repro.analysis.project import FileInfo, Project

FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


def scope_walk(stmts):
    """Walk statements (descending into compound statements and their
    expressions) without ever crossing a function/class/lambda
    boundary — the whole-body view of one scope."""
    stack = list(stmts)
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, SCOPE_BOUNDARY):
            continue
        stack.extend(ast.iter_child_nodes(n))


def chain_str(node: ast.AST) -> str | None:
    """`self.cache.kv` -> "self.cache.kv"; None when the expression is
    not a plain Name/Attribute chain. Unlike `FileInfo.dotted`, no
    alias resolution: these strings name VALUES in a function body."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def flat_names(target: ast.AST, acc: set[str]) -> None:
    """Bare names bound by an assignment target (tuple/list/starred
    unpacking included; attribute/subscript targets bind no name)."""
    if isinstance(target, ast.Name):
        acc.add(target.id)
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            flat_names(e, acc)
    elif isinstance(target, ast.Starred):
        flat_names(target.value, acc)


# -- generic forward solver ---------------------------------------------------


class ForwardAnalysis:
    """A MAY forward analysis: state = frozenset of facts, join = union.
    Subclasses override `entry_state` and `transfer`."""

    def entry_state(self) -> frozenset:
        return frozenset()

    def transfer(self, state: frozenset, atom: ast.AST) -> frozenset:
        return state


def solve(cfg: CFG, analysis: ForwardAnalysis) -> dict[int, frozenset]:
    """Fixpoint in-states per block. Terminates because in-states only
    ever grow (union join) over a finite fact universe; blocks
    unreachable from entry keep the empty state."""
    in_states: dict[int, frozenset | None] = {b: None for b in cfg.blocks}
    in_states[cfg.entry] = analysis.entry_state()
    work = [cfg.entry]
    while work:
        bid = work.pop()
        state = in_states[bid]
        for atom in cfg.blocks[bid].atoms:
            state = analysis.transfer(state, atom)
        for s in cfg.blocks[bid].succs:
            prev = in_states[s]
            new = state if prev is None else prev | state
            if new != prev:
                in_states[s] = new
                work.append(s)
    return {b: (st if st is not None else frozenset())
            for b, st in in_states.items()}


def atom_states(cfg: CFG, analysis: ForwardAnalysis,
                in_states: dict[int, frozenset]):
    """Yield (atom, in-state-at-atom) for every atom in the CFG, in
    block order — the finding-collection pass, replaying `transfer`
    inside each block."""
    for bid, block in cfg.blocks.items():
        state = in_states[bid]
        for atom in block.atoms:
            yield atom, state
            state = analysis.transfer(state, atom)


def exit_states(cfg: CFG, analysis: ForwardAnalysis,
                in_states: dict[int, frozenset]
                ) -> tuple[frozenset, frozenset]:
    """(state at normal exit, state at uncaught-exception exit)."""
    return in_states[cfg.exit], in_states[cfg.raise_exit]


# -- taint lattice ------------------------------------------------------------

# attribute/call accesses that yield static Python values at trace time
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type"}


def expr_is_static(e: ast.AST) -> bool:
    """Expression is static at trace time despite touching traced
    names: `.shape[0]`, `len(x)`, `x.ndim`, ..."""
    for n in shallow_walk(e):
        if isinstance(n, ast.Attribute) and n.attr in STATIC_ATTRS:
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "len"):
            return True
    return False


def expr_tainted(e: ast.AST, state: frozenset) -> bool:
    return (not expr_is_static(e)
            and any(isinstance(n, ast.Name) and n.id in state
                    for n in shallow_walk(e)))


class TaintAnalysis(ForwardAnalysis):
    """Names that MAY hold parameter-derived (traced) values. Seeded
    from the function's non-static parameters; propagated through
    bindings; killed when a name is rebound from a static expression
    (flow-sensitive laundering)."""

    def __init__(self, params: set[str]):
        self.params = frozenset(params)

    def entry_state(self) -> frozenset:
        return self.params

    def transfer(self, state: frozenset, atom: ast.AST) -> frozenset:
        bindings = list(atom_bindings(atom))
        for n in shallow_walk(atom):
            if isinstance(n, ast.NamedExpr) and n is not atom:
                bindings.append(([n.target], n.value))
        for targets, value in bindings:
            names: set[str] = set()
            for t in targets:
                flat_names(t, names)
            if value is not None and expr_tainted(value, state):
                state = state | names
            elif not isinstance(atom, ast.AugAssign):
                # rebound from a static/untainted expression: laundered
                # (augmented assigns read the old value, so never kill)
                state = state - names
        return state


# -- function index + call graph ----------------------------------------------


@dataclasses.dataclass
class Func:
    path: str
    qual: str                      # e.g. "Class.method" / "factory.step"
    name: str
    node: ast.AST
    cls: str | None                # enclosing class name, if a method
    params: set[str]
    jit_decorated: bool = False
    donate_argnums: frozenset[int] | None = None
    returned_inner: set[str] = dataclasses.field(default_factory=set)
    reachable_via: str | None = None   # root qual once BFS marks it


# parameter annotations that mean "static python value at trace time":
# scalar builtins, and the repo's config/policy carrier types
_STATIC_SCALAR_TYPES = {"int", "float", "bool", "str", "bytes", "None"}


def annotation_is_static(ann: ast.AST | None) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant):
        # string annotations and bare None
        if isinstance(ann.value, str):
            return (ann.value in _STATIC_SCALAR_TYPES
                    or ann.value.endswith(("Config", "Policy")))
        return ann.value is None
    if isinstance(ann, (ast.Name, ast.Attribute)):
        name = ann.attr if isinstance(ann, ast.Attribute) else ann.id
        return (name in _STATIC_SCALAR_TYPES
                or name.endswith(("Config", "Policy")))
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (annotation_is_static(ann.left)
                and annotation_is_static(ann.right))
    if isinstance(ann, ast.Subscript):
        base = ann.value
        name = (base.attr if isinstance(base, ast.Attribute)
                else base.id if isinstance(base, ast.Name) else "")
        if name in ("Optional", "Union"):
            return annotation_is_static(ann.slice)
    if isinstance(ann, ast.Tuple):
        return all(annotation_is_static(e) for e in ann.elts)
    return False


def params_of(node) -> set[str]:
    """Parameter names that may carry TRACED values — parameters whose
    annotation pins them to a static python scalar or a config/policy
    object are excluded from taint."""
    a = node.args
    params = [p for p in a.posonlyargs + a.args + a.kwonlyargs]
    names = [p.arg for p in params
             if not annotation_is_static(p.annotation)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def donate_argnums_of(call: ast.Call) -> frozenset[int] | None:
    """Parse a `donate_argnums=` keyword off a jit call: a literal int
    or tuple of ints. Anything dynamic (an IfExp, a name) returns None
    — the call is conservatively treated as non-donating."""
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return frozenset({v.value})
        if isinstance(v, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) and isinstance(e.value, int)
                for e in v.elts):
            return frozenset(e.value for e in v.elts)
        return None
    return None


def jit_decorator_argnums(f: FileInfo, dec: ast.AST
                          ) -> tuple[bool, frozenset[int] | None]:
    """(is a jit decorator, donated positions if any). Covers bare
    `@jax.jit`, `@jax.jit(...)`, and `@functools.partial(jax.jit, ...)`."""
    if f.dotted(dec) == "jax.jit":
        return True, None
    if isinstance(dec, ast.Call):
        d = f.dotted(dec.func)
        if d == "jax.jit":
            return True, donate_argnums_of(dec)
        if d == "functools.partial" and dec.args \
                and f.dotted(dec.args[0]) == "jax.jit":
            return True, donate_argnums_of(dec)
    return False, None


def collect_functions(f: FileInfo) -> dict[str, Func]:
    funcs: dict[str, Func] = {}

    def scope(stmts, prefix: str, cls: str | None):
        for n in scope_walk(stmts):
            if isinstance(n, FN_NODES):
                qual = prefix + n.name
                fn = Func(path=f.path, qual=qual, name=n.name, node=n,
                          cls=cls, params=params_of(n))
                for d in n.decorator_list:
                    is_jit, donated = jit_decorator_argnums(f, d)
                    if is_jit:
                        fn.jit_decorated = True
                        if donated:
                            fn.donate_argnums = donated
                # inner defs this function returns (factory pattern)
                inner = {c.name for c in scope_walk(n.body)
                         if isinstance(c, FN_NODES)}
                for r in scope_walk(n.body):
                    if (isinstance(r, ast.Return)
                            and isinstance(r.value, ast.Name)
                            and r.value.id in inner):
                        fn.returned_inner.add(f"{qual}.{r.value.id}")
                funcs[qual] = fn
                scope(n.body, qual + ".", None)
            elif isinstance(n, ast.ClassDef):
                scope(n.body, prefix + n.name + ".", n.name)

    scope(f.tree.body, "", None)
    return funcs


# jax transforms whose function-valued arguments are traced as part of
# the caller: an edge to those functions keeps scan/vmap bodies inside
# the reachable set
TRANSFORMS = {
    "jax.vmap", "jax.pmap", "jax.checkpoint", "jax.remat", "jax.grad",
    "jax.value_and_grad", "functools.partial",
    "jax.lax.scan", "jax.lax.map", "jax.lax.cond", "jax.lax.switch",
    "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.associative_scan",
}


def resolve_callable(f: FileInfo, fn: Func, t: ast.AST, project: Project,
                     index: dict[tuple[str, str], Func]
                     ) -> tuple[str, str] | None:
    """Resolve a Name/Attribute reference inside `fn`'s body to a
    (path, qual) key of the project function index: nested functions of
    enclosing scopes (innermost first), same-file module functions,
    `self.method` within the class, imported names."""
    if isinstance(t, ast.Name):
        parts = fn.qual.split(".")
        for i in range(len(parts), 0, -1):
            cand = ".".join(parts[:i]) + "." + t.id
            if (f.path, cand) in index:
                return (f.path, cand)
        if (f.path, t.id) in index:
            return (f.path, t.id)
        dotted = f.aliases.get(t.id)
        if dotted and "." in dotted:
            mod, name = dotted.rsplit(".", 1)
            for path2, fi in project.files.items():
                if fi.module == mod and (path2, name) in index:
                    return (path2, name)
    elif isinstance(t, ast.Attribute):
        if (isinstance(t.value, ast.Name) and t.value.id == "self"
                and fn.cls is not None):
            cand = f"{fn.cls}.{t.attr}"
            if (f.path, cand) in index:
                return (f.path, cand)
        dotted = f.dotted(t)
        if dotted and "." in dotted:
            mod, name = dotted.rsplit(".", 1)
            for path2, fi in project.files.items():
                if fi.module == mod and (path2, name) in index:
                    return (path2, name)
    return None


def call_edges(f: FileInfo, fn: Func, project: Project,
               index: dict[tuple[str, str], Func]
               ) -> list[tuple[str, str]]:
    """Resolved (path, qual) targets of plain-name calls in fn's own
    body (nested defs excluded — they are graph nodes of their own),
    plus function-valued arguments handed to jax transforms."""
    out: list[tuple[str, str]] = []
    for n in scope_walk(fn.node.body):
        if not isinstance(n, ast.Call):
            continue
        tgt = resolve_callable(f, fn, n.func, project, index)
        if tgt is not None:
            out.append(tgt)
        if f.dotted(n.func) in TRANSFORMS:
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    tgt = resolve_callable(f, fn, arg, project, index)
                    if tgt is not None:
                        out.append(tgt)
    return out


class CallGraph:
    """Project function index + jit reachability. `functions` maps
    (path, qual) -> Func; a Func with `reachable_via` set is reachable
    from the jit surface, and the value names the root it was reached
    from (for finding messages)."""

    def __init__(self, functions: dict[tuple[str, str], Func]):
        self.functions = functions

    @classmethod
    def build(cls, project: Project) -> "CallGraph":
        index: dict[tuple[str, str], Func] = {}
        for f in project.files.values():
            if f.tree is None:
                continue
            for qual, fn in collect_functions(f).items():
                index[(f.path, qual)] = fn

        surface = project.jit_surface
        boundary = surface["wrapped"] | surface["kernels"]
        roots: list[tuple[str, str]] = []
        for key, fn in index.items():
            module = project.files[fn.path].module
            # wrapped/kernel matches are module-exact and module-level
            # only; method refs (`jax.jit(self._m)` and partials over
            # them) match by bare method name on classed functions — a
            # documented over-approximation, since `self` at the jit
            # site cannot be resolved to one class statically
            if fn.jit_decorated or ("." not in fn.qual
                                    and (module, fn.name) in boundary):
                roots.append(key)
            elif fn.cls is not None and fn.name in surface["methods"]:
                roots.append(key)
            elif fn.name in surface["factories"]:
                for inner in fn.returned_inner:
                    if (fn.path, inner) in index:
                        roots.append((fn.path, inner))

        edges = {key: call_edges(project.files[key[0]], fn, project,
                                 index)
                 for key, fn in index.items()}
        todo = []
        for key in roots:
            if index[key].reachable_via is None:
                index[key].reachable_via = index[key].qual
                todo.append(key)
        while todo:
            key = todo.pop()
            via = index[key].reachable_via
            for tgt in edges[key]:
                if index[tgt].reachable_via is None:
                    index[tgt].reachable_via = via
                    todo.append(tgt)
        return cls(index)


def call_graph(project: Project) -> CallGraph:
    cached = getattr(project, "_call_graph", None)
    if cached is None:
        cached = CallGraph.build(project)
        project._call_graph = cached
    return cached


# -- donation index -----------------------------------------------------------


@dataclasses.dataclass
class DonationIndex:
    """Which callables donate which positional argument slots.

    functions — dotted "module.name" of module-level jitted defs
    attrs     — instance-attribute / method names (`self._prefill_fn`)
                bound from donating factories or jit calls, matched by
                bare attribute name project-wide (over-approximation)
    locals    — (path, name) for `x = jax.jit(f, donate_argnums=...)`
                or tuple-unpacks of factory calls into locals,
                file-scoped by name
    """

    functions: dict[str, frozenset[int]]
    attrs: dict[str, frozenset[int]]
    locals: dict[tuple[str, str], frozenset[int]]


def _is_jit_call(f: FileInfo, node: ast.AST) -> bool:
    return (isinstance(node, ast.Call)
            and f.dotted(node.func) == "jax.jit")


def _factory_returns(project: Project, graph: CallGraph
                     ) -> dict[tuple[str, str],
                               tuple[frozenset[int] | None, ...]]:
    """(path, qual) -> per-element donate_argnums for functions that
    return jitted callables: `return jax.jit(...), jax.jit(...)`,
    `return prefill, decode` over local jit bindings, or
    `return other_factory(...)` (resolved by fixpoint)."""
    direct: dict[tuple[str, str],
                 tuple[frozenset[int] | None, ...]] = {}
    deferred: dict[tuple[str, str], tuple[str, str]] = {}
    for key, fn in graph.functions.items():
        f = project.files[key[0]]
        # local `name = jax.jit(...)` bindings inside this function
        jit_locals: dict[str, frozenset[int] | None] = {}
        for n in scope_walk(fn.node.body):
            if isinstance(n, ast.Assign) and _is_jit_call(f, n.value):
                for t in n.targets:
                    if isinstance(t, ast.Name):
                        jit_locals[t.id] = donate_argnums_of(n.value)
        for r in scope_walk(fn.node.body):
            if not isinstance(r, ast.Return) or r.value is None:
                continue
            elems = (list(r.value.elts)
                     if isinstance(r.value, ast.Tuple) else [r.value])
            per_elem: list[frozenset[int] | None] = []
            known = False
            for e in elems:
                if _is_jit_call(f, e):
                    per_elem.append(donate_argnums_of(e))
                    known = True
                elif isinstance(e, ast.Name) and e.id in jit_locals:
                    per_elem.append(jit_locals[e.id])
                    known = True
                else:
                    per_elem.append(None)
            if known:
                direct[key] = tuple(per_elem)
            elif len(elems) == 1 and isinstance(elems[0], ast.Call):
                tgt = resolve_callable(f, fn, elems[0].func, project,
                                       graph.functions)
                if tgt is not None:
                    deferred[key] = tgt
    # fixpoint: `return other_factory(...)` chains (e.g. a backend's
    # `_steps` method delegating to the module-level step factory)
    for _ in range(len(deferred) + 1):
        changed = False
        for key, tgt in deferred.items():
            if key not in direct and tgt in direct:
                direct[key] = direct[tgt]
                changed = True
        if not changed:
            break
    return direct


def _build_donation_index(project: Project) -> DonationIndex:
    graph = call_graph(project)
    functions: dict[str, frozenset[int]] = {}
    attrs: dict[str, frozenset[int]] = {}
    locals_: dict[tuple[str, str], frozenset[int]] = {}

    for key, fn in graph.functions.items():
        if fn.donate_argnums:
            module = project.files[fn.path].module
            if fn.cls is not None:
                attrs[fn.name] = fn.donate_argnums
            else:
                functions[f"{module}.{fn.qual}"] = fn.donate_argnums

    factory = _factory_returns(project, graph)

    for key, fn in graph.functions.items():
        f = project.files[key[0]]
        for n in scope_walk(fn.node.body):
            if not isinstance(n, ast.Assign):
                continue
            # direct jit binding: x = jax.jit(f, donate_argnums=...)
            if _is_jit_call(f, n.value):
                donated = donate_argnums_of(n.value)
                if donated:
                    for t in n.targets:
                        if isinstance(t, ast.Name):
                            locals_[(f.path, t.id)] = donated
                        elif (isinstance(t, ast.Attribute)
                              and isinstance(t.value, ast.Name)
                              and t.value.id == "self"):
                            attrs[t.attr] = donated
                continue
            # factory-product binding: a, b = make_steps(...)  /
            # self._p, self._d = self._steps(policy)
            if not isinstance(n.value, ast.Call):
                continue
            tgt = resolve_callable(f, fn, n.value.func, project,
                                   graph.functions)
            per_elem = factory.get(tgt) if tgt is not None else None
            if per_elem is None:
                continue
            for t in n.targets:
                elts = (list(t.elts)
                        if isinstance(t, (ast.Tuple, ast.List))
                        else [t])
                if len(elts) != len(per_elem):
                    continue
                for e, donated in zip(elts, per_elem):
                    if not donated:
                        continue
                    if isinstance(e, ast.Name):
                        locals_[(f.path, e.id)] = donated
                    elif (isinstance(e, ast.Attribute)
                          and isinstance(e.value, ast.Name)
                          and e.value.id == "self"):
                        attrs[e.attr] = donated
    return DonationIndex(functions=functions, attrs=attrs,
                         locals=locals_)


def donation_index(project: Project) -> DonationIndex:
    cached = getattr(project, "_donation_index", None)
    if cached is None:
        cached = _build_donation_index(project)
        project._donation_index = cached
    return cached


def donated_positions(f: FileInfo, call: ast.Call, idx: DonationIndex
                      ) -> frozenset[int] | None:
    """Donated positional slots of a call site, or None when the
    callee is not a known donating callable."""
    func = call.func
    if isinstance(func, ast.Name):
        key = (f.path, func.id)
        if key in idx.locals:
            return idx.locals[key]
        dotted = f.dotted(func)
        if dotted is not None:
            if "." not in dotted:
                dotted = f"{f.module}.{dotted}"
            if dotted in idx.functions:
                return idx.functions[dotted]
    elif isinstance(func, ast.Attribute):
        if (isinstance(func.value, ast.Name) and func.value.id == "self"
                and func.attr in idx.attrs):
            return idx.attrs[func.attr]
        dotted = f.dotted(func)
        if dotted is not None and dotted in idx.functions:
            return idx.functions[dotted]
    return None
