"""Static contract checker for the serve layer.

The serving stack's correctness rests on contracts that runtime tests
exercise only on executed paths: the virtual ARTEMIS clock (no wall
clock in serve code), the PR 5 RNG-lane discipline (keys derive from
`(seed, tokens_generated)` and nothing else), the compile-once jit
design (no retraces, no host syncs inside traced code), the metrics
registry namespaces, and the `SequenceBackend` protocol. This package
checks them at the SOURCE level with a small AST rule framework:

    python -m repro.analysis src tests benchmarks [--format json]

Suppress an intentional violation at the call site with
`# repro: allow[rule-id]` (same line, or a comment line directly
above); grandfathered findings live in the committed, audited
`analysis-baseline.json`. See the README "Static analysis" section
for how to add a rule.

Stdlib-only on purpose: the checker never imports the code it
analyzes, so the CI gate needs no jax install and cannot be broken by
the very bug it is trying to catch.
"""
from repro.analysis.baseline import Baseline
from repro.analysis.core import (
    AnalysisResult,
    Rule,
    all_rules,
    analyze_project,
    register,
)
from repro.analysis.findings import Finding
from repro.analysis.project import Project

__all__ = [
    "AnalysisResult", "Baseline", "Finding", "Project", "Rule",
    "all_rules", "analyze_project", "register",
]
