"""The repo-specific rule set. Importing this package registers every
rule with `repro.analysis.core.RULES` (that is its only job — see each
module for the contract it enforces)."""
from repro.analysis.rules import (  # noqa: F401
    allocator_refcount,
    donation,
    host_sync,
    mesh_discipline,
    protocol,
    registry_ns,
    retrace,
    rng_discipline,
    shard_spec,
    wall_clock,
)
