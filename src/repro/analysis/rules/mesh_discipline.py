"""mesh-discipline: the mesh-seam contract from the tensor-parallel
serve PR. Device topology enters the repo as a VALUE (`ServeMesh`,
built once by `repro/serve/mesh.py`; the launch layer's production
meshes live in `repro/launch/mesh.py` under explicit suppressions) and
the collectives that consume it live under `repro/parallel/`. Any
other `repro/` module asking jax about devices — `jax.devices()`,
`jax.device_count()`, `jax.make_mesh(...)`, constructing a
`jax.sharding.Mesh` — reintroduces the implicit global topology the
seam exists to remove: code that silently behaves differently on a
different machine, untestable under a simulated mesh, and branchy in
layers (engine, scheduler) that must stay mesh-oblivious.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

BANNED = {
    "jax.devices": "device inventory query",
    "jax.local_devices": "device inventory query",
    "jax.device_count": "device count query",
    "jax.local_device_count": "device count query",
    "jax.make_mesh": "mesh construction",
    "jax.sharding.Mesh": "mesh construction",
    "jax.experimental.mesh_utils.create_device_mesh": "mesh construction",
}

# The two modules allowed to own topology: the serve seam and the
# parallel collectives layer it hands meshes to.
EXEMPT_SUFFIX = ("repro/serve/mesh.py",)
EXEMPT_DIR = "repro/parallel/"


def _governed(path: str) -> bool:
    if "repro/" not in path:
        return False
    sub = path.split("repro/", 1)[1]
    return not (("repro/" + sub).startswith(EXEMPT_DIR)
                or any(path.endswith(s) for s in EXEMPT_SUFFIX))


@register
class MeshDiscipline(Rule):
    id = "mesh-discipline"
    description = ("no jax.devices()/device_count()/make_mesh()/"
                   "Mesh(...) outside repro/serve/mesh.py and "
                   "repro/parallel/ — topology flows as a ServeMesh "
                   "value")

    def applies(self, f: FileInfo) -> bool:
        return _governed(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = f.dotted(node.func)
            if dotted in BANNED:
                out.append(self.finding(
                    f, node,
                    f"`{dotted}(...)` ({BANNED[dotted]}) outside the "
                    f"mesh seam — take a `ServeMesh` value (built by "
                    f"repro/serve/mesh.py) instead of asking jax about "
                    f"device topology"))
        return out
