"""wall-clock-in-serve: the serve layer runs on the VIRTUAL ARTEMIS
clock (every step advances `engine.now` by the hwsim-simulated latency
of its composed batch). A single `time.time()` or stdlib-`random` draw
in that layer silently decouples results from the cost model the paper
is about, so none of it is allowed under `repro/serve/` — and the
serve-facing benchmarks may use wall timing only with an explicit
`# repro: allow[wall-clock-in-serve]` at the call site.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, in_virtual_clock_scope, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

BANNED_CALLS = {
    "time.time", "time.time_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns",
    "time.process_time", "time.process_time_ns",
    "time.sleep",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}


@register
class WallClockInServe(Rule):
    id = "wall-clock-in-serve"
    description = ("no wall clock (time.time/perf_counter/datetime.now) "
                   "or stdlib random in virtual-clock code "
                   "(repro/serve + serve benchmarks)")

    def applies(self, f: FileInfo) -> bool:
        return in_virtual_clock_scope(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Call):
                dotted = f.dotted(node.func)
                if dotted in BANNED_CALLS:
                    out.append(self.finding(
                        f, node,
                        f"`{dotted}()` in virtual-clock code — serve "
                        f"time comes from the ARTEMIS cost model "
                        f"(engine.now), never the wall clock"))
                elif dotted is not None and (
                        dotted == "random" or dotted.startswith("random.")):
                    out.append(self.finding(
                        f, node,
                        f"stdlib `{dotted}()` in virtual-clock code — "
                        f"use np.random.default_rng(seed) (traffic) or "
                        f"jax.random via sampler.lane_key (sampling)"))
            elif isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "random" or a.name.startswith("random."):
                        out.append(self.finding(
                            f, node,
                            "stdlib `random` imported in virtual-clock "
                            "code — its global hidden-state RNG breaks "
                            "(trace, seed) determinism"))
            elif isinstance(node, ast.ImportFrom) and node.level == 0:
                if node.module == "random":
                    out.append(self.finding(
                        f, node,
                        "stdlib `random` imported in virtual-clock "
                        "code — its global hidden-state RNG breaks "
                        "(trace, seed) determinism"))
        return out
