"""allocator-refcount: every page handle minted by
`PageAllocator.alloc` (and every refcount taken by `.share`) must be
accounted for on ALL paths out of the function — freed, returned,
stored into a field, or handed to a callee — including the paths an
exception takes. A handle that can fall off the end of a function is a
leaked physical page: `check_invariants` catches the imbalance at
runtime only if the leaking path actually runs; this rule is its
static twin over the CFG's exception edges too.

Escape analysis over the shared forward solver: the abstract state is
a set of (handle, carrier) pairs, where a handle is the (line, col) of
the minting call and a carrier is a local name holding it. Sinks that
discharge a handle (conservatively — this is a leak detector, not an
ownership checker): passing a carrier to ANY call (`free(pages)`,
`jnp.int32(slot)`, `list(spages)` — the callee may take ownership),
returning it, raising with it, or storing it into an attribute or
subscript. Rebinding a handle's last carrier marks the handle dead —
it can no longer be freed, so it still reports at the exits. A minting
call whose result is discarded outright (a bare expression statement)
is flagged immediately.

Allocator receivers are recognized syntactically: a dotted chain
ending in `.allocator` (`self.cache.allocator.alloc(...)`), or a local
alias bound from one (`alloc = self.cache.allocator`) or from a
`PageAllocator(...)` construction. Nested minting calls consumed by an
enclosing expression (`pages.extend(a.alloc(1, rid))`) are treated as
immediately sunk by the consumer.
"""
from __future__ import annotations

import ast

from repro.analysis.cfg import atom_bindings, build_cfg, shallow_walk
from repro.analysis.core import Rule, in_serve, register
from repro.analysis.dataflow import (ForwardAnalysis, atom_states,
                                     call_graph, chain_str, flat_names,
                                     solve)
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

DEAD = "<dead>"   # the handle's last carrier was rebound: unfreeable


def _allocator_aliases(fn_node: ast.AST) -> set[str]:
    """Local names bound to an allocator anywhere in the function
    (scope-insensitive pre-pass): `alloc = self.cache.allocator` or
    `alloc = PageAllocator(...)`."""
    from repro.analysis.dataflow import scope_walk
    out: set[str] = set()
    for n in scope_walk(fn_node.body):
        if not isinstance(n, ast.Assign):
            continue
        src = n.value
        chain = chain_str(src)
        is_alloc = (chain is not None
                    and (chain == "allocator"
                         or chain.endswith(".allocator")))
        if (isinstance(src, ast.Call)
                and isinstance(src.func, ast.Name)
                and src.func.id == "PageAllocator"):
            is_alloc = True
        if is_alloc:
            for t in n.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


def _minting_call(call: ast.Call, aliases: set[str]) -> str | None:
    """"alloc" / "share" when the call mints a tracked handle."""
    func = call.func
    if not isinstance(func, ast.Attribute) or func.attr not in (
            "alloc", "share"):
        return None
    recv = chain_str(func.value)
    if recv is None:
        return None
    if (recv == "allocator" or recv.endswith(".allocator")
            or recv in aliases):
        return func.attr
    return None


def _loaded_names(e: ast.AST) -> set[str]:
    return {n.id for n in shallow_walk(e)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


class _EscapeAnalysis(ForwardAnalysis):
    def __init__(self, aliases: set[str]):
        self.aliases = aliases

    def transfer(self, state: frozenset, atom: ast.AST) -> frozenset:
        bindings = atom_bindings(atom)

        # 1. sinks: carriers read by a call argument, a return/raise,
        #    or the value stored into an attribute/subscript discharge
        #    their whole handle (aliases included)
        sunk_names: set[str] = set()
        for n in shallow_walk(atom):
            if isinstance(n, ast.Call):
                for arg in list(n.args) + [kw.value for kw in n.keywords]:
                    sunk_names |= _loaded_names(arg)
        if isinstance(atom, ast.Return) and atom.value is not None:
            sunk_names |= _loaded_names(atom.value)
        if isinstance(atom, ast.Raise):
            sunk_names |= _loaded_names(atom)
        for targets, value in bindings:
            stored = any(
                isinstance(sub, (ast.Attribute, ast.Subscript))
                for t in targets for sub in ast.walk(t))
            if stored and value is not None:
                sunk_names |= _loaded_names(value)
        sunk_handles = {h for (h, c) in state if c in sunk_names}
        state = frozenset(p for p in state if p[0] not in sunk_handles)

        # 2. aliasing: `b = a` keeps the handle reachable through b
        for targets, value in bindings:
            if (isinstance(value, ast.Name)
                    and len(targets) == 1
                    and isinstance(targets[0], ast.Name)):
                extra = {(h, targets[0].id) for (h, c) in state
                         if c == value.id}
                state = state | extra

        # 3. rebinds: a bound name stops carrying; a handle whose last
        #    carrier is rebound becomes dead (still a leak at exit)
        bound: set[str] = set()
        for targets, _ in bindings:
            for t in targets:
                flat_names(t, bound)
        if bound:
            dropped = {(h, c) for (h, c) in state if c in bound}
            if dropped:
                kept = state - dropped
                live = {h for (h, _) in kept}
                dead = {(h, DEAD) for (h, _) in dropped
                        if h not in live}
                state = kept | dead

        # 4. gen: direct minting assignments and bare `share(...)`
        #    statements create (handle, carrier) pairs
        for targets, value in bindings:
            if not isinstance(value, ast.Call):
                continue
            kind = _minting_call(value, self.aliases)
            if kind is None:
                continue
            handle = (value.lineno, value.col_offset)
            names: set[str] = set()
            for t in targets:
                flat_names(t, names)
            state = state | {(handle, c) for c in names}
        if isinstance(atom, ast.Expr) and isinstance(atom.value, ast.Call):
            call = atom.value
            if (_minting_call(call, self.aliases) == "share"
                    and call.args and isinstance(call.args[0], ast.Name)):
                handle = (call.lineno, call.col_offset)
                state = state | {(handle, call.args[0].id)}
        return state


@register
class AllocatorRefcount(Rule):
    id = "allocator-refcount"
    description = ("every PageAllocator.alloc/.share handle must reach "
                   "free, a return, or a stored field on all paths out "
                   "of the function, exception edges included")

    def applies(self, f: FileInfo) -> bool:
        return in_serve(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for (path, _), fn in call_graph(project).functions.items():
            if path != f.path:
                continue
            aliases = _allocator_aliases(fn.node)
            analysis = _EscapeAnalysis(aliases)
            cfg = build_cfg(fn.node)
            in_states = solve(cfg, analysis)
            # discarded results: a bare `....alloc(...)` statement
            for atom, _ in atom_states(cfg, analysis, in_states):
                if (isinstance(atom, ast.Expr)
                        and isinstance(atom.value, ast.Call)
                        and _minting_call(atom.value, aliases)
                        == "alloc"):
                    out.append(self.finding(
                        f, atom.value,
                        f"`alloc(...)` result discarded in "
                        f"`{fn.qual}` — the pages can never be freed; "
                        f"bind the handle and free or store it"))
            # leaks: handles still live when some path leaves the
            # function (normal exit or uncaught exception)
            leaked: dict[tuple[int, int], str] = {}
            for exit_bid, how in ((cfg.exit, "a normal exit"),
                                  (cfg.raise_exit, "an exception edge")):
                for (h, _c) in sorted(in_states[exit_bid]):
                    leaked.setdefault(h, how)
            for (line, col), how in sorted(leaked.items()):
                node = ast.Expr(lineno=line, col_offset=col)
                out.append(self.finding(
                    f, node,
                    f"allocator handle minted here may leak in "
                    f"`{fn.qual}`: on {how} it reaches neither "
                    f"`free(...)`, a return, nor a stored field"))
        return out
