"""donation-discipline: a buffer passed at a `donate_argnums` position
of a jitted step is INVALID after the call — jax may have aliased its
memory into the result. The serve stack leans on donation for every
hot buffer (the paged KV pool, the recurrent state-slot pool, COW page
copies), so a read of a donated buffer on any path after the donating
call is a use-after-free that only reproduces on backends that honor
donation — exactly what CPU-only tier-1 runs miss.

The rule runs the shared forward solver per function: the abstract
state is the set of dotted value-chains (`self.cache.kv`, `pool`) that
have been donated and not yet rebound. A donating call (resolved
through the project-wide donation index: decorated steps, local
`jax.jit(...)` bindings, donating factories, and instance attributes
bound from factory results) GENS the chains it donates; any assignment
to a chain (or to a prefix of it — rebinding `self.cache` refreshes
`self.cache.kv` too) KILLS it; a read of a live donated chain on any
path is the finding. The idiomatic
`self.cache.kv = step(..., self.cache.kv, ...)` is clean: the read
happens before the donation gen, and the rebind kills it in the same
atom.
"""
from __future__ import annotations

import ast

from repro.analysis.cfg import atom_bindings, build_cfg, shallow_walk
from repro.analysis.core import Rule, register
from repro.analysis.dataflow import (ForwardAnalysis, atom_states,
                                     call_graph, chain_str,
                                     donated_positions, donation_index,
                                     solve)
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project


def _donated_chains(f: FileInfo, atom: ast.AST, idx) -> set[str]:
    """Value chains donated by calls inside this atom."""
    out: set[str] = set()
    for n in shallow_walk(atom):
        if not isinstance(n, ast.Call):
            continue
        positions = donated_positions(f, n, idx)
        if not positions:
            continue
        for pos in positions:
            if pos < len(n.args):
                chain = chain_str(n.args[pos])
                if chain is not None:
                    out.add(chain)
    return out


def _killed(state: frozenset, target: ast.AST) -> frozenset:
    """Remove chains rebound by an assignment target: the exact chain
    and everything reached through it (`self.cache = ...` refreshes
    `self.cache.kv`)."""
    chain = chain_str(target)
    if isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            state = _killed(state, e)
        return state
    if isinstance(target, ast.Starred):
        return _killed(state, target.value)
    if isinstance(target, ast.Subscript):
        # storing INTO the buffer does not revalidate it; but jax
        # arrays are immutable, so this does not occur on real buffers
        return state
    if chain is None:
        return state
    return frozenset(k for k in state
                     if k != chain and not k.startswith(chain + "."))


class _DonationAnalysis(ForwardAnalysis):
    def __init__(self, f: FileInfo, idx):
        self.f = f
        self.idx = idx

    def transfer(self, state: frozenset, atom: ast.AST) -> frozenset:
        state = state | _donated_chains(self.f, atom, self.idx)
        for targets, _ in atom_bindings(atom):
            for t in targets:
                state = _killed(state, t)
        return state


def _reads_of(atom: ast.AST, state: frozenset) -> list[tuple[str, ast.AST]]:
    """(chain, node) for every Load of a live donated chain in the
    atom. Matching every sub-node means `self.cache.kv.shape` trips on
    its inner `self.cache.kv` chain too."""
    hits: list[tuple[str, ast.AST]] = []
    for n in shallow_walk(atom):
        if not isinstance(n, (ast.Name, ast.Attribute)):
            continue
        if not isinstance(getattr(n, "ctx", None), ast.Load):
            continue
        chain = chain_str(n)
        if chain in state:
            hits.append((chain, n))
    return hits


@register
class DonationDiscipline(Rule):
    id = "donation-discipline"
    description = ("a buffer passed at a donate_argnums position of a "
                   "jitted step must not be read again until rebound "
                   "from the call's result")

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        idx = donation_index(project)
        if not (idx.functions or idx.attrs or idx.locals):
            return out
        analysis = _DonationAnalysis(f, idx)
        for (path, _), fn in call_graph(project).functions.items():
            if path != f.path:
                continue
            cfg = build_cfg(fn.node)
            in_states = solve(cfg, analysis)
            seen: set[tuple[str, int]] = set()
            for atom, state in atom_states(cfg, analysis, in_states):
                if not state:
                    continue
                for chain, node in _reads_of(atom, state):
                    key = (chain, node.lineno)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(self.finding(
                        f, node,
                        f"`{chain}` is read in `{fn.qual}` after being "
                        f"passed at a donated position "
                        f"(donate_argnums) of a jitted step — the "
                        f"buffer may be aliased into the result; "
                        f"rebind it from the call's return value "
                        f"before reuse"))
        return out
