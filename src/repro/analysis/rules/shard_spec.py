"""shard-spec-discipline: sharding LAYOUT is seam-owned. PR 8's mesh
seam made topology flow as a `ServeMesh` value (`mesh-discipline`
pins that); this rule hardens the other half — the placement
vocabulary. `PartitionSpec` / `NamedSharding` constructions and
string axis-name literals scattered through consumer modules are
layout decisions the seam can no longer see or change: a renamed mesh
axis or a new sharding strategy then means hunting call sites instead
of editing `parallel/sharding.py` + `serve/mesh.py`, the two modules
that own spec construction (and are exempt here, mirroring
mesh-discipline's scoping).

Flagged in governed `repro/` modules:

  * any call resolving to `jax.sharding.PartitionSpec` or
    `jax.sharding.NamedSharding` (import aliases followed — `P(...)`
    counts);
  * a string-literal `axis_name=` keyword in any call;
  * a string-literal positional axis handed to the named `jax.lax`
    collectives (`psum(x, "model")`, ...).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

SPEC_TYPES = {
    "jax.sharding.PartitionSpec",
    "jax.sharding.NamedSharding",
}

# collectives whose second positional argument is the axis name
COLLECTIVES = {
    "jax.lax.psum", "jax.lax.pmax", "jax.lax.pmin", "jax.lax.pmean",
    "jax.lax.all_gather", "jax.lax.ppermute", "jax.lax.axis_index",
}

# The two modules that own placement: the parallel collectives layer
# and the serve mesh seam (same exemptions as mesh-discipline).
EXEMPT_SUFFIX = ("repro/serve/mesh.py",)
EXEMPT_DIR = "repro/parallel/"


def _governed(path: str) -> bool:
    if "repro/" not in path:
        return False
    sub = path.split("repro/", 1)[1]
    return not (("repro/" + sub).startswith(EXEMPT_DIR)
                or any(path.endswith(s) for s in EXEMPT_SUFFIX))


def _is_axis_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return True
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_is_axis_literal(e) for e in node.elts)
    return False


@register
class ShardSpecDiscipline(Rule):
    id = "shard-spec-discipline"
    description = ("no PartitionSpec/NamedSharding construction or "
                   "axis-name string literals outside "
                   "repro/parallel/ and repro/serve/mesh.py — specs "
                   "come from the seam helpers")

    def applies(self, f: FileInfo) -> bool:
        return _governed(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = f.dotted(node.func)
            if dotted in SPEC_TYPES:
                short = dotted.rsplit(".", 1)[-1]
                out.append(self.finding(
                    f, node,
                    f"`{short}(...)` constructed outside the sharding "
                    f"seam — obtain specs from repro/parallel/sharding "
                    f"or repro/serve/mesh helpers so layout stays "
                    f"seam-owned"))
                continue
            for kw in node.keywords:
                if kw.arg == "axis_name" and _is_axis_literal(kw.value):
                    out.append(self.finding(
                        f, kw.value,
                        f"string-literal `axis_name=` outside the "
                        f"sharding seam — axis names are seam-owned; "
                        f"take them from the mesh value"))
            if (dotted in COLLECTIVES and len(node.args) >= 2
                    and _is_axis_literal(node.args[1])):
                out.append(self.finding(
                    f, node.args[1],
                    f"string-literal axis name passed to "
                    f"`{dotted}` outside the sharding seam — take the "
                    f"axis from the mesh value"))
        return out
