"""registry-namespace: every `MetricsRegistry` key published (or read
back) in the serve layer is a string LITERAL — or a module-level
string constant, like `sampler.N_SAMPLED_KEY` — under one of the four
namespaces `engine/`, `scheduler/`, `sampler/`, `backend/`. Backend
modules may publish only under `backend/`: it is the ONE namespace
allowed to differ between sequence backends (every other key set must
be backend-independent — the conformance suite pins the runtime half
of this; the static half is that nobody can even spell a key that
would violate it).

Receiver heuristic (the convention the serve layer already follows):
registry method calls are checked when the receiver is a name `reg` /
`registry` or any attribute chain ending in `.registry`
(`self.obs.registry.inc(...)`). Bind registries to those names.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, in_serve, is_backend_module, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

NAMESPACES = ("engine/", "scheduler/", "sampler/", "backend/")
# methods whose FIRST argument is a registry key
KEYED_METHODS = {"inc", "set_gauge", "observe", "count", "gauge", "hist"}
RECEIVER_NAMES = {"reg", "registry"}


def _is_registry_receiver(node: ast.AST) -> bool:
    if isinstance(node, ast.Name):
        return node.id in RECEIVER_NAMES
    if isinstance(node, ast.Attribute):
        return node.attr in RECEIVER_NAMES
    return False


@register
class RegistryNamespace(Rule):
    id = "registry-namespace"
    description = ("MetricsRegistry keys must be literals (or module "
                   "constants) under engine/ scheduler/ sampler/ "
                   "backend/; backend modules may only use backend/")

    def applies(self, f: FileInfo) -> bool:
        return in_serve(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        backend_mod = is_backend_module(f.path)
        for node in ast.walk(f.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in KEYED_METHODS
                    and _is_registry_receiver(node.func.value)
                    and node.args):
                continue
            key_node = node.args[0]
            if (isinstance(key_node, ast.Constant)
                    and isinstance(key_node.value, str)):
                key = key_node.value
            else:
                key = project.lookup_constant(f, key_node)
                if key is None:
                    out.append(self.finding(
                        f, node,
                        "registry key is not a string literal or a "
                        "module-level string constant — dynamic keys "
                        "defeat the namespace audit"))
                    continue
            if not key.startswith(NAMESPACES):
                out.append(self.finding(
                    f, node,
                    f"registry key {key!r} outside the serve "
                    f"namespaces {'/'.join(n[:-1] for n in NAMESPACES)}"))
            elif backend_mod and not key.startswith("backend/"):
                out.append(self.finding(
                    f, node,
                    f"backend module publishes {key!r} — backends may "
                    f"only use the `backend/` namespace (the one "
                    f"namespace allowed to differ between backends)"))
        return out
