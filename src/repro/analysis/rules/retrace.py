"""retrace-hazard: the serve layer compiles each step function exactly
ONCE (fixed (max_batch, chunk) shapes; `_paged_steps`/`_slot_steps`
lru_cache the jitted callables per (cfg, policy)). A `jax.jit` (or
`pallas_call`) invocation sitting lexically inside a loop or a
comprehension builds a FRESH wrapper per iteration, each with its own
trace cache — compile time leaks into the iteration and the
compile-once design of PR 2/4 is silently defeated. Hoist the wrapper
out of the loop (module level, or an lru_cached factory).
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

_LOOPS = (ast.For, ast.AsyncFor, ast.While)
_COMPREHENSIONS = (ast.ListComp, ast.SetComp, ast.DictComp,
                   ast.GeneratorExp)
_BOUNDARIES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
               ast.Module)


def _is_jit_wrapper(f: FileInfo, node: ast.Call) -> str | None:
    dotted = f.dotted(node.func)
    if dotted == "jax.jit":
        return "jax.jit"
    if dotted is not None and (dotted == "pallas_call"
                               or dotted.endswith(".pallas_call")):
        return "pallas_call"
    if dotted == "functools.partial" and node.args:
        if f.dotted(node.args[0]) == "jax.jit":
            return "functools.partial(jax.jit, ...)"
    return None


@register
class RetraceHazard(Rule):
    id = "retrace-hazard"
    description = ("jax.jit/pallas_call invoked inside a loop or "
                   "comprehension — a fresh wrapper (and trace cache) "
                   "per iteration defeats compile-once")

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            wrapper = _is_jit_wrapper(f, node)
            if wrapper is None:
                continue
            cur = f.parent(node)
            while cur is not None and not isinstance(cur, _BOUNDARIES):
                if isinstance(cur, _LOOPS + _COMPREHENSIONS):
                    where = ("a comprehension"
                             if isinstance(cur, _COMPREHENSIONS)
                             else f"a `{'while' if isinstance(cur, ast.While) else 'for'}` loop")
                    out.append(self.finding(
                        f, node,
                        f"`{wrapper}` invoked inside {where} — each "
                        f"iteration builds a fresh wrapper with its own "
                        f"trace cache; hoist it out (module level or an "
                        f"lru_cached factory like serve.backend."
                        f"_paged_steps)"))
                    break
                cur = f.parent(cur)
        return out
