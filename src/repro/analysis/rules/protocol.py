"""backend-protocol: static signature conformance of `SequenceBackend`
implementers. The runtime conformance suite
(tests/test_serve_backend.py) exercises behavior; this rule checks the
part a typo survives until runtime on an unexercised path: every
abstract method of the protocol is implemented, with the protocol's
positional parameter names in the protocol's order (extra parameters
must carry defaults so engine call sites keep working).

The protocol is located structurally: a class named `SequenceBackend`
whose methods are `@abc.abstractmethod`-decorated. Implementers are
classes anywhere in the project with `SequenceBackend` among their
bases; in-project intermediate bases are followed by name, so shared
partial implementations resolve before a method counts as missing.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

PROTOCOL_CLASS = "SequenceBackend"
_FN = (ast.FunctionDef, ast.AsyncFunctionDef)


def _base_name(node: ast.AST) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _methods(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, _FN)}


def _positional_params(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    params = [p.arg for p in a.posonlyargs + a.args]
    return params[1:] if params and params[0] in ("self", "cls") else params


def _is_abstract(f: FileInfo, fn: ast.FunctionDef) -> bool:
    for d in fn.decorator_list:
        if f.dotted(d) in ("abc.abstractmethod", "abstractmethod"):
            return True
    return False


def _has_varargs(fn: ast.FunctionDef) -> bool:
    return fn.args.vararg is not None


def _classes(project: Project):
    for f in project.files.values():
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ClassDef):
                yield f, node


def _find_protocol(project: Project):
    for f, cls in _classes(project):
        if cls.name != PROTOCOL_CLASS:
            continue
        abstract = {name: fn for name, fn in _methods(cls).items()
                    if _is_abstract(f, fn)}
        if abstract:
            return f, cls, abstract
    return None


def _resolve_method(project: Project, cls: ast.ClassDef, name: str,
                    seen: set[str]) -> ast.FunctionDef | None:
    """Look up `name` on cls, then on in-project bases by simple name
    (excluding the protocol itself — inheriting the abstract stub is
    not an implementation)."""
    own = _methods(cls).get(name)
    if own is not None:
        return own
    for base in cls.bases:
        bname = _base_name(base)
        if bname is None or bname == PROTOCOL_CLASS or bname in seen:
            continue
        seen.add(bname)
        for _, candidate in _classes(project):
            if candidate.name == bname:
                found = _resolve_method(project, candidate, name, seen)
                if found is not None:
                    return found
    return None


@register
class BackendProtocol(Rule):
    id = "backend-protocol"
    description = ("SequenceBackend implementers must define every "
                   "abstract method with the protocol's positional "
                   "signature")

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        proto = _find_protocol(project)
        if proto is None:
            return []
        _, proto_cls, abstract = proto
        out: list[Finding] = []
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            if node.name == PROTOCOL_CLASS:
                continue
            if not any(_base_name(b) == PROTOCOL_CLASS
                       for b in node.bases):
                continue
            for name, proto_fn in sorted(abstract.items()):
                impl = _resolve_method(project, node, name, set())
                if impl is None:
                    out.append(self.finding(
                        f, node,
                        f"`{node.name}` does not implement abstract "
                        f"`{name}` of the SequenceBackend protocol"))
                    continue
                if _is_abstract(f, impl):
                    continue   # explicitly re-abstracted intermediate
                if _has_varargs(impl):
                    continue   # forwards everything; runtime suite owns it
                want = _positional_params(proto_fn)
                got = _positional_params(impl)
                extra = got[len(want):]
                defaults = impl.args.defaults
                n_defaulted = len(defaults)
                bad_extra = [p for i, p in enumerate(extra)
                             if len(got) - (len(want) + i) > n_defaulted]
                if got[:len(want)] != want:
                    out.append(self.finding(
                        f, impl,
                        f"`{node.name}.{name}` positional parameters "
                        f"{got[:len(want)]} do not match the protocol's "
                        f"{want} — engine call sites pass these "
                        f"positionally and by keyword"))
                elif bad_extra:
                    out.append(self.finding(
                        f, impl,
                        f"`{node.name}.{name}` adds required "
                        f"parameter(s) {bad_extra} beyond the protocol "
                        f"signature — extras must have defaults"))
        return out
