"""rng-key-discipline: the PR 5 batch-invariance contract. Every
sampled token's key is `fold_in(PRNGKey(request.seed), tokens
generated so far)` and NOTHING else — constructed in exactly one
place, `repro/serve/sampler.py` (`lane_key`). Any other `PRNGKey`
construction in the serve layer is a second RNG root that can
decorrelate a request's stream from its (seed, position) identity, so
it is flagged; keys reaching draw sites must arrive through
`fold_in`/`split`, never be built inline at the draw.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Rule, in_serve, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

KEY_CONSTRUCTORS = {"jax.random.PRNGKey", "jax.random.key"}
# jax.random callables that CONSUME a key (first positional / key=)
# without being key plumbing themselves
_KEY_PLUMBING = {"PRNGKey", "key", "fold_in", "split", "wrap_key_data",
                 "key_data", "clone"}

SANCTIONED_FILES = ("repro/serve/sampler.py",)


def _is_sanctioned(path: str) -> bool:
    return any(path.endswith(p) for p in SANCTIONED_FILES)


@register
class RngKeyDiscipline(Rule):
    id = "rng-key-discipline"
    description = ("PRNGKey construction only in repro/serve/sampler.py; "
                   "keys must flow through fold_in/split, never be "
                   "built inline at a draw site")

    def applies(self, f: FileInfo) -> bool:
        return in_serve(f.path)

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        sanctioned = _is_sanctioned(f.path)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            dotted = f.dotted(node.func)
            if dotted is None:
                continue
            if dotted in KEY_CONSTRUCTORS and not sanctioned:
                out.append(self.finding(
                    f, node,
                    f"`{dotted}` constructed outside sampler.py — the "
                    f"RNG-lane contract derives every serve key from "
                    f"fold_in(PRNGKey(request.seed), tokens_generated) "
                    f"in sampler.lane_key"))
            elif (dotted.startswith("jax.random.")
                    and dotted.rsplit(".", 1)[-1] not in _KEY_PLUMBING):
                key_arg = None
                if node.args:
                    key_arg = node.args[0]
                else:
                    for kw in node.keywords:
                        if kw.arg == "key":
                            key_arg = kw.value
                if (isinstance(key_arg, ast.Call)
                        and f.dotted(key_arg.func) in KEY_CONSTRUCTORS):
                    out.append(self.finding(
                        f, node,
                        f"fresh PRNGKey built inline at a `{dotted}` "
                        f"draw site — reusing a root key here breaks "
                        f"batch invariance; derive the key via "
                        f"fold_in/split (sampler.lane_key)"))
        return out
