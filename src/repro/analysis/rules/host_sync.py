"""host-sync-in-jit: code reachable from a `jax.jit` / `pallas_call`
boundary runs on TRACED values — `.item()`, `float()/int()/bool()` on
a traced array, or `np.asarray` force a host sync (or a trace-time
error on the first UNEXERCISED path to hit them, which is exactly what
a runtime suite misses).

The rule is a thin client of the shared dataflow layer
(`analysis/dataflow.py`): the interprocedural call graph grown from
`Project.jit_surface` decides which functions run under a trace, and
the flow-sensitive `TaintAnalysis` over each function's CFG decides
which names may hold traced values at each call site. Flow sensitivity
means a rebind from static metadata (`n = x.shape[0]`) launders the
name from that point on, and code on paths never reached from the
function entry cannot flag — both strictly tighter than the old
flow-insensitive fixpoint this rule carried privately.
"""
from __future__ import annotations

import ast

from repro.analysis.cfg import build_cfg, shallow_walk
from repro.analysis.core import Rule, register
from repro.analysis.dataflow import (TaintAnalysis, atom_states,
                                     call_graph, expr_is_static,
                                     expr_tainted, solve)
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

NUMPY_PULLS = {"numpy.asarray", "numpy.array", "numpy.copy"}
CONVERSIONS = {"float", "int", "bool", "complex"}


@register
class HostSyncInJit(Rule):
    id = "host-sync-in-jit"
    description = (".item()/float()/int()/bool()/np.asarray on traced "
                   "values inside functions reachable from "
                   "jax.jit/pallas_call")

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        graph = call_graph(project)
        for (path, _), fn in graph.functions.items():
            if path != f.path or fn.reachable_via is None:
                continue
            analysis = TaintAnalysis(fn.params)
            cfg = build_cfg(fn.node)
            in_states = solve(cfg, analysis)
            where = (f"in `{fn.qual}` (jit-reachable via "
                     f"`{fn.reachable_via}`)")
            for atom, state in atom_states(cfg, analysis, in_states):

                def hit(e: ast.AST) -> bool:
                    return (expr_tainted(e, state)
                            and not expr_is_static(e))

                for n in shallow_walk(atom):
                    if not isinstance(n, ast.Call):
                        continue
                    if (isinstance(n.func, ast.Attribute)
                            and n.func.attr in ("item", "tolist")
                            and not n.args and hit(n.func.value)):
                        out.append(self.finding(
                            f, n,
                            f"`.{n.func.attr}()` on a traced value "
                            f"{where} — forces a host sync / trace "
                            f"error"))
                        continue
                    dotted = f.dotted(n.func)
                    if (isinstance(n.func, ast.Name)
                            and n.func.id in CONVERSIONS
                            and len(n.args) == 1 and hit(n.args[0])):
                        out.append(self.finding(
                            f, n,
                            f"`{n.func.id}()` on a traced value {where} "
                            f"— host conversion inside jit; use jnp "
                            f"casts or keep it in the array program"))
                    elif (dotted in NUMPY_PULLS
                            and n.args and hit(n.args[0])):
                        out.append(self.finding(
                            f, n,
                            f"`{dotted.replace('numpy', 'np')}` on a "
                            f"traced value {where} — device->host pull "
                            f"inside jit; use jnp.asarray"))
        return out
