"""host-sync-in-jit: code reachable from a `jax.jit` / `pallas_call`
boundary runs on TRACED values — `.item()`, `float()/int()/bool()` on
a traced array, or `np.asarray` force a host sync (or a trace-time
error on the first UNEXERCISED path to hit them, which is exactly what
a runtime suite misses). The rule grows a lightweight intra-package
call graph from every jit root and flags host-sync constructs applied
to parameter-derived (i.e. traced) values inside reachable functions.

Jit roots, resolved project-wide (see `Project.jit_surface`):

  * functions decorated `@jax.jit` / `@functools.partial(jax.jit, ...)`
  * functions passed by name to `jax.jit(f)` or `pallas_call(f, ...)`
  * inner functions RETURNED by a factory whose call is jitted
    (`jax.jit(make_paged_decode(cfg, policy))` — the serve idiom)

Reachability follows plain-name calls: locals/nested functions,
same-file module functions, `self.method` within a class, and imported
names that resolve to an analyzed module. Taint is the function's own
parameters propagated through simple assignments; access to static
metadata (`.shape`, `.ndim`, `.dtype`, `len()`) launders it, since
those are Python values at trace time.
"""
from __future__ import annotations

import ast
import dataclasses

from repro.analysis.core import Rule, register
from repro.analysis.findings import Finding
from repro.analysis.project import FileInfo, Project

_FN = (ast.FunctionDef, ast.AsyncFunctionDef)
_SCOPE_BOUNDARY = _FN + (ast.Lambda, ast.ClassDef)

# attribute/call accesses that yield static Python values at trace time
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type"}
NUMPY_PULLS = {"numpy.asarray", "numpy.array", "numpy.copy"}
CONVERSIONS = {"float", "int", "bool", "complex"}


def _stmt_walk(stmts):
    """Walk statements descending into compound statements but never
    across a function/class/lambda boundary."""
    stack = list(stmts)
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, _SCOPE_BOUNDARY):
            continue
        stack.extend(ast.iter_child_nodes(n))


@dataclasses.dataclass
class _Func:
    path: str
    qual: str                      # e.g. "Class.method" / "factory.step"
    name: str
    node: ast.AST
    cls: str | None                # enclosing class name, if a method
    params: set[str]
    jit_decorated: bool = False
    returned_inner: set[str] = dataclasses.field(default_factory=set)
    reachable_via: str | None = None   # root qual once BFS marks it


# parameter annotations that mean "static python value at trace time":
# scalar builtins, and the repo's config/policy carrier types
_STATIC_SCALAR_TYPES = {"int", "float", "bool", "str", "bytes", "None"}


def _annotation_is_static(ann: ast.AST | None) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant):
        # string annotations and bare None
        if isinstance(ann.value, str):
            return (ann.value in _STATIC_SCALAR_TYPES
                    or ann.value.endswith(("Config", "Policy")))
        return ann.value is None
    if isinstance(ann, (ast.Name, ast.Attribute)):
        name = ann.attr if isinstance(ann, ast.Attribute) else ann.id
        return (name in _STATIC_SCALAR_TYPES
                or name.endswith(("Config", "Policy")))
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_annotation_is_static(ann.left)
                and _annotation_is_static(ann.right))
    if isinstance(ann, ast.Subscript):
        base = ann.value
        name = (base.attr if isinstance(base, ast.Attribute)
                else base.id if isinstance(base, ast.Name) else "")
        if name in ("Optional", "Union"):
            return _annotation_is_static(ann.slice)
    if isinstance(ann, ast.Tuple):
        return all(_annotation_is_static(e) for e in ann.elts)
    return False


def _params_of(node) -> set[str]:
    """Parameter names that may carry TRACED values — parameters whose
    annotation pins them to a static python scalar or a config/policy
    object are excluded from taint."""
    a = node.args
    params = [p for p in a.posonlyargs + a.args + a.kwonlyargs]
    names = [p.arg for p in params
             if not _annotation_is_static(p.annotation)]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return set(names)


def _is_jit_decorator(f: FileInfo, dec: ast.AST) -> bool:
    if f.dotted(dec) == "jax.jit":
        return True
    if isinstance(dec, ast.Call):
        d = f.dotted(dec.func)
        if d == "jax.jit":
            return True
        if d == "functools.partial" and dec.args:
            return f.dotted(dec.args[0]) == "jax.jit"
    return False


def _collect_file(f: FileInfo) -> dict[str, _Func]:
    funcs: dict[str, _Func] = {}

    def scope(stmts, prefix: str, cls: str | None):
        for n in _stmt_walk(stmts):
            if isinstance(n, _FN):
                qual = prefix + n.name
                fn = _Func(path=f.path, qual=qual, name=n.name, node=n,
                           cls=cls, params=_params_of(n))
                fn.jit_decorated = any(_is_jit_decorator(f, d)
                                       for d in n.decorator_list)
                # inner defs this function returns (factory pattern)
                inner = {c.name for c in _stmt_walk(n.body)
                         if isinstance(c, _FN)}
                for r in _stmt_walk(n.body):
                    if (isinstance(r, ast.Return)
                            and isinstance(r.value, ast.Name)
                            and r.value.id in inner):
                        fn.returned_inner.add(f"{qual}.{r.value.id}")
                funcs[qual] = fn
                scope(n.body, qual + ".", None)
            elif isinstance(n, ast.ClassDef):
                scope(n.body, prefix + n.name + ".", n.name)

    scope(f.tree.body, "", None)
    return funcs


# jax transforms whose function-valued arguments are traced as part of
# the caller: an edge to those functions keeps scan/vmap bodies inside
# the reachable set
TRANSFORMS = {
    "jax.vmap", "jax.pmap", "jax.checkpoint", "jax.remat", "jax.grad",
    "jax.value_and_grad", "functools.partial",
    "jax.lax.scan", "jax.lax.map", "jax.lax.cond", "jax.lax.switch",
    "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.associative_scan",
}


def _call_edges(f: FileInfo, fn: _Func, project: Project,
                index: dict[tuple[str, str], _Func]
                ) -> list[tuple[str, str]]:
    """Resolved (path, qual) targets of plain-name calls in fn's own
    body (nested defs excluded — they are graph nodes of their own),
    plus function-valued arguments handed to jax transforms."""
    out: list[tuple[str, str]] = []

    def resolve(t: ast.AST):
        if isinstance(t, ast.Name):
            # nested function of an enclosing scope, innermost first
            parts = fn.qual.split(".")
            for i in range(len(parts), 0, -1):
                cand = ".".join(parts[:i]) + "." + t.id
                if (f.path, cand) in index:
                    return (f.path, cand)
            if (f.path, t.id) in index:
                return (f.path, t.id)
            dotted = f.aliases.get(t.id)
            if dotted and "." in dotted:
                mod, name = dotted.rsplit(".", 1)
                for path2, fi in project.files.items():
                    if fi.module == mod and (path2, name) in index:
                        return (path2, name)
        elif isinstance(t, ast.Attribute):
            if (isinstance(t.value, ast.Name) and t.value.id == "self"
                    and fn.cls is not None):
                cand = f"{fn.cls}.{t.attr}"
                if (f.path, cand) in index:
                    return (f.path, cand)
            dotted = f.dotted(t)
            if dotted and "." in dotted:
                mod, name = dotted.rsplit(".", 1)
                for path2, fi in project.files.items():
                    if fi.module == mod and (path2, name) in index:
                        return (path2, name)
        return None

    for n in _stmt_walk(fn.node.body):
        if not isinstance(n, ast.Call):
            continue
        tgt = resolve(n.func)
        if tgt is not None:
            out.append(tgt)
        if f.dotted(n.func) in TRANSFORMS:
            for arg in list(n.args) + [kw.value for kw in n.keywords]:
                if isinstance(arg, (ast.Name, ast.Attribute)):
                    tgt = resolve(arg)
                    if tgt is not None:
                        out.append(tgt)
    return out


def _build_graph(project: Project) -> dict[tuple[str, str], _Func]:
    index: dict[tuple[str, str], _Func] = {}
    for f in project.files.values():
        if f.tree is None:
            continue
        for qual, fn in _collect_file(f).items():
            index[(f.path, qual)] = fn

    surface = project.jit_surface
    boundary = surface["wrapped"] | surface["kernels"]
    roots: list[tuple[str, str]] = []
    for key, fn in index.items():
        module = project.files[fn.path].module
        # wrapped/kernel matches are module-exact and module-level only
        if fn.jit_decorated or ("." not in fn.qual
                                and (module, fn.name) in boundary):
            roots.append(key)
        elif fn.name in surface["factories"]:
            for inner in fn.returned_inner:
                if (fn.path, inner) in index:
                    roots.append((fn.path, inner))

    edges = {key: _call_edges(project.files[key[0]], fn, project, index)
             for key, fn in index.items()}
    todo = []
    for key in roots:
        if index[key].reachable_via is None:
            index[key].reachable_via = index[key].qual
            todo.append(key)
    while todo:
        key = todo.pop()
        via = index[key].reachable_via
        for tgt in edges[key]:
            if index[tgt].reachable_via is None:
                index[tgt].reachable_via = via
                todo.append(tgt)
    return index


def _graph(project: Project) -> dict[tuple[str, str], _Func]:
    cached = getattr(project, "_host_sync_graph", None)
    if cached is None:
        cached = _build_graph(project)
        project._host_sync_graph = cached
    return cached


def _taint(fn: _Func) -> set[str]:
    """Parameter names plus names assigned from tainted expressions
    (small fixpoint — traced values flow through simple locals).
    Assignments from static expressions (`tg = x.shape[1]`) launder:
    the bound name is a Python value at trace time."""
    tainted = set(fn.params)

    def expr_tainted(e) -> bool:
        return (not _is_static(e)
                and any(isinstance(n, ast.Name) and n.id in tainted
                        for n in ast.walk(e)))

    def targets(t, acc):
        if isinstance(t, ast.Name):
            acc.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                targets(e, acc)

    for _ in range(8):
        before = len(tainted)
        for n in _stmt_walk(fn.node.body):
            if isinstance(n, ast.Assign) and expr_tainted(n.value):
                for t in n.targets:
                    targets(t, tainted)
            elif (isinstance(n, (ast.AugAssign, ast.AnnAssign))
                    and n.value is not None and expr_tainted(n.value)):
                targets(n.target, tainted)
            elif isinstance(n, (ast.For, ast.AsyncFor)) \
                    and expr_tainted(n.iter):
                targets(n.target, tainted)
        if len(tainted) == before:
            break
    return tainted


def _is_static(e: ast.AST) -> bool:
    """Expression is static at trace time despite touching traced
    names: `.shape[0]`, `len(x)`, `x.ndim`, ..."""
    for n in ast.walk(e):
        if isinstance(n, ast.Attribute) and n.attr in STATIC_ATTRS:
            return True
        if (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "len"):
            return True
    return False


@register
class HostSyncInJit(Rule):
    id = "host-sync-in-jit"
    description = (".item()/float()/int()/bool()/np.asarray on traced "
                   "values inside functions reachable from "
                   "jax.jit/pallas_call")

    def check(self, f: FileInfo, project: Project) -> list[Finding]:
        out: list[Finding] = []
        graph = _graph(project)
        for (path, _), fn in graph.items():
            if path != f.path or fn.reachable_via is None:
                continue
            tainted = _taint(fn)

            def hit(e) -> bool:
                return (any(isinstance(n, ast.Name) and n.id in tainted
                            for n in ast.walk(e))
                        and not _is_static(e))

            where = (f"in `{fn.qual}` (jit-reachable via "
                     f"`{fn.reachable_via}`)")
            for n in _stmt_walk(fn.node.body):
                if not isinstance(n, ast.Call):
                    continue
                if (isinstance(n.func, ast.Attribute)
                        and n.func.attr in ("item", "tolist")
                        and not n.args and hit(n.func.value)):
                    out.append(self.finding(
                        f, n,
                        f"`.{n.func.attr}()` on a traced value {where} "
                        f"— forces a host sync / trace error"))
                    continue
                dotted = f.dotted(n.func)
                if (isinstance(n.func, ast.Name)
                        and n.func.id in CONVERSIONS
                        and len(n.args) == 1 and hit(n.args[0])):
                    out.append(self.finding(
                        f, n,
                        f"`{n.func.id}()` on a traced value {where} — "
                        f"host conversion inside jit; use jnp casts or "
                        f"keep it in the array program"))
                elif (dotted in NUMPY_PULLS
                        and n.args and hit(n.args[0])):
                    out.append(self.finding(
                        f, n,
                        f"`{dotted.replace('numpy', 'np')}` on a traced "
                        f"value {where} — device->host pull inside jit; "
                        f"use jnp.asarray"))
        return out
