"""Per-function control-flow graphs for the dataflow rules.

`build_cfg(fn_node)` lowers one function body to basic blocks of
ATOMS — simple statements kept whole, compound statements decomposed
into their control expressions (an `if` contributes its test, a `for`
contributes the For node itself so transfer functions see the
target-from-iter binding, a `try` contributes nothing but edges).
Nested function/class definitions are single opaque atoms: a CFG never
crosses a scope boundary.

Edges model:

  * branches (`if`/`else`), loops (back edges, `break`/`continue`,
    `orelse`), `while`;
  * `try`/`except`/`else`/`finally`: every atom inside a `try` body
    gets an out-edge to each handler entry (an exception can interrupt
    the body at any statement, so handler in-states join the state at
    EVERY point of the body), handlers and the normal path route
    through `finally`;
  * exception exits: `raise` and a failing `assert` jump to the
    innermost enclosing handlers, or to the function's dedicated
    `raise_exit` block when uncaught — so "all paths out of the
    function" includes the paths an exception takes. Implicit
    exceptions from arbitrary calls are NOT modeled (every call site
    would otherwise be an edge, drowning the analysis in paths that
    cannot leak anything they did not already own).

Two virtual empty blocks terminate every CFG: `exit` (normal return or
falling off the end) and `raise_exit` (uncaught exception). Both are
real blocks so forward analyses observe the state on every way out.

Approximations (conservative for may-analyses, documented here so
rules don't re-derive them): `finally` bodies appear once and fall
through to both the normal continuation and the exception
continuation; `with` does not model `__exit__` suppressing exceptions;
`break`/`continue` bypass `finally` routing.
"""
from __future__ import annotations

import ast
import dataclasses

# statements that open a new scope: atoms, never descended into
SCOPE_STMTS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
SCOPE_BOUNDARY = SCOPE_STMTS + (ast.Lambda,)


@dataclasses.dataclass
class Block:
    bid: int
    atoms: list[ast.AST] = dataclasses.field(default_factory=list)
    succs: set[int] = dataclasses.field(default_factory=set)


@dataclasses.dataclass
class CFG:
    blocks: dict[int, Block]
    entry: int
    exit: int           # normal return / fall-off-the-end
    raise_exit: int     # uncaught exception leaves the function

    def preds(self) -> dict[int, set[int]]:
        out: dict[int, set[int]] = {b: set() for b in self.blocks}
        for b in self.blocks.values():
            for s in b.succs:
                out[s].add(b.bid)
        return out


def shallow_walk(node: ast.AST):
    """`ast.walk` that never crosses into a nested scope (function,
    lambda, class) — the expression-level view of one atom. The
    boundary node itself is yielded (so a nested `def` atom is
    visible), its body is not."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, SCOPE_BOUNDARY) and n is not node:
            continue
        if isinstance(n, SCOPE_BOUNDARY):
            # even as the root, a scope's body belongs to the inner CFG
            continue
        stack.extend(ast.iter_child_nodes(n))


def atom_bindings(atom: ast.AST) -> list[tuple[list[ast.AST], ast.AST | None]]:
    """(targets, value) pairs an atom binds: assignments, loop targets
    (bound from the iterable), `with ... as` names, except-handler
    names. Transfer functions use this instead of re-matching node
    types."""
    if isinstance(atom, ast.Assign):
        return [(list(atom.targets), atom.value)]
    if isinstance(atom, ast.AugAssign):
        return [([atom.target], atom.value)]
    if isinstance(atom, ast.AnnAssign):
        return [([atom.target], atom.value)] if atom.value is not None else []
    if isinstance(atom, (ast.For, ast.AsyncFor)):
        return [([atom.target], atom.iter)]
    if isinstance(atom, (ast.With, ast.AsyncWith)):
        return [([it.optional_vars], it.context_expr)
                for it in atom.items if it.optional_vars is not None]
    if isinstance(atom, ast.ExceptHandler) and atom.name:
        return [([ast.Name(id=atom.name, ctx=ast.Store())], None)]
    if isinstance(atom, (ast.NamedExpr,)):
        return [([atom.target], atom.value)]
    return []


class _Builder:
    def __init__(self):
        self.blocks: dict[int, Block] = {}
        self._n = 0
        self.exit = self._new().bid
        self.raise_exit = self._new().bid
        # innermost-first stacks
        self._handlers: list[list[int]] = []   # except-entry block ids
        self._loops: list[tuple[int, int]] = []  # (header, after)

    def _new(self) -> Block:
        b = Block(bid=self._n)
        self._n += 1
        self.blocks[b.bid] = b
        return b

    def _edge(self, a: int, b: int) -> None:
        self.blocks[a].succs.add(b)

    def _raise_targets(self) -> list[int]:
        return self._handlers[-1] if self._handlers else [self.raise_exit]

    # `cur` is the open block id; every method returns the open block
    # continuing the normal path, or None when the path terminated
    # (return/raise/break/continue).

    def _seq(self, stmts: list[ast.stmt], cur: int | None) -> int | None:
        for s in stmts:
            if cur is None:
                # unreachable code after return/raise: still built (a
                # rule may want its atoms) but disconnected
                cur = self._new().bid
            cur = self._stmt(s, cur)
        return cur

    def _stmt(self, s: ast.stmt, cur: int) -> int | None:
        in_try = bool(self._handlers)

        def put(atom: ast.AST, b: int) -> int:
            self.blocks[b].atoms.append(atom)
            if in_try:
                # the exception can fire at any atom: close the block
                # so its out-state reaches the handlers
                for h in self._handlers[-1]:
                    self._edge(b, h)
                nxt = self._new().bid
                self._edge(b, nxt)
                return nxt
            return b

        if isinstance(s, ast.Return):
            self.blocks[cur].atoms.append(s)
            self._edge(cur, self.exit)
            return None
        if isinstance(s, ast.Raise):
            self.blocks[cur].atoms.append(s)
            for t in self._raise_targets():
                self._edge(cur, t)
            return None
        if isinstance(s, ast.Assert):
            cur = put(s, cur)
            for t in self._raise_targets():
                self._edge(cur, t)
            nxt = self._new().bid
            self._edge(cur, nxt)
            return nxt
        if isinstance(s, ast.Break):
            if self._loops:
                self._edge(cur, self._loops[-1][1])
            return None
        if isinstance(s, ast.Continue):
            if self._loops:
                self._edge(cur, self._loops[-1][0])
            return None
        if isinstance(s, ast.If):
            cur = put(s.test, cur)
            after = self._new().bid
            then_end = self._seq(s.body, self._branch(cur))
            if then_end is not None:
                self._edge(then_end, after)
            if s.orelse:
                else_end = self._seq(s.orelse, self._branch(cur))
                if else_end is not None:
                    self._edge(else_end, after)
            else:
                self._edge(cur, after)
            return after
        if isinstance(s, ast.While):
            header = self._new().bid
            self._edge(cur, header)
            header = put(s.test, header)
            after = self._new().bid
            self._loops.append((header, after))
            body_end = self._seq(s.body, self._branch(header))
            self._loops.pop()
            if body_end is not None:
                self._edge(body_end, header)
            if s.orelse:
                else_end = self._seq(s.orelse, self._branch(header))
                if else_end is not None:
                    self._edge(else_end, after)
            else:
                self._edge(header, after)
            return after
        if isinstance(s, (ast.For, ast.AsyncFor)):
            header = self._new().bid
            self._edge(cur, header)
            header = put(s, header)   # the For node: target-from-iter
            after = self._new().bid
            self._loops.append((header, after))
            body_end = self._seq(s.body, self._branch(header))
            self._loops.pop()
            if body_end is not None:
                self._edge(body_end, header)
            if s.orelse:
                else_end = self._seq(s.orelse, self._branch(header))
                if else_end is not None:
                    self._edge(else_end, after)
            else:
                self._edge(header, after)
            return after
        if isinstance(s, (ast.With, ast.AsyncWith)):
            cur = put(s, cur)          # the With node: `as` bindings
            return self._seq(s.body, cur)
        if isinstance(s, ast.Try):
            return self._try(s, cur)
        if isinstance(s, ast.Match):
            # match: each case is a branch from the subject
            cur = put(s.subject, cur)
            after = self._new().bid
            for case in s.cases:
                end = self._seq(case.body, self._branch(cur))
                if end is not None:
                    self._edge(end, after)
            self._edge(cur, after)     # no case may match
            return after
        # simple statement (incl. nested def/class as opaque atoms)
        return put(s, cur)

    def _branch(self, frm: int) -> int:
        b = self._new()
        self._edge(frm, b.bid)
        return b.bid

    def _try(self, s: ast.Try, cur: int) -> int | None:
        after = self._new().bid
        # where does the normal/handled path continue? through finally
        if s.finalbody:
            fin_entry = self._new().bid
            fin_end = self._seq(s.finalbody, fin_entry)
            if fin_end is not None:
                self._edge(fin_end, after)
                # exception continuation: the finally also sits on the
                # propagation path out of the try
                for t in self._raise_targets():
                    self._edge(fin_end, t)
            done = fin_entry
        else:
            done = after
        handler_entries: list[int] = []
        handler_blocks: list[tuple[int, ast.ExceptHandler]] = []
        for h in s.handlers:
            hb = self._new()
            hb.atoms.append(h)         # binds `except E as name`
            handler_entries.append(hb.bid)
            handler_blocks.append((hb.bid, h))
        if not handler_entries and s.finalbody:
            # try/finally with no except: the finally entry IS the
            # exception target, so body exceptions route through it
            # (fin_end above already continues to the outer raise
            # targets as the propagation path)
            handler_entries = [done]
        if handler_entries:
            self._handlers.append(handler_entries)
        body_end = self._seq(s.body, self._branch(cur))
        if handler_entries:
            self._handlers.pop()
        if body_end is not None:
            body_end = self._seq(s.orelse, body_end)
        if body_end is not None:
            self._edge(body_end, done)
        for hb, h in handler_blocks:
            h_end = self._seq(h.body, self._branch(hb))
            if h_end is not None:
                self._edge(h_end, done)
        return after


def build_cfg(fn_node: ast.AST) -> CFG:
    """CFG of one function's body. `fn_node` is a FunctionDef /
    AsyncFunctionDef (or any node with a statement-list `body`)."""
    b = _Builder()
    entry = b._new().bid
    end = b._seq(list(fn_node.body), entry)
    if end is not None:
        b._edge(end, b.exit)
    return CFG(blocks=b.blocks, entry=entry, exit=b.exit,
               raise_exit=b.raise_exit)
