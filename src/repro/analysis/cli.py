"""`python -m repro.analysis [paths] [--format json]` — the CI gate.

Exit codes: 0 = no new unsuppressed findings (baselined ones are
reported but tolerated), 1 = new findings (or unparseable files, or —
under `--audit-suppressions` — a suppression without a rationale),
2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from repro.analysis.baseline import DEFAULT_BASELINE, Baseline
from repro.analysis.core import all_rules, analyze_project
from repro.analysis.findings import Finding
from repro.analysis.project import Project, suppression_sites
from repro.analysis.sarif import render_sarif

DEFAULT_PATHS = ["src", "tests", "benchmarks"]


def changed_files(root: Path | None = None) -> set[str] | None:
    """Repo-relative paths changed vs `merge-base(HEAD, origin/main)`,
    uncommitted and untracked files included. None when git (or the
    origin/main ref) is unavailable — callers fall back to a full
    run rather than silently analyzing nothing."""
    def run(*cmd: str):
        return subprocess.run(cmd, cwd=root, capture_output=True,
                              text=True, timeout=30)
    try:
        base = run("git", "merge-base", "HEAD", "origin/main")
        if base.returncode != 0:
            return None
        diff = run("git", "diff", "--name-only", base.stdout.strip())
        untracked = run("git", "ls-files", "--others",
                        "--exclude-standard")
        if diff.returncode != 0 or untracked.returncode != 0:
            return None
    except (OSError, subprocess.SubprocessError):
        return None
    return {p for p in (diff.stdout + untracked.stdout).splitlines()
            if p.strip()}


def _audit_suppressions(project: Project) -> int:
    sites = [(path, s) for path, f in sorted(project.files.items())
             for s in suppression_sites(f.source)]
    missing = 0
    for path, s in sites:
        why = s.rationale or "(no rationale)"
        print(f"{path}:{s.line}  allow[{', '.join(s.rules)}]  {why}")
        if not s.rationale:
            missing += 1
    print(f"{len(sites)} suppression site(s), {missing} without "
          f"rationale")
    return 1 if missing else 0


def _render_json(result, new, baselined, stale, rules) -> str:
    return json.dumps({
        "version": 1,
        "n_files": result.n_files,
        "rules": [{"id": r.id, "description": r.description}
                  for r in rules],
        "new": [f.to_dict() for f in new],
        "baselined": [f.to_dict() for f in baselined],
        "suppressed": [f.to_dict() for f in result.suppressed],
        "stale_baseline": [list(e.key()) for e in stale],
    }, indent=2)


def _render_text(result, new, baselined, stale) -> str:
    lines = [str(f) for f in new]
    if baselined:
        lines.append(f"-- {len(baselined)} baselined finding(s) "
                     f"(grandfathered, not failing):")
        lines.extend(f"   {f}" for f in baselined)
    if stale:
        lines.append(f"-- {len(stale)} stale baseline entr"
                     f"{'y' if len(stale) == 1 else 'ies'} (fixed; "
                     f"prune with --write-baseline):")
        lines.extend(f"   {e.rule} {e.path}:{e.line}" for e in stale)
    lines.append(
        f"{result.n_files} files: {len(new)} new finding(s), "
        f"{len(baselined)} baselined, {len(result.suppressed)} "
        f"suppressed")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("AST-based contract checker for the serve-layer "
                     "invariants (RNG discipline, virtual clock, "
                     "jit/host-sync hazards, registry namespaces)."))
    parser.add_argument("paths", nargs="*", default=None,
                        help=f"files/dirs to analyze "
                             f"(default: {' '.join(DEFAULT_PATHS)})")
    parser.add_argument("--format", choices=("text", "json", "sarif"),
                        default="text")
    parser.add_argument("--changed-only", action="store_true",
                        help="report findings only for files changed "
                             "vs merge-base(HEAD, origin/main) — the "
                             "pre-commit mode; the cross-file indexes "
                             "still see the whole tree. Falls back to "
                             "a full run outside a git checkout")
    parser.add_argument("--audit-suppressions", action="store_true",
                        help="list every `# repro: allow[...]` site "
                             "with its rationale; exit 1 if any site "
                             "lacks one")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline file of audited grandfathered "
                             "findings (missing file = empty baseline)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write the current unsuppressed findings "
                             "to the baseline file and exit 0")
    parser.add_argument("--out", default=None,
                        help="also write the report to this file")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    rules = all_rules()
    if args.list_rules:
        for r in rules:
            print(f"{r.id}: {r.description}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if Path(p).exists()]
    if not paths:
        print("no paths to analyze", file=sys.stderr)
        return 2
    project = Project.from_paths(paths)

    if args.audit_suppressions:
        return _audit_suppressions(project)

    result = analyze_project(project, rules)

    if args.changed_only:
        changed = changed_files()
        if changed is None:
            print("--changed-only: no usable git checkout, running "
                  "on the full tree", file=sys.stderr)
        else:
            result.findings = [f for f in result.findings
                               if f.path in changed]
            result.suppressed = [f for f in result.suppressed
                                 if f.path in changed]

    if args.write_baseline:
        Baseline.save(args.baseline, result.findings)
        print(f"wrote {len(result.findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    baseline = Baseline.load(args.baseline)
    new, baselined, stale = baseline.split(result.findings)

    if args.format == "json":
        report = _render_json(result, new, baselined, stale, rules)
    elif args.format == "sarif":
        report = render_sarif(new, baselined, rules)
    else:
        report = _render_text(result, new, baselined, stale)
    print(report)
    if args.out:
        Path(args.out).write_text(report + "\n")
    return 1 if new else 0


def run_paths(paths: list[str],
              baseline: str | None = None
              ) -> tuple[list[Finding], list[Finding]]:
    """Library entry point used by tests: (new, suppressed) for a set
    of real paths, optionally against a baseline file."""
    project = Project.from_paths(paths)
    result = analyze_project(project)
    new, _, _ = Baseline.load(baseline).split(result.findings)
    return new, result.suppressed
