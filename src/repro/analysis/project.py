"""Project model: the file set one analysis run sees, plus the shared
cross-file indexes rules consult.

A `Project` owns a set of parsed `FileInfo`s and lazily builds:

  * per-file import-alias maps (`FileInfo.aliases`) so rules can
    resolve `jnp.asarray` / `from time import perf_counter` back to
    canonical dotted names (`jax.numpy.asarray`, `time.perf_counter`);
  * a module -> {NAME: "literal"} table of module-level string
    constants, so a registry key published as `sampler.N_SAMPLED_KEY`
    resolves to its literal value across files;
  * the jit surface: names passed to `jax.jit(f)` directly, factory
    names whose RETURN value is jitted (`jax.jit(make_decode(cfg))`),
    and kernel names handed to `pallas_call` — the roots the
    host-sync-in-jit rule grows its call graph from.

Pure stdlib (`ast` only): the analyzer must import nothing from the
code under analysis, so it runs in CI without jax installed.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import re
from pathlib import Path

ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9_\-*,\s]+)\]")
_COMMENT_ONLY_RE = re.compile(r"^\s*#")


def parse_suppressions(source: str) -> dict[int, set[str]]:
    """Map line number -> rule ids suppressed there.

    `# repro: allow[rule-id]` (comma-separated ids allowed) suppresses
    findings on its own line; when the comment stands on a line of its
    own, it suppresses the next non-comment line instead (so a
    suppression can carry an explanation block above the flagged
    statement)."""
    lines = source.splitlines()
    eff: dict[int, set[str]] = {}
    for i, text in enumerate(lines, 1):
        m = ALLOW_RE.search(text)
        if m is None:
            continue
        ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
        if _COMMENT_ONLY_RE.match(text):
            j = i + 1
            while j <= len(lines) and _COMMENT_ONLY_RE.match(lines[j - 1]):
                j += 1
            eff.setdefault(j, set()).update(ids)
        else:
            eff.setdefault(i, set()).update(ids)
    return eff


@dataclasses.dataclass(frozen=True)
class SuppressionSite:
    """One `# repro: allow[...]` comment: where it sits, which line it
    suppresses, which rules, and the human rationale next to it."""

    line: int                 # line of the allow comment itself
    target_line: int          # code line the suppression applies to
    rules: tuple[str, ...]
    rationale: str            # "" when the author gave no reason


def suppression_sites(source: str) -> list[SuppressionSite]:
    """Every allow comment in a file, with its rationale text: for a
    same-line suppression, whatever follows the `]`; for a comment-
    block suppression, the other comment lines of the contiguous block
    (the shape `parse_suppressions` targets at the next code line)."""
    lines = source.splitlines()
    sites: list[SuppressionSite] = []
    for i, text in enumerate(lines, 1):
        m = ALLOW_RE.search(text)
        if m is None:
            continue
        ids = tuple(sorted(s.strip() for s in m.group(1).split(",")
                           if s.strip()))
        trailing = text[m.end():].strip().lstrip("-: ").strip()
        if not _COMMENT_ONLY_RE.match(text):
            sites.append(SuppressionSite(line=i, target_line=i,
                                         rules=ids, rationale=trailing))
            continue
        start = i
        while start > 1 and _COMMENT_ONLY_RE.match(lines[start - 2]):
            start -= 1
        target = i + 1
        while (target <= len(lines)
               and _COMMENT_ONLY_RE.match(lines[target - 1])):
            target += 1
        parts = [lines[k - 1].strip().lstrip("#").strip()
                 for k in range(start, target) if k != i]
        rationale = " ".join(p for p in parts + [trailing] if p)
        sites.append(SuppressionSite(line=i, target_line=target,
                                     rules=ids, rationale=rationale))
    return sites


def module_for_path(path: str) -> str:
    """Best-effort dotted module name for a repo-relative path
    (`src/repro/serve/engine.py` -> `repro.serve.engine`)."""
    p = path.replace("\\", "/")
    if p.startswith("src/"):
        p = p[len("src/"):]
    if p.endswith(".py"):
        p = p[:-3]
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    return p.replace("/", ".")


def _resolve_relative(module: str, level: int, name: str | None) -> str:
    """Resolve a `from ..x import y`-style base against `module`."""
    parts = module.split(".")
    base = parts[: max(len(parts) - level, 0)]
    if name:
        base.append(name)
    return ".".join(base)


def import_aliases(tree: ast.AST, module: str) -> dict[str, str]:
    """Local name -> canonical dotted origin, from every import in the
    file (any nesting level)."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom):
            base = (node.module or "") if node.level == 0 else \
                _resolve_relative(module, node.level, node.module)
            for a in node.names:
                if a.name == "*":
                    continue
                target = f"{base}.{a.name}" if base else a.name
                aliases[a.asname or a.name] = target
    return aliases


def dotted_name(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Canonical dotted name of a Name/Attribute chain, resolving the
    leftmost segment through the file's import aliases. None for
    anything that is not a plain dotted chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(aliases.get(node.id, node.id))
    return ".".join(reversed(parts))


@dataclasses.dataclass
class FileInfo:
    """One parsed source file. `tree` is None when the file failed to
    parse (the analyzer reports that as a finding instead of dying)."""

    path: str
    source: str
    tree: ast.Module | None
    module: str
    suppressions: dict[int, set[str]]
    parse_error: str | None = None
    _aliases: dict[str, str] | None = None
    _parents: dict[int, ast.AST] | None = None

    @property
    def aliases(self) -> dict[str, str]:
        if self._aliases is None:
            self._aliases = (import_aliases(self.tree, self.module)
                             if self.tree is not None else {})
        return self._aliases

    def parent(self, node: ast.AST) -> ast.AST | None:
        """Syntactic parent of `node` (built lazily, once per file)."""
        if self._parents is None:
            self._parents = {}
            if self.tree is not None:
                for parent in ast.walk(self.tree):
                    for child in ast.iter_child_nodes(parent):
                        self._parents[id(child)] = parent
        return self._parents.get(id(node))

    def dotted(self, node: ast.AST) -> str | None:
        return dotted_name(node, self.aliases)


def _load(path: str, source: str) -> FileInfo:
    module = module_for_path(path)
    try:
        tree = ast.parse(source, filename=path)
        err = None
    except SyntaxError as e:
        tree, err = None, f"{e.msg} (line {e.lineno})"
    return FileInfo(path=path, source=source, tree=tree, module=module,
                    suppressions=parse_suppressions(source),
                    parse_error=err)


# Directory names never descended into when collecting files.
EXCLUDED_DIRS = {"__pycache__", "analysis_fixtures", ".git", ".venv",
                 "node_modules", ".ruff_cache", ".pytest_cache"}


def collect_py_files(paths: list[str], root: Path | None = None
                     ) -> list[Path]:
    root = root or Path.cwd()
    out: list[Path] = []
    for p in paths:
        path = Path(p) if Path(p).is_absolute() else root / p
        if path.is_file() and path.suffix == ".py":
            out.append(path)
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if not EXCLUDED_DIRS.intersection(f.parts):
                    out.append(f)
    return sorted(set(out))


class Project:
    """The analyzed file set plus lazily-built cross-file indexes."""

    def __init__(self, files: dict[str, FileInfo]):
        self.files = files

    @classmethod
    def from_paths(cls, paths: list[str], root: Path | None = None
                   ) -> "Project":
        root = root or Path.cwd()
        files: dict[str, FileInfo] = {}
        for f in collect_py_files(paths, root):
            try:
                rel = f.relative_to(root).as_posix()
            except ValueError:
                rel = f.as_posix()
            files[rel] = _load(rel, f.read_text())
        return cls(files)

    @classmethod
    def from_sources(cls, sources: dict[str, str]) -> "Project":
        """Build from in-memory {path: source} — the fixture-test entry
        point (paths may be virtual, e.g. `src/repro/serve/x.py`)."""
        return cls({p: _load(p, s) for p, s in sources.items()})

    # -- cross-file indexes --------------------------------------------------

    @functools.cached_property
    def constants(self) -> dict[str, dict[str, str]]:
        """module -> {NAME: value} for module-level string constants."""
        out: dict[str, dict[str, str]] = {}
        for f in self.files.values():
            if f.tree is None:
                continue
            consts: dict[str, str] = {}
            for node in f.tree.body:
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, str)):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            consts[t.id] = node.value.value
                elif (isinstance(node, ast.AnnAssign)
                        and isinstance(node.target, ast.Name)
                        and isinstance(node.value, ast.Constant)
                        and isinstance(node.value.value, str)):
                    consts[node.target.id] = node.value.value
            out[f.module] = consts
        return out

    def lookup_constant(self, f: FileInfo, node: ast.AST) -> str | None:
        """Resolve a Name/Attribute to a module-level string constant
        anywhere in the project (via the file's import aliases)."""
        if isinstance(node, ast.Name):
            val = self.constants.get(f.module, {}).get(node.id)
            if val is not None:
                return val
        dotted = f.dotted(node)
        if dotted is None or "." not in dotted:
            return None
        mod, name = dotted.rsplit(".", 1)
        return self.constants.get(mod, {}).get(name)

    @functools.cached_property
    def jit_surface(self) -> dict[str, set]:
        """The project's jit boundary:

        factories — simple names f where `jax.jit(f(...))` appears, OR
                    `jax.jit(x)` where x was assigned `x = f(...)`:
                    the factory's RETURNED inner function is the
                    traced code
        wrapped   — (module, name) pairs for `jax.jit(f)` where f is a
                    plain function reference (module-exact, so a local
                    variable named `step` in one file cannot mark
                    unrelated `step` functions elsewhere)
        kernels   — (module, name) pairs for `pallas_call(f, ...)`
        methods   — bare method names for `jax.jit(self._m)` and
                    `jax.jit(functools.partial(self._m, ...))` — the
                    receiver class cannot be resolved statically, so
                    consumers match these by name against class
                    methods only (documented over-approximation)

        `functools.partial` chains are followed to the underlying
        callable at any depth (`jax.jit(partial(partial(f, a), b))`
        marks f, not "partial"), including through simple local
        bindings (`step = functools.partial(f, cfg); jax.jit(step)`).
        """
        factories: set[str] = set()
        wrapped: set[tuple[str, str]] = set()
        kernels: set[tuple[str, str]] = set()
        methods: set[str] = set()

        def exact(f: FileInfo, node: ast.AST) -> tuple[str, str] | None:
            dotted = f.dotted(node)
            if dotted is None:
                return None
            if "." in dotted:
                return tuple(dotted.rsplit(".", 1))
            return (f.module, dotted)

        for f in self.files.values():
            if f.tree is None:
                continue
            # name -> the Call it was assigned from, for simple
            # `x = f(...)` bindings: a jitted variable holding a
            # factory product counts as a jitted factory call, and a
            # jitted variable holding a partial is followed through
            assigned_call: dict[str, ast.Call] = {}
            for node in ast.walk(f.tree):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Call)):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            assigned_call[t.id] = node.value

            def classify(arg: ast.AST, depth: int = 0) -> None:
                """Record the callable expression handed to jax.jit."""
                if depth > 8:
                    return
                if isinstance(arg, ast.Call):
                    callee = f.dotted(arg.func)
                    if callee == "functools.partial" and arg.args:
                        classify(arg.args[0], depth + 1)
                    elif callee:
                        factories.add(callee.rsplit(".", 1)[-1])
                elif isinstance(arg, ast.Name) and arg.id in assigned_call:
                    classify(assigned_call[arg.id], depth + 1)
                elif (isinstance(arg, ast.Attribute)
                        and isinstance(arg.value, ast.Name)
                        and arg.value.id == "self"):
                    methods.add(arg.attr)
                else:
                    pair = exact(f, arg)
                    if pair:
                        wrapped.add(pair)

            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                dotted = f.dotted(node.func)
                if dotted == "jax.jit" and node.args:
                    classify(node.args[0])
                elif (dotted is not None
                        and (dotted == "pallas_call"
                             or dotted.endswith(".pallas_call"))
                        and node.args):
                    pair = exact(f, node.args[0])
                    if pair:
                        kernels.add(pair)
        return {"wrapped": wrapped, "factories": factories,
                "kernels": kernels, "methods": methods}
