"""Committed audited baseline of grandfathered findings.

The gate is RATCHETING: a finding whose `(rule, path, line)` identity
appears in the baseline is reported but does not fail the run; any
other finding is NEW and fails it. Fixing a baselined finding leaves a
STALE entry behind, which the CLI reports so the baseline can be
re-written (`--write-baseline`) and shrink monotonically — it must
never grow without an explicit audit.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.analysis.findings import Finding

BASELINE_VERSION = 1
DEFAULT_BASELINE = "analysis-baseline.json"


@dataclasses.dataclass
class Baseline:
    entries: list[Finding]
    path: str | None = None

    @property
    def keys(self) -> set[tuple[str, str, int]]:
        return {e.key() for e in self.entries}

    @classmethod
    def load(cls, path: str | Path | None) -> "Baseline":
        """Load a baseline file; a missing path is an empty baseline
        (every finding is new)."""
        if path is None or not Path(path).is_file():
            return cls(entries=[], path=str(path) if path else None)
        data = json.loads(Path(path).read_text())
        if data.get("version") != BASELINE_VERSION:
            raise ValueError(
                f"unsupported baseline version {data.get('version')!r} "
                f"in {path}")
        return cls(entries=[Finding.from_dict(d)
                            for d in data.get("findings", [])],
                   path=str(path))

    @staticmethod
    def save(path: str | Path, findings: list[Finding]) -> None:
        payload = {
            "version": BASELINE_VERSION,
            "comment": ("Audited grandfathered findings for "
                        "`python -m repro.analysis`. Entries may only "
                        "be REMOVED (fix the finding, re-run with "
                        "--write-baseline); adding one requires an "
                        "explicit audit in the PR that does it."),
            "findings": [f.to_dict() for f in sorted(findings)],
        }
        Path(path).write_text(json.dumps(payload, indent=2) + "\n")

    def split(self, findings: list[Finding]
              ) -> tuple[list[Finding], list[Finding], list[Finding]]:
        """(new, baselined, stale): findings not in the baseline,
        findings covered by it, and baseline entries that no longer
        fire (candidates for pruning)."""
        known = self.keys
        new = [f for f in findings if f.key() not in known]
        baselined = [f for f in findings if f.key() in known]
        live = {f.key() for f in findings}
        stale = [e for e in self.entries if e.key() not in live]
        return new, baselined, stale
