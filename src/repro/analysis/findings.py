"""Finding model for the static contract checker.

A `Finding` is one rule violation at one source location. Identity for
baseline matching is `(rule, path, line)` — messages may be reworded
without invalidating a committed baseline, but a finding that moves
(file renamed, line shifted) counts as NEW and must be re-audited.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation: `path:line:col: rule message`."""

    path: str          # posix-normalized, repo-relative
    line: int          # 1-indexed
    col: int           # 0-indexed (ast col_offset)
    rule: str          # rule id, e.g. "wall-clock-in-serve"
    message: str

    def key(self) -> tuple[str, str, int]:
        """Baseline identity (message excluded — see module docstring)."""
        return (self.rule, self.path, self.line)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}

    @classmethod
    def from_dict(cls, d: dict) -> "Finding":
        return cls(path=d["path"], line=int(d["line"]),
                   col=int(d.get("col", 0)), rule=d["rule"],
                   message=d.get("message", ""))

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"
