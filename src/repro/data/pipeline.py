"""Deterministic synthetic data pipeline.

Design constraints (DESIGN.md §5, fault tolerance):
  * stateless-deterministic: batch t is a pure function of (seed, t) — a
    restarted job regenerates the identical stream with no reader state to
    checkpoint; elastic rescaling re-shards the same stream.
  * per-host sharding: each data-parallel host slices its rows from the
    global batch by fold_in(host_id), so no two hosts read the same rows.

Two generators:
  * `make_batch` — language-model-shaped random tokens with a Zipf-ish
    marginal (realistic embedding-gather patterns for benches).
  * `synthetic_task_batch` — *learnable* tasks for the accuracy ladder
    (Table IV reproduction): copy / reverse / sort / modular addition.
    These give a real accuracy axis against which exact / int8 / artemis
    arithmetic is compared.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import frontend
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    seq_len: int = 1024
    global_batch: int = 8
    task: str = "lm"            # lm | copy | reverse | sort | modadd
    host_id: int = 0
    n_hosts: int = 1


def _zipf_tokens(key, shape, vocab: int) -> jax.Array:
    """Zipf-ish marginal over the vocab (heavy head, long tail)."""
    u = jax.random.uniform(key, shape, jnp.float32, 1e-6, 1.0)
    # inverse-CDF of p(k) ~ 1/(k+10): k = exp(u * log(V)) - like skew
    r = jnp.exp(u * jnp.log(float(vocab))) - 1.0
    return jnp.clip(r.astype(jnp.int32), 0, vocab - 1)


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int) -> dict:
    """Batch t as a pure function of (seed, step, host)."""
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(dcfg.seed), step),
        dcfg.host_id)
    rows = dcfg.global_batch // dcfg.n_hosts
    kt, kp = jax.random.split(key)
    shape = frontend.token_shape(cfg, rows, dcfg.seq_len)
    tokens = _zipf_tokens(kt, shape, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": _shift_labels(tokens)}
    if cfg.modality == "vlm":
        batch["prefix_embeds"] = frontend.synth_prefix_embeds(kp, cfg, rows)
    return batch


def _shift_labels(tokens: jax.Array) -> jax.Array:
    """Next-token labels (last position predicts a pad 0)."""
    return jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)


# ---------------------------------------------------------------------------
# learnable tasks for the accuracy ladder (benchmarks/table4_accuracy.py)
# ---------------------------------------------------------------------------

SEP = 1  # separator token id; 0 is pad


def synthetic_task_batch(key, task: str, batch: int, n: int,
                         vocab: int) -> tuple[jax.Array, jax.Array]:
    """Returns (tokens (B, S), loss_mask (B, S)) for sequence tasks.

    Layout: [src tokens, SEP, tgt tokens]; loss is masked to the tgt span.
    Payload tokens are drawn from [2, vocab).
    """
    src = jax.random.randint(key, (batch, n), 2, vocab, dtype=jnp.int32)
    if task == "copy":
        tgt = src
    elif task == "reverse":
        tgt = src[:, ::-1]
    elif task == "sort":
        tgt = jnp.sort(src, axis=1)
    elif task == "modadd":
        # tgt_i = (src_i + src_{i-1}) mod (vocab-2) + 2
        prev = jnp.roll(src, 1, axis=1).at[:, 0].set(0)
        tgt = (src - 2 + prev - 2) % (vocab - 2) + 2
    else:
        raise ValueError(task)
    sep = jnp.full((batch, 1), SEP, jnp.int32)
    tokens = jnp.concatenate([src, sep, tgt], axis=1)
    mask = jnp.concatenate(
        [jnp.zeros((batch, n + 1), jnp.float32),
         jnp.ones((batch, n), jnp.float32)], axis=1)
    return tokens, mask


def batch_iterator(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0):
    """Infinite deterministic batch stream, resumable at any step."""
    step = start_step
    while True:
        yield step, make_batch(cfg, dcfg, step)
        step += 1
