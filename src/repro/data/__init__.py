from repro.data.pipeline import (
    DataConfig,
    batch_iterator,
    make_batch,
    synthetic_task_batch,
)

__all__ = ["DataConfig", "make_batch", "batch_iterator",
           "synthetic_task_batch"]
