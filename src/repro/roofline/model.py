"""Three-term roofline model over the compiled dry-run artifact.

Per (arch × shape × mesh) cell (brief §Roofline):

  compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
  memory term     = HLO_bytes   / (chips × HBM_bw)
  collective term = coll_bytes  / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from `compiled.cost_analysis()` (whole-program,
all devices — divided by chips here). collective_bytes comes from the HLO
parser (already per-device operand bytes; wire-weighted variant reported
too). The dominant term is the bottleneck; MODEL_FLOPS/HLO_FLOPs measures
how much compiled compute is 'useful' (remat/dequant-emulation waste).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig
from repro.roofline.hlo import CollectiveStats


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float      # per chip, FLOP/s (bf16)
    hbm_bw: float          # per chip, B/s
    ici_bw: float          # per link, B/s
    ici_links: int = 4     # v5e: 4 links per chip (2D torus x2 directions)
    hbm_gib: float = 16.0  # per chip HBM capacity


# brief-specified constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
HW_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float           # whole program
    hlo_bytes: float           # whole program (HBM traffic estimate)
    coll_bytes: float          # per-device operand bytes
    coll_wire_bytes: float     # ring-weighted per-device
    model_flops: float         # 6·N_active·D useful flops
    peak_bytes_per_device: float  # from memory_analysis
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower bound assuming perfect overlap: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_fraction(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        useful FLOPs / (chips · peak · step_time)."""
        if self.step_time <= 0:
            return 0.0
        return self.t_compute / self.step_time * self.useful_flop_fraction

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_mbytes": self.coll_bytes / 1e6,
            "model_gflops": self.model_flops / 1e9,
            "useful_flop_frac": self.useful_flop_fraction,
            "roofline_frac": self.roofline_fraction,
            "bytes_per_device_gib": self.peak_bytes_per_device / 2**30,
        }


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, coll: CollectiveStats, mflops: float,
            peak_bytes: float, hw: HardwareSpec = HW_V5E) -> RooflineReport:
    """NOTE on units: `compiled.cost_analysis()` reports the PARTITIONED
    (per-device SPMD) program — flops/bytes are already per-chip (verified
    empirically: a (4,2)-sharded matmul reports total/8). The HLO collective
    parse is per-device operand bytes for the same reason. `chips` is used
    only to convert whole-model useful FLOPs to per-chip."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=float(coll.raw_bytes),
        coll_wire_bytes=float(coll.wire_bytes),
        model_flops=mflops / chips, peak_bytes_per_device=peak_bytes)
    rep.t_compute = flops / hw.peak_flops
    rep.t_memory = byts / hw.hbm_bw
    # collective bytes are per-device; each chip drives ici_links links
    # concurrently (ring collectives on a 2D torus use all of them)
    rep.t_collective = rep.coll_wire_bytes / (hw.ici_bw * hw.ici_links)
    return rep


def model_flops(cfg: ModelConfig, n_tokens: int, kind: str,
                kv_len: int = 0) -> float:
    """Useful FLOPs: 6·N_active·D for training, 2·N_active·D for inference
    (+ attention score/value FLOPs, which 6ND omits)."""
    n_active = cfg.active_param_count()
    per_token = 2.0 * n_active
    # attention quadratic term (omitted by 2ND): 4·H·hd·context FLOPs per
    # token per layer (QK^T + PV, 2 FLOPs each), halved for causal prefill
    if cfg.family in ("dense", "moe"):
        h, hd = cfg.n_heads, cfg.resolved_head_dim
        context = kv_len if kind == "decode" else (kv_len or 1) / 2.0
        per_token += 4.0 * h * hd * context * cfg.n_layers
    mult = 3.0 if kind == "train" else 1.0   # fwd+bwd = 3x fwd
    return per_token * n_tokens * mult
