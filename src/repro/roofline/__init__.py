from repro.roofline.hlo import collective_bytes, parse_collectives
from repro.roofline.model import (
    HW_V5E,
    HardwareSpec,
    RooflineReport,
    analyze,
    model_flops,
)

__all__ = ["collective_bytes", "parse_collectives", "HardwareSpec",
           "HW_V5E", "RooflineReport", "analyze", "model_flops"]
