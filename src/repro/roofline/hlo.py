"""HLO collective-bytes parser.

`cost_analysis()` does not expose collective traffic, so we parse the
compiled (post-SPMD-partitioning, per-device) HLO text. Compiled HLO
writes operands as bare refs (`all-reduce(%dot)`), so sizes are derived
from each collective's OUTPUT shape(s) plus the replica-group size S:

  op                  operand bytes      ring wire bytes / device
  all-reduce          out                2·(S-1)/S·out
  all-gather          out / S            (S-1)/S·out
  reduce-scatter      out · S            (S-1)/S·out·S
  all-to-all          out                (S-1)/S·out
  collective-permute  out                out

Variadic (combined) collectives have tuple outputs — all elements are
summed. Async pairs (`-start`/`-done`) are counted once at `-start`.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = f32[8,128]{1,0} all-reduce(...)` or
# `%name = (f32[..], f32[..]) all-gather-start(...)`
_LINE_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[\w\[\]{},]+)\s+"
    r"(?P<kind>" + "|".join(_COLLECTIVES) + r")"
    r"(?P<variant>-start|-done)?\(")
# iota form `replica_groups=[4,2]<=[8]` -> group size 2;
# explicit form `replica_groups={{0,1},{2,3}}` -> len of first group
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        size = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * size
    return total


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


@dataclasses.dataclass
class CollectiveStats:
    ops: dict            # kind -> count
    bytes_by_kind: dict  # kind -> operand bytes (per device)
    raw_bytes: int       # total operand bytes (the brief's metric)
    wire_bytes: float    # ring-algorithm bytes on the wire per device

    def summary(self) -> str:
        parts = [f"{k}:{v} ({self.bytes_by_kind.get(k, 0)/1e6:.1f}MB)"
                 for k, v in sorted(self.ops.items())]
        return ", ".join(parts) or "none"


def parse_collectives(hlo_text: str) -> CollectiveStats:
    ops: dict = defaultdict(int)
    by_kind: dict = defaultdict(float)
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        if m.group("variant") == "-done":
            continue
        kind = m.group("kind")
        out_bytes = _shapes_bytes(m.group("out"))
        s = _group_size(line)
        if s <= 1:
            continue  # degenerate group: no traffic
        ops[kind] += 1
        frac = (s - 1) / s
        if kind == "all-reduce":
            operand, w = out_bytes, 2.0 * frac * out_bytes
        elif kind == "all-gather":
            operand, w = out_bytes / s, frac * out_bytes
        elif kind == "reduce-scatter":
            operand, w = out_bytes * s, frac * out_bytes * s
        elif kind == "all-to-all":
            operand, w = out_bytes, frac * out_bytes
        else:  # collective-permute
            operand, w = out_bytes, float(out_bytes)
        by_kind[kind] += operand
        wire += w
    raw = int(sum(by_kind.values()))
    return CollectiveStats(dict(ops), dict(by_kind), raw, wire)


def collective_bytes(hlo_text: str) -> int:
    return parse_collectives(hlo_text).raw_bytes
