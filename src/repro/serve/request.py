"""Request lifecycle for the serving engine.

State machine:

    QUEUED -> PREFILL -> DECODE -> DONE
       ^                   |
       +---- (preempt) ----+

Prefill is CHUNKED: a request can sit in PREFILL across many engine
steps, `prefill_pos` marking how many tokens of its effective prompt
the backend has absorbed (written to paged K/V, or folded into a
recurrent state slot). `seq_len` counts the tokens the backend's
device state currently covers. Everything else the backend needs to
serve the request — page tables, refcounted shared prefixes, a state
slot id — lives in `mem`, an opaque object owned by the engine's
`SequenceBackend` (see repro.serve.backend): the engine and scheduler
never look inside it.

A preempted request (from either PREFILL or DECODE) is re-queued in
*recompute* style: its prompt becomes original-prompt +
tokens-generated-so-far, the backend releases its `mem`, and a later
admission re-prefills from scratch — token-identical to never having
been preempted for greedy AND sampled requests alike (a sampled
request's RNG lane is keyed by `(seed, tokens generated so far)`, so
replay re-draws the same tokens — see repro.serve.sampler).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.serve.obs import PhaseAttribution


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration, threaded through
    `ServeEngine.submit()` into the `Request` and consumed by
    `repro.serve.sampler`.

    `temperature=0.0` is the greedy fast path: plain argmax, no RNG,
    `top_k`/`top_p` irrelevant — the semantics every pre-sampling
    token-identity suite pins. Any `temperature > 0` samples from the
    temperature-scaled, top-k- then top-p-truncated distribution on a
    per-request RNG lane keyed by `(seed, tokens generated so far)`,
    so a request's sampled stream is deterministic and independent of
    batch composition, chunking, scheduling, and preemption (the
    contract `sampler.py` documents and tests pin over both backends).
    """
    temperature: float = 0.0     # 0.0 = greedy argmax
    top_k: int = 0               # 0 = no truncation
    top_p: float = 1.0           # nucleus mass; 1.0 = no truncation
    seed: int = 0                # RNG-lane seed for sampled decode

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(
                f"top_p must be in (0, 1], got {self.top_p}")
        if not 0 <= self.seed < 2 ** 32:
            raise ValueError(
                f"seed must be a uint32 (0 <= seed < 2**32), got "
                f"{self.seed}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) i32 — original prompt
    max_new_tokens: int
    arrival_time: float = 0.0
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    state: RequestState = RequestState.QUEUED
    generated: list[int] = dataclasses.field(default_factory=list)
    mem: object | None = None        # backend-owned sequence memory
    #                                  (page table / state slot / ...)
    seq_len: int = 0                 # tokens covered by device state
    prefill_pos: int = 0             # effective-prompt tokens prefilled
    lane: int = -1                   # batch lane (prefill or decode), -1 = none
    n_preemptions: int = 0
    # metrics (virtual-clock seconds)
    t_first_token: float | None = None
    t_done: float | None = None
    # per-phase energy/time attribution: each executed step's ARTEMIS
    # price is split across participating lanes by token share
    # (repro.serve.obs.PhaseAttribution); recompute after preemption
    # re-attributes — energy spent is energy spent
    attr: PhaseAttribution = dataclasses.field(
        default_factory=PhaseAttribution)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def effective_prompt(self) -> np.ndarray:
        """Prompt for (re-)prefill: original prompt plus everything
        generated so far (recompute-style preemption recovery)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    def latency(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_time

    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_time
