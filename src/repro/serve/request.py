"""Request lifecycle for the serving engine.

State machine:

    QUEUED -> PREFILL -> DECODE -> DONE
       ^                   |
       +---- (preempt) ----+

Prefill is CHUNKED: a request can sit in PREFILL across many engine
steps, `prefill_pos` marking how many tokens of its effective prompt
are already written to the paged cache. With prefix sharing, admission
may find a leading run of the prompt already resident: `shared_len`
counts those tokens, `seq_len` covers them, and `prefill_pos` starts
past them (capped at prompt length - 1 so the last prompt token reruns
for its logits). A preempted request (from either PREFILL or DECODE)
is re-queued in *recompute* style: its prompt becomes original-prompt
+ tokens-generated-so-far, its page references are released (pages
other requests still share stay resident), `prefill_pos` and
`shared_len` reset to 0, and a later admission re-matches and
re-prefills — for greedy sampling this is token-identical to never
having been preempted.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) i32 — original prompt
    max_new_tokens: int
    arrival_time: float = 0.0
    state: RequestState = RequestState.QUEUED
    generated: list[int] = dataclasses.field(default_factory=list)
    pages: list[int] = dataclasses.field(default_factory=list)
    seq_len: int = 0                 # tokens currently in the paged cache
    prefill_pos: int = 0             # effective-prompt tokens prefilled
    shared_len: int = 0              # leading tokens resident via prefix
    #                                  sharing at admission: prefill skips
    #                                  their writes, seq_len covers them
    lane: int = -1                   # batch lane (prefill or decode), -1 = none
    n_preemptions: int = 0
    # metrics (virtual-clock seconds)
    t_first_token: float | None = None
    t_done: float | None = None

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens

    def effective_prompt(self) -> np.ndarray:
        """Prompt for (re-)prefill: original prompt plus everything
        generated so far (recompute-style preemption recovery)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)])

    def latency(self) -> float | None:
        if self.t_done is None:
            return None
        return self.t_done - self.arrival_time

    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_time
