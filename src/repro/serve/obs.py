"""Serve-layer observability: typed lifecycle events, a metrics
registry, span assembly, and Chrome trace-event export — all over the
VIRTUAL ARTEMIS clock.

Everything the engine knows about a run flows through two channels:

  Tracer     — the structured-event log. Every lifecycle transition
               (queued / admit / prefill chunk / decode round /
               preempt / COW fork / finish) and every scheduler
               decision is a frozen dataclass event carrying the
               request id, virtual timestamps, token counts, and the
               ARTEMIS cost/energy of the step that produced it. At
               `level="metrics"` (the default) events are counted but
               NOT retained — a drain allocates no per-event history;
               `level="trace"` retains the full log for span assembly
               and Perfetto export.
  MetricsRegistry — counters, gauges, and streaming histograms the
               engine, scheduler, both sequence backends, and the
               sampler publish into. Histograms tally values in a
               bounded value -> count map: percentiles are EXACT
               (nearest-rank over the multiset) while the number of
               distinct values stays under `max_bins`, after which the
               map collapses into log-spaced bins (~1.8% relative
               error at the default 64 bins/decade) — never an
               unbounded sample list.

Events remain BACKWARD-COMPATIBLE with the tuple event log they
replace: each event indexes and iterates like its legacy tuple
(`ev[0]` is the kind, `("share", rid, matched, ts)` unpacks as
before), so pre-obs consumers keep working unchanged.

Span assembly (`assemble_spans`) folds a trace-level event log into
per-request span trees — queued wait, each admit->finish/preempt
lifecycle attempt, and the per-step prefill/decode execution slices —
validating on the way that every admit is closed by a finish or
preempt, that slices nest inside their attempt, and that per-request
virtual timestamps are monotone. `to_chrome_trace` turns the same log
into Chrome trace-event JSON (one Perfetto thread per request over
the virtual clock); `validate_chrome_trace` checks the required
`ph`/`ts`/`pid`/`tid` fields, and

    python -m repro.serve.obs serve_trace.json

validates an exported file from the command line (CI runs this on the
per-run trace artifact).
"""
from __future__ import annotations

import dataclasses
import json
import math
import sys
from typing import ClassVar


def percentile(sorted_vals, p: float) -> float:
    """Nearest-rank percentile over an ascending-sorted sequence:
    element ceil(p/100 * n) of the 1-indexed list (so p50 of two values
    is the LOWER one, and p100 is the max — no off-by-one upward)."""
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    k = min(max(math.ceil(p / 100.0 * n), 1), n)
    return float(sorted_vals[k - 1])


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class Histogram:
    """Streaming histogram with exact nearest-rank percentiles under a
    bounded memory budget.

    Observations are tallied in a value -> count map. While the number
    of DISTINCT values stays at or under `max_bins`, percentiles are
    exact over the full multiset (identical to sorting every sample —
    virtual-clock latencies repeat heavily thanks to the simulator's
    round-based plateaus, so this is the common regime). Past the
    budget the map collapses once into log-spaced bins
    (`bins_per_decade` per decade, sign-preserving, 0 kept exact) and
    later observations land in bins too; count/sum/min/max stay exact
    forever, percentiles become bin-representative (~1.8% relative
    error at the default 64/decade). Memory is O(max_bins) always."""

    def __init__(self, max_bins: int = 4096, bins_per_decade: int = 64):
        if max_bins < 1:
            raise ValueError(f"max_bins must be >= 1, got {max_bins}")
        if bins_per_decade < 1:
            raise ValueError(
                f"bins_per_decade must be >= 1, got {bins_per_decade}")
        self.max_bins = max_bins
        self.bins_per_decade = bins_per_decade
        self.exact = True
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._counts: dict[float, int] = {}

    def _bin(self, v: float) -> float:
        if v == 0.0 or not math.isfinite(v):
            return v
        exp = round(math.log10(abs(v)) * self.bins_per_decade)
        return math.copysign(10.0 ** (exp / self.bins_per_decade), v)

    def observe(self, v, n: int = 1) -> None:
        v = float(v)
        n = int(n)
        if n < 1:
            raise ValueError(f"observation count must be >= 1, got {n}")
        self.n += n
        self.total += v * n
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        key = v if self.exact else self._bin(v)
        self._counts[key] = self._counts.get(key, 0) + n
        if self.exact and len(self._counts) > self.max_bins:
            self._collapse()

    def _collapse(self) -> None:
        binned: dict[float, int] = {}
        for v, c in self._counts.items():
            key = self._bin(v)
            binned[key] = binned.get(key, 0) + c
        self._counts = binned
        self.exact = False

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the tallied multiset — exact
        while `exact` holds, bin-representative after a collapse."""
        if self.n == 0:
            return 0.0
        k = min(max(math.ceil(p / 100.0 * self.n), 1), self.n)
        run = 0
        for v in sorted(self._counts):
            run += self._counts[v]
            if run >= k:
                return float(v)
        return float(self.vmax)   # unreachable; counts sum to n

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def values(self) -> list[float]:
        """The full sorted multiset (exact mode only — the collapsed
        map no longer knows the original samples)."""
        if not self.exact:
            raise RuntimeError(
                "histogram collapsed to bins; exact samples are gone")
        out: list[float] = []
        for v in sorted(self._counts):
            out.extend([v] * self._counts[v])
        return out

    def snapshot(self) -> dict:
        return {
            "count": self.n,
            "mean": self.mean(),
            "min": self.vmin if self.n else 0.0,
            "max": self.vmax if self.n else 0.0,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "exact": self.exact,
        }


class MetricsRegistry:
    """Counters, gauges, and streaming histograms under dotted/slashed
    names. Conventions used by the serve layer: `engine/...` for
    engine-level series, `scheduler/...`, `sampler/...`, and
    `backend/...` for backend-specific series (the only namespace
    allowed to differ between sequence backends — the conformance
    suite pins that every other key set is backend-independent)."""

    def __init__(self):
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    # counters ---------------------------------------------------------------

    def inc(self, name: str, v: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + v

    def count(self, name: str, default: float = 0) -> float:
        return self._counters.get(name, default)

    # gauges -----------------------------------------------------------------

    def set_gauge(self, name: str, v: float) -> None:
        self._gauges[name] = float(v)

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    # histograms -------------------------------------------------------------

    def observe(self, name: str, v, n: int = 1) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        h.observe(v, n)
        return h

    def hist(self, name: str) -> Histogram | None:
        return self._hists.get(name)

    # introspection ----------------------------------------------------------

    def keys(self) -> list[str]:
        return sorted(set(self._counters) | set(self._gauges)
                      | set(self._hists))

    def snapshot(self) -> dict:
        out: dict = {}
        for k, v in self._counters.items():
            out[k] = v
        for k, v in self._gauges.items():
            out[k] = v
        for k, h in self._hists.items():
            out[k] = h.snapshot()
        return dict(sorted(out.items()))


# ---------------------------------------------------------------------------
# typed lifecycle events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Event:
    """Base structured event. `ts` is VIRTUAL-clock seconds (the
    ARTEMIS cost model's simulated time), never wall time.

    Events index/iterate like the legacy tuples they replaced
    (`ev[0]` is the kind string, `("share", rid, matched, ts)` unpacks
    as before), so pre-obs consumers of the engine event log keep
    working. `counted` marks the kinds the legacy log retained — they
    increment the `engine/n_events` counter at every level, keeping
    step-count metrics identical whether or not events are kept."""

    ts: float
    kind: ClassVar[str] = "event"
    counted: ClassVar[bool] = True

    def legacy(self) -> tuple:
        return (self.kind, self.ts)

    def __getitem__(self, i):
        return self.legacy()[i]

    def __iter__(self):
        return iter(self.legacy())

    def __len__(self) -> int:
        return len(self.legacy())


@dataclasses.dataclass(frozen=True)
class QueuedEvent(Event):
    """Request entered the queue; `ts` is its ARRIVAL time (which may
    lie ahead of the clock at submission)."""
    rid: int = -1
    prompt_len: int = 0
    max_new_tokens: int = 0
    kind: ClassVar[str] = "queued"
    counted: ClassVar[bool] = False

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.ts)


@dataclasses.dataclass(frozen=True)
class AdmitEvent(Event):
    """Request took a batch lane and backend memory. One lifecycle
    attempt runs from here to the matching finish or preempt."""
    rid: int = -1
    lane: int = -1
    shared_tokens: int = 0       # prefix-share discount at admission
    kind: ClassVar[str] = "admit"
    counted: ClassVar[bool] = False

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.lane, self.ts)


@dataclasses.dataclass(frozen=True)
class ShareEvent(Event):
    """Admission matched `matched` resident prefix tokens (paged-KV
    backend). Legacy tuple: ("share", rid, matched, ts)."""
    rid: int = -1
    matched: int = 0
    kind: ClassVar[str] = "share"

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.matched, self.ts)


@dataclasses.dataclass(frozen=True)
class CowForkEvent(Event):
    """A write into a co-owned page forked it to a private copy.
    Legacy tuple: ("cow", rid, old_page, new_page, ts)."""
    rid: int = -1
    old_page: int = -1
    new_page: int = -1
    kind: ClassVar[str] = "cow"

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.old_page, self.new_page, self.ts)


@dataclasses.dataclass(frozen=True)
class PreemptEvent(Event):
    """Recompute-style preemption: memory released, request requeued.
    `reason` is the audit code for WHY ("decode_pressure" — a decode
    lane needed a write target; "prefill_funding" — an older prefill
    chunk claimed the memory). Legacy: ("preempt", rid, phase, ts)."""
    rid: int = -1
    phase: str = ""              # "prefill" | "decode"
    reason: str = "memory_pressure"
    kind: ClassVar[str] = "preempt"

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.phase, self.ts)


@dataclasses.dataclass(frozen=True)
class PreemptAllEvent(Event):
    """A step that executed nothing but preempted every lane — progress
    (the freed memory re-admits the victims), not a stall."""
    kind: ClassVar[str] = "preempt_all"


@dataclasses.dataclass(frozen=True)
class AdvanceEvent(Event):
    """Nothing runnable: the clock jumped to the next arrival (`ts` is
    the time jumped TO). Legacy tuple: ("advance", ts)."""
    kind: ClassVar[str] = "advance"


@dataclasses.dataclass(frozen=True)
class ExecStepEvent(Event):
    """One executed engine step. `ts` is the clock AFTER the step's
    advance; the step ran over [ts - dur_s, ts]. `price_ns` and
    `energy_pj` are the ArtemisCostModel's price for the step's
    composed `n_tokens` — the numbers per-request attribution splits
    across the participating lanes."""
    chunks: tuple = ()           # ((rid, n_tokens), ...) prefill plan
    decode_rids: tuple = ()      # rids that decoded one token
    n_tokens: int = 0
    dur_s: float = 0.0
    price_ns: float = 0.0
    energy_pj: float = 0.0

    @property
    def t_start(self) -> float:
        return self.ts - self.dur_s


@dataclasses.dataclass(frozen=True)
class PrefillStepEvent(ExecStepEvent):
    kind: ClassVar[str] = "prefill"

    def legacy(self) -> tuple:
        return (self.kind, self.chunks, self.ts)


@dataclasses.dataclass(frozen=True)
class DecodeStepEvent(ExecStepEvent):
    kind: ClassVar[str] = "decode"

    def legacy(self) -> tuple:
        return (self.kind, self.decode_rids, self.ts)


@dataclasses.dataclass(frozen=True)
class MixedStepEvent(ExecStepEvent):
    kind: ClassVar[str] = "mixed"

    def legacy(self) -> tuple:
        return (self.kind, self.chunks, self.decode_rids, self.ts)


@dataclasses.dataclass(frozen=True)
class ShardStepEvent(Event):
    """One mesh shard's share of an executed backend forward (sharded
    backends emit one per shard per prefill/decode forward, stamped
    with the clock at step START — the engine's exec-step event that
    follows carries the step's duration, so trace export renders the
    shard slices against that step's [t_start, ts] window). Span
    assembly ignores these (they are per-shard, not per-request);
    they surface as per-shard tracks in the Chrome trace."""
    shard: int = -1
    n_shards: int = 1
    phase: str = ""              # "prefill" | "decode"
    n_tokens: int = 0            # tokens this shard processed (TP:
    #                              every shard sees the full token
    #                              batch, a head/sequence slice each)
    kind: ClassVar[str] = "shard_step"
    counted: ClassVar[bool] = False

    def legacy(self) -> tuple:
        return (self.kind, self.shard, self.phase, self.ts)


@dataclasses.dataclass(frozen=True)
class FinishEvent(Event):
    """Request completed. Carries its final per-phase energy/time
    attribution so a trace alone reconstructs the cost story."""
    rid: int = -1
    n_generated: int = 0
    prefill_energy_J: float = 0.0
    decode_energy_J: float = 0.0
    sampling_energy_J: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    kind: ClassVar[str] = "finish"
    counted: ClassVar[bool] = False

    def legacy(self) -> tuple:
        return (self.kind, self.rid, self.ts)


@dataclasses.dataclass(frozen=True)
class DecisionEvent(Event):
    """Scheduler audit record for one decide(): the candidate
    compositions it priced (kind, n_tokens, price/token ns,
    energy/token pJ), what it chose and why, the chunk plan, and the
    admit/defer outcomes with the budget-probe numbers that drove
    them. Emitted at level="trace" only."""
    chosen: str = "idle"
    reason: str = ""
    candidates: tuple = ()       # ((kind, n_tokens, ns/tok, pJ/tok), ...)
    plan: tuple = ()             # ((rid, n_tokens), ...) chunk plan
    n_decode: int = 0
    admitted: tuple = ()         # ((rid, n_first_chunk), ...)
    deferred: tuple = ()         # ((rid, reason_code), ...)
    budget_free: int | None = None   # probe's free units before planning
    kind: ClassVar[str] = "decision"
    counted: ClassVar[bool] = False

    def legacy(self) -> tuple:
        return (self.kind, self.chosen, self.ts)


class Tracer:
    """One engine's observability hub: the metrics registry plus the
    level-gated structured event log.

    level="metrics" (default) — counters/gauges/histograms only; every
        emitted event is counted (legacy kinds bump `engine/n_events`)
        and immediately dropped, so a drain retains no per-event
        objects.
    level="trace" — additionally retains every event in order for span
        assembly and Chrome trace export.
    """

    LEVELS = ("metrics", "trace")

    def __init__(self, level: str = "metrics",
                 registry: MetricsRegistry | None = None):
        if level not in self.LEVELS:
            raise ValueError(
                f"observability level must be one of {self.LEVELS}, "
                f"got {level!r}")
        self.level = level
        self.registry = registry if registry is not None else MetricsRegistry()
        self.events: list[Event] = []

    @property
    def tracing(self) -> bool:
        return self.level == "trace"

    def emit(self, ev: Event) -> Event:
        if ev.counted:
            self.registry.inc("engine/n_events")
        if self.level == "trace":
            self.events.append(ev)
        return ev


# ---------------------------------------------------------------------------
# per-request energy / cost attribution
# ---------------------------------------------------------------------------

PHASES = ("prefill", "decode", "sampling")


@dataclasses.dataclass
class PhaseAttribution:
    """Per-request split of the ArtemisCostModel's step prices. Each
    executed step's energy (pJ) and latency (ns) is divided across the
    participating lanes proportionally to their token share (chunks
    contribute their chunk length, decode lanes one token), so summing
    attribution over all requests reproduces the run's total simulated
    energy and busy time exactly (modulo fp). "sampling" counts the
    tokens drawn on non-greedy RNG lanes; the virtual clock prices
    only the model forward, so its energy/time stay zero — the phase
    exists so the token mix is visible per request."""

    tokens: dict = dataclasses.field(
        default_factory=lambda: {p: 0 for p in PHASES})
    energy_J: dict = dataclasses.field(
        default_factory=lambda: {p: 0.0 for p in PHASES})
    virtual_s: dict = dataclasses.field(
        default_factory=lambda: {p: 0.0 for p in PHASES})

    def add(self, phase: str, tokens: int, energy_J: float,
            virtual_s: float) -> None:
        self.tokens[phase] += tokens
        self.energy_J[phase] += energy_J
        self.virtual_s[phase] += virtual_s

    @property
    def total_energy_J(self) -> float:
        return sum(self.energy_J.values())

    @property
    def total_virtual_s(self) -> float:
        return sum(self.virtual_s.values())

    def summary(self) -> dict:
        return {
            "phases": {p: {"tokens": self.tokens[p],
                           "energy_J": self.energy_J[p],
                           "virtual_s": self.virtual_s[p]}
                       for p in PHASES},
            "total_energy_J": self.total_energy_J,
            "total_virtual_s": self.total_virtual_s,
        }


# ---------------------------------------------------------------------------
# span assembly
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Span:
    """A closed interval on one request's virtual timeline."""
    name: str
    rid: int
    t0: float
    t1: float
    args: tuple = ()             # ((key, value), ...) — kept hashable


@dataclasses.dataclass
class RequestTrace:
    """One request's assembled span tree: the queued wait, each
    admit -> finish/preempt lifecycle attempt, and the per-step
    prefill/decode execution slices nested inside the attempts."""
    rid: int
    queued_at: float | None = None
    attempts: list[Span] = dataclasses.field(default_factory=list)
    slices: list[Span] = dataclasses.field(default_factory=list)
    instants: list[tuple] = dataclasses.field(default_factory=list)
    finished_at: float | None = None
    open_attempt_at: float | None = None   # admit ts of an unclosed attempt


def assemble_spans(events) -> dict[int, RequestTrace]:
    """Fold a trace-level event log into per-request span trees,
    validating well-formedness on the way:

      * an admit may not land while the previous attempt is open;
      * finish/preempt must close an OPEN attempt;
      * execution slices must nest inside an open attempt;
      * each request's event timestamps are monotone non-decreasing.

    Raises ValueError on any violation. A trailing open attempt (log
    exported mid-run) is legal and left in `open_attempt_at`."""
    traces: dict[int, RequestTrace] = {}
    last_ts: dict[int, float] = {}

    def trace(rid: int) -> RequestTrace:
        if rid not in traces:
            traces[rid] = RequestTrace(rid=rid)
        return traces[rid]

    def touch(rid: int, ts: float, what: str) -> None:
        prev = last_ts.get(rid)
        if prev is not None and ts < prev - 1e-12:
            raise ValueError(
                f"request {rid}: {what} at ts {ts} precedes earlier "
                f"event at {prev} — virtual timestamps must be monotone")
        last_ts[rid] = ts

    def close_attempt(tr: RequestTrace, ts: float, how: str,
                      args: tuple) -> None:
        if tr.open_attempt_at is None:
            raise ValueError(
                f"request {tr.rid}: {how} at ts {ts} without an open "
                f"admit — every finish/preempt must close an attempt")
        tr.attempts.append(Span(how, tr.rid, tr.open_attempt_at, ts, args))
        tr.open_attempt_at = None

    def add_slice(rid: int, name: str, t0: float, t1: float,
                  args: tuple) -> None:
        tr = trace(rid)
        if tr.open_attempt_at is None:
            raise ValueError(
                f"request {rid}: {name} slice at [{t0}, {t1}] outside "
                f"any admitted lifecycle attempt")
        if t0 < tr.open_attempt_at - 1e-12:
            raise ValueError(
                f"request {rid}: {name} slice starts at {t0}, before "
                f"its attempt's admit at {tr.open_attempt_at}")
        touch(rid, t1, name)
        tr.slices.append(Span(name, rid, t0, t1, args))

    for ev in events:
        if isinstance(ev, QueuedEvent):
            trace(ev.rid).queued_at = ev.ts
            touch(ev.rid, ev.ts, "queued")
        elif isinstance(ev, AdmitEvent):
            tr = trace(ev.rid)
            touch(ev.rid, ev.ts, "admit")
            if tr.open_attempt_at is not None:
                raise ValueError(
                    f"request {ev.rid}: admit at ts {ev.ts} while the "
                    f"attempt from {tr.open_attempt_at} is still open")
            tr.open_attempt_at = ev.ts
        elif isinstance(ev, PreemptEvent):
            touch(ev.rid, ev.ts, "preempt")
            tr = trace(ev.rid)
            close_attempt(tr, ev.ts, "preempted",
                          (("phase", ev.phase), ("reason", ev.reason)))
            tr.instants.append(("preempt", ev.ts, ev.reason))
        elif isinstance(ev, FinishEvent):
            touch(ev.rid, ev.ts, "finish")
            tr = trace(ev.rid)
            close_attempt(
                tr, ev.ts, "completed",
                (("n_generated", ev.n_generated),
                 ("energy_J", ev.prefill_energy_J + ev.decode_energy_J
                  + ev.sampling_energy_J)))
            tr.finished_at = ev.ts
        elif isinstance(ev, ExecStepEvent):
            for rid, n in ev.chunks:
                add_slice(rid, "prefill_chunk", ev.t_start, ev.ts,
                          (("tokens", n),))
            for rid in ev.decode_rids:
                add_slice(rid, "decode", ev.t_start, ev.ts,
                          (("tokens", 1),))
        elif isinstance(ev, (ShareEvent, CowForkEvent)):
            trace(ev.rid).instants.append((ev.kind, ev.ts))
            touch(ev.rid, ev.ts, ev.kind)
    for tr in traces.values():
        if tr.queued_at is not None and tr.attempts:
            first = min(s.t0 for s in tr.attempts)
            if first < tr.queued_at - 1e-12:
                raise ValueError(
                    f"request {tr.rid}: admitted at {first} before its "
                    f"arrival at {tr.queued_at}")
    return traces


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------

_ENGINE_TID = 0
_SHARD_PID = 1      # Chrome-trace process grouping per-shard tracks


def _us(t_s: float) -> float:
    return t_s * 1e6


def to_chrome_trace(events, metadata: dict | None = None) -> dict:
    """Render a trace-level event log as a Chrome trace-event JSON
    object (the `{"traceEvents": [...]}` object form) over the VIRTUAL
    clock, loadable in Perfetto / chrome://tracing. One thread (tid)
    per request plus tid 0 for engine-level events; complete events
    (ph "X") for steps/attempts/queued waits, instants (ph "i") for
    preemptions, shares, COW forks, and scheduler decisions."""
    traces = assemble_spans(events)   # validates well-formedness
    te: list[dict] = []

    def meta(tid: int, name: str, pid: int = 0) -> None:
        te.append({"ph": "M", "pid": pid, "tid": tid,
                   "name": "thread_name", "args": {"name": name}})

    te.append({"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
               "args": {"name": "repro.serve (virtual ARTEMIS clock)"}})
    meta(_ENGINE_TID, "engine")
    for rid in sorted(traces):
        meta(rid + 1, f"request {rid}")
    shard_ids = sorted({ev.shard for ev in events
                        if isinstance(ev, ShardStepEvent)})
    if shard_ids:
        te.append({"ph": "M", "pid": _SHARD_PID, "tid": 0,
                   "name": "process_name",
                   "args": {"name": "backend shards"}})
        for s in shard_ids:
            meta(s, f"shard {s}", pid=_SHARD_PID)

    # shard slices emitted DURING a step carry only its start time; the
    # engine's exec-step event that follows carries the duration, so
    # pending shard events render against that step's window
    pending_shards: list[ShardStepEvent] = []
    for ev in events:
        if isinstance(ev, ShardStepEvent):
            pending_shards.append(ev)
        elif isinstance(ev, ExecStepEvent):
            te.append({
                "ph": "X", "pid": 0, "tid": _ENGINE_TID,
                "name": f"step:{ev.kind}", "cat": "step",
                "ts": _us(ev.t_start), "dur": _us(ev.dur_s),
                "args": {"n_tokens": ev.n_tokens,
                         "price_ns": ev.price_ns,
                         "energy_pj": ev.energy_pj}})
            for sev in pending_shards:
                te.append({
                    "ph": "X", "pid": _SHARD_PID, "tid": sev.shard,
                    "name": f"shard{sev.shard}:{sev.phase}",
                    "cat": "backend",
                    "ts": _us(ev.t_start), "dur": _us(ev.dur_s),
                    "args": {"n_tokens": sev.n_tokens,
                             "n_shards": sev.n_shards}})
            pending_shards.clear()
        elif isinstance(ev, AdvanceEvent):
            te.append({"ph": "i", "pid": 0, "tid": _ENGINE_TID,
                       "name": "advance", "cat": "engine", "s": "g",
                       "ts": _us(ev.ts), "args": {}})
        elif isinstance(ev, PreemptAllEvent):
            te.append({"ph": "i", "pid": 0, "tid": _ENGINE_TID,
                       "name": "preempt_all", "cat": "engine", "s": "g",
                       "ts": _us(ev.ts), "args": {}})
        elif isinstance(ev, DecisionEvent):
            te.append({
                "ph": "i", "pid": 0, "tid": _ENGINE_TID,
                "name": f"decide:{ev.chosen}", "cat": "scheduler",
                "s": "t", "ts": _us(ev.ts),
                "args": {"reason": ev.reason,
                         "candidates": [list(c) for c in ev.candidates],
                         "plan": [list(c) for c in ev.plan],
                         "n_decode": ev.n_decode,
                         "admitted": [list(a) for a in ev.admitted],
                         "deferred": [list(d) for d in ev.deferred],
                         "budget_free": ev.budget_free}})
        elif isinstance(ev, PreemptEvent):
            te.append({"ph": "i", "pid": 0, "tid": ev.rid + 1,
                       "name": "preempt", "cat": "lifecycle", "s": "t",
                       "ts": _us(ev.ts),
                       "args": {"phase": ev.phase, "reason": ev.reason}})
        elif isinstance(ev, ShareEvent):
            te.append({"ph": "i", "pid": 0, "tid": ev.rid + 1,
                       "name": "prefix_share", "cat": "lifecycle",
                       "s": "t", "ts": _us(ev.ts),
                       "args": {"matched_tokens": ev.matched}})
        elif isinstance(ev, CowForkEvent):
            te.append({"ph": "i", "pid": 0, "tid": ev.rid + 1,
                       "name": "cow_fork", "cat": "lifecycle", "s": "t",
                       "ts": _us(ev.ts),
                       "args": {"old_page": ev.old_page,
                                "new_page": ev.new_page}})
        elif isinstance(ev, FinishEvent):
            te.append({
                "ph": "i", "pid": 0, "tid": ev.rid + 1, "name": "finish",
                "cat": "lifecycle", "s": "t", "ts": _us(ev.ts),
                "args": {"n_generated": ev.n_generated,
                         "prefill_energy_J": ev.prefill_energy_J,
                         "decode_energy_J": ev.decode_energy_J,
                         "sampling_energy_J": ev.sampling_energy_J,
                         "prefill_s": ev.prefill_s,
                         "decode_s": ev.decode_s}})

    for rid in sorted(traces):
        tr = traces[rid]
        tid = rid + 1
        ends = [s.t1 for s in tr.attempts]
        if tr.open_attempt_at is not None:
            ends.append(tr.open_attempt_at)
        if tr.queued_at is not None and tr.attempts:
            te.append({"ph": "X", "pid": 0, "tid": tid, "name": "queued",
                       "cat": "lifecycle", "ts": _us(tr.queued_at),
                       "dur": _us(tr.attempts[0].t0 - tr.queued_at),
                       "args": {}})
        for sp in tr.attempts:
            te.append({"ph": "X", "pid": 0, "tid": tid, "name": sp.name,
                       "cat": "lifecycle", "ts": _us(sp.t0),
                       "dur": _us(sp.t1 - sp.t0),
                       "args": dict(sp.args)})
        for sp in tr.slices:
            te.append({"ph": "X", "pid": 0, "tid": tid, "name": sp.name,
                       "cat": "exec", "ts": _us(sp.t0),
                       "dur": _us(sp.t1 - sp.t0),
                       "args": dict(sp.args)})

    out = {"traceEvents": te, "displayTimeUnit": "ns",
           "metadata": {"clock": "virtual (ARTEMIS cost model)",
                        "n_requests": len(traces)}}
    if metadata:
        out["metadata"].update(metadata)
    return out


def dumps_chrome_trace(obj: dict) -> str:
    """Deterministic serialization: same trace object -> identical
    bytes (sorted keys, fixed separators) — pinned by the export
    determinism test."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def export_chrome_trace(events, path: str,
                        metadata: dict | None = None) -> str:
    """Assemble, serialize, and write a Chrome trace-event JSON file.
    Returns the path. Open it at https://ui.perfetto.dev (or
    chrome://tracing) — the timeline is the VIRTUAL ARTEMIS clock in
    microseconds."""
    with open(path, "w") as f:
        f.write(dumps_chrome_trace(to_chrome_trace(events, metadata)))
    return path


_PHASES_OK = {"X", "i", "M", "B", "E", "C"}


def validate_chrome_trace(obj) -> dict:
    """Check a loaded Chrome trace-event object for the fields the
    format requires (`ph`/`pid`/`tid` everywhere, numeric `ts` on
    non-metadata events, non-negative `dur` on complete events).
    Raises ValueError with the first violation; returns a small
    summary dict on success."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace-event object: no 'traceEvents' key")
    evs = obj["traceEvents"]
    if not isinstance(evs, list) or not evs:
        raise ValueError("'traceEvents' must be a non-empty list")
    n_spans = n_instants = 0
    tids = set()
    t_lo, t_hi = math.inf, -math.inf
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        for field in ("ph", "pid", "tid"):
            if field not in e:
                raise ValueError(f"traceEvents[{i}] missing {field!r}")
        ph = e["ph"]
        if ph not in _PHASES_OK:
            raise ValueError(f"traceEvents[{i}] has unknown ph {ph!r}")
        if ph == "M":
            continue
        if not isinstance(e.get("ts"), (int, float)):
            raise ValueError(f"traceEvents[{i}] ({ph}) needs numeric 'ts'")
        tids.add(e["tid"])
        t_lo = min(t_lo, e["ts"])
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"traceEvents[{i}] (X) needs non-negative 'dur'")
            n_spans += 1
            t_hi = max(t_hi, e["ts"] + dur)
        else:
            n_instants += 1
            t_hi = max(t_hi, e["ts"])
    return {"n_events": len(evs), "n_spans": n_spans,
            "n_instants": n_instants, "n_tracks": len(tids),
            "span_us": (t_hi - t_lo) if n_spans + n_instants else 0.0}


def _main(argv: list[str]) -> int:
    if len(argv) != 1:
        print("usage: python -m repro.serve.obs <trace.json>",
              file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        obj = json.load(f)
    try:
        info = validate_chrome_trace(obj)
    except ValueError as e:
        print(f"INVALID {argv[0]}: {e}", file=sys.stderr)
        return 1
    print(f"OK {argv[0]}: {info['n_events']} events "
          f"({info['n_spans']} spans, {info['n_instants']} instants) "
          f"on {info['n_tracks']} tracks over {info['span_us']:.3f} "
          f"virtual us")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main(sys.argv[1:]))
