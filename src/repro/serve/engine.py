"""Continuous-batching serving engine: submit() / step() / drain().

One `step()` executes one scheduler action on the device:

  prefill — one request through `make_paged_prefill` (prompt bucketed
            to a page multiple), K/V scattered into freshly allocated
            pages, first token greedily sampled from the last prompt
            logit, request moved to a decode lane.
  decode  — every decode lane advances one token through the single
            compiled `make_paged_decode` step (fixed max-batch shape;
            idle lanes are masked onto the trash page). Lanes that hit
            a page boundary get a new page first; if the pool is dry
            the latest-admitted request is preempted (pages freed,
            recompute-style requeue) until the allocation fits.

The engine keeps a VIRTUAL clock priced by the ARTEMIS cost model
(`hwsim.simulate_model`, token_PP dataflow): every executed batch
advances time by its simulated latency, so arrival interleaving,
latency percentiles and the scheduler's decisions are deterministic
functions of (trace, seed) — wall-clock throughput is measured
separately by the benchmark. Greedy sampling end-to-end: the engine's
outputs are token-identical to decoding each request alone on the
dense-cache path (tests/test_serve.py pins this).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ArithmeticPolicy
from repro.launch import steps as stepslib
from repro.models import model
from repro.models.config import ModelConfig
from repro.serve.cost import ArtemisCostModel
from repro.serve.paged_cache import (
    TRASH_PAGE,
    init_paged_cache,
    pad_to_page,
)
from repro.serve.paged_model import make_paged_decode, make_paged_prefill
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import Scheduler, SchedulerConfig
from repro.serve.traffic import TraceItem


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    page_size: int = 8
    n_pages: int = 128             # includes the reserved trash page 0
    max_batch: int = 4             # decode lanes (compiled batch width)
    max_pages_per_seq: int = 16    # block-table width
    cache_dtype: str = "float32"
    scheduler: str = "cost"        # "cost" | "fcfs"
    scheme: str = "token_PP"       # hwsim dataflow used for pricing


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None,
                 policy: ArithmeticPolicy = ArithmeticPolicy(),
                 ecfg: EngineConfig = EngineConfig(), seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        if params is None:
            params = model.init(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.cache = init_paged_cache(
            cfg, ecfg.n_pages, ecfg.page_size,
            dtype=jnp.dtype(ecfg.cache_dtype))
        self.cost = ArtemisCostModel(cfg, scheme=ecfg.scheme)
        self.scheduler = Scheduler(
            SchedulerConfig(policy=ecfg.scheduler),
            self.cost, ecfg.page_size)
        # donate the KV pool (arg 2): both steps return the updated pool
        # and the engine overwrites self.cache.kv with it, so XLA can
        # update pages in place instead of copying the whole pool
        self._prefill = jax.jit(make_paged_prefill(cfg, policy),
                                donate_argnums=(2,))
        self._decode = jax.jit(make_paged_decode(cfg, policy),
                               donate_argnums=(2,))
        self.requests: dict[int, Request] = {}
        self.lanes: list[Request | None] = [None] * ecfg.max_batch
        self.now = 0.0
        self.events: list[tuple] = []
        self._next_rid = 0
        self._admit_seq = 0
        self._admit_order: dict[int, int] = {}   # rid -> admission counter
        self._util_sum = 0.0
        self._util_samples = 0

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_time: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("prompt must have at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # last cache write lands at position prompt+gen-2 (the final
        # sampled token is never fed back), so this bounds page usage
        worst_pages = self.cache.allocator.pages_for(
            len(prompt) + max_new_tokens - 1)
        if worst_pages > self.ecfg.max_pages_per_seq:
            raise ValueError(
                f"request needs up to {worst_pages} pages, block table "
                f"holds {self.ecfg.max_pages_per_seq}")
        if worst_pages > self.ecfg.n_pages - 1:
            raise ValueError(
                f"request needs up to {worst_pages} pages, pool has "
                f"{self.ecfg.n_pages - 1}")
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_time=float(arrival_time))
        return rid

    def submit_trace(self, items: list[TraceItem]) -> list[int]:
        return [self.submit(it.prompt, it.max_new_tokens, it.arrival_time)
                for it in items]

    # -- stepping -----------------------------------------------------------

    def _queued_visible(self) -> list[Request]:
        qs = [r for r in self.requests.values()
              if r.state is RequestState.QUEUED
              and r.arrival_time <= self.now]
        return sorted(qs, key=lambda r: (r.arrival_time, r.rid))

    def _next_arrival(self) -> float | None:
        future = [r.arrival_time for r in self.requests.values()
                  if r.state is RequestState.QUEUED
                  and r.arrival_time > self.now]
        return min(future) if future else None

    def _decoding(self) -> list[Request]:
        return [r for r in self.lanes if r is not None]

    def step(self) -> tuple | None:
        """Execute one scheduler action; returns the event or None when
        there is nothing left to do."""
        action = self.scheduler.decide(
            self._queued_visible(), self._next_arrival(),
            len(self._decoding()), self.lanes.count(None),
            self.cache.allocator.n_free)
        if action.kind == "idle":
            return None
        if action.kind == "advance":
            self.now = action.next_time
            ev = ("advance", action.next_time)
        elif action.kind == "prefill":
            ev = self._do_prefill(self.requests[action.rid])
        else:
            ev = self._do_decode()
        if ev is not None:
            self.events.append(ev)
            if ev[0] != "advance":   # utilization of EXECUTED batches
                self._util_sum += self.cache.utilization()
                self._util_samples += 1
        return ev

    def drain(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if all(r.state is RequestState.DONE
                   for r in self.requests.values()):
                return
            if self.step() is None:
                break
        undone = [r.rid for r in self.requests.values()
                  if r.state is not RequestState.DONE]
        if undone:
            raise RuntimeError(f"drain stalled with requests {undone}")

    # -- actions ------------------------------------------------------------

    def _do_prefill(self, req: Request) -> tuple:
        page = self.ecfg.page_size
        prompt = req.effective_prompt()
        s_pad = pad_to_page(len(prompt), page)
        req.state = RequestState.PREFILL
        req.pages = self.cache.allocator.alloc(s_pad // page, req.rid)
        tokens = np.zeros((1, s_pad), np.int32)
        tokens[0, :len(prompt)] = prompt
        logits, kv = self._prefill(
            self.params, jnp.asarray(tokens), self.cache.kv,
            jnp.asarray(req.pages, jnp.int32))
        self.cache.kv = kv
        nxt = int(stepslib.greedy_sample(logits[len(prompt) - 1]))
        req.seq_len = len(prompt)
        self.now += self.cost.price(s_pad) * 1e-9
        req.generated.append(nxt)
        if req.t_first_token is None:
            req.t_first_token = self.now
        self._admit_order[req.rid] = self._admit_seq
        self._admit_seq += 1
        if req.done:
            self._finish(req)
        else:
            lane = self.lanes.index(None)
            req.lane = lane
            self.lanes[lane] = req
            req.state = RequestState.DECODE
        return ("prefill", req.rid, s_pad, self.now)

    def _grow(self, req: Request) -> bool:
        """Give `req` one more page, preempting latest-admitted decode
        requests under cache pressure. False if req itself was evicted."""
        alloc = self.cache.allocator
        while not alloc.can_alloc(1):
            victims = self._decoding()
            victim = max(victims, key=lambda r: self._admit_order[r.rid])
            self._preempt(victim)
            if victim is req:
                return False
        req.pages.extend(alloc.alloc(1, req.rid))
        return True

    def _preempt(self, req: Request) -> None:
        self.cache.allocator.free(req.pages)
        req.pages = []
        req.seq_len = 0
        self.lanes[req.lane] = None
        req.lane = -1
        req.state = RequestState.QUEUED
        req.n_preemptions += 1
        self.events.append(("preempt", req.rid, self.now))

    def _do_decode(self) -> tuple | None:
        page = self.ecfg.page_size
        # page boundary crossings first, oldest admissions first so
        # eviction pressure lands on the newest request
        for req in sorted(self._decoding(),
                          key=lambda r: self._admit_order[r.rid]):
            if req.state is not RequestState.DECODE:
                continue   # evicted earlier in this very loop
            if req.seq_len >= len(req.pages) * page:
                self._grow(req)
        batch = self._decoding()
        if not batch:
            return None   # everything was preempted; nothing ran

        b, pmax = self.ecfg.max_batch, self.ecfg.max_pages_per_seq
        tokens = np.zeros((b, 1), np.int32)
        tables = np.full((b, pmax), TRASH_PAGE, np.int32)
        seq_lens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for req in batch:
            tokens[req.lane, 0] = req.generated[-1]
            tables[req.lane, :len(req.pages)] = req.pages
            seq_lens[req.lane] = req.seq_len
            active[req.lane] = True
        logits, kv = self._decode(
            self.params, jnp.asarray(tokens), self.cache.kv,
            jnp.asarray(tables), jnp.asarray(seq_lens),
            jnp.asarray(active))
        self.cache.kv = kv
        nxt = np.asarray(stepslib.greedy_sample(logits))
        self.now += self.cost.price(len(batch)) * 1e-9
        rids = []
        for req in batch:
            req.generated.append(int(nxt[req.lane]))
            req.seq_len += 1
            rids.append(req.rid)
            if req.done:
                self._finish(req)
        return ("decode", tuple(rids), self.now)

    def _finish(self, req: Request) -> None:
        if req.pages:
            self.cache.allocator.free(req.pages)
            req.pages = []
        if req.lane >= 0:
            self.lanes[req.lane] = None
            req.lane = -1
        req.state = RequestState.DONE
        req.t_done = self.now

    # -- results ------------------------------------------------------------

    def results(self) -> dict[int, np.ndarray]:
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in sorted(self.requests.items())}

    def metrics(self) -> dict:
        done = [r for r in self.requests.values()
                if r.state is RequestState.DONE]
        lats = sorted(r.latency() for r in done)
        n_tok = sum(len(r.generated) for r in done)

        def pct(p):
            if not lats:
                return 0.0
            return lats[min(int(p / 100 * len(lats)), len(lats) - 1)]

        return {
            "n_done": len(done),
            "n_generated_tokens": n_tok,
            "virtual_time_s": self.now,
            "virtual_tok_per_s": n_tok / max(self.now, 1e-12),
            "p50_latency_s": pct(50),
            "p99_latency_s": pct(99),
            "mean_ttft_s": (float(np.mean([r.ttft() for r in done]))
                            if done else 0.0),
            "n_preemptions": sum(r.n_preemptions
                                 for r in self.requests.values()),
            "cache_utilization": (self._util_sum
                                  / max(self._util_samples, 1)),
        }
