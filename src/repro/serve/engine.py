"""Continuous-batching serving engine: submit() / step() / drain().

One `step()` executes one scheduler action on the device:

  prefill — one fixed-size chunk of prompt tokens for up to max_batch
            requests AT ONCE through the single compiled
            `make_paged_chunked_prefill` step ((B, C) shapes are
            engine constants, so chunked prefill compiles exactly
            once). A request whose prompt exceeds the chunk size sits
            in PREFILL across steps, `prefill_pos` marking its cursor;
            pages are allocated chunk-by-chunk. When a chunk completes
            the prompt, the first token is sampled from the last valid
            chunk logit and the request flips to DECODE on the lane it
            reserved at admission.
  decode  — every decode lane advances one token through the single
            compiled `make_paged_decode` step (fixed max-batch shape;
            idle lanes are masked onto the trash page). Lanes that hit
            a page boundary get a new page first; if the pool is dry
            the latest-admitted request is preempted (pages freed,
            recompute-style requeue) until the allocation fits.
  mixed   — prefill chunks AND a decode round in the same step, priced
            as ONE pass over the composed token count — the ARTEMIS
            token-parallel dataflow prices a batch by its total
            concurrent tokens, so sharing a pass is exactly where the
            hardware model wins. The two halves touch disjoint pages,
            so execution order inside the step is irrelevant to the
            results.

PREFIX SHARING (copy-on-write): at admission the engine matches the
request's prompt against the `PrefixIndex` of already-resident pages.
Matched pages are SHARED (allocator refcount + 1) instead of
re-allocated and re-prefilled: `prefill_pos` starts past the shared
prefix (capped at prompt_len - 1 — the last prompt token always reruns
so its logits can seed decode, with its K/V write skipped via the
chunk's write_from mask) and `seq_len` covers the resident tokens.
Full pages completed by prefill are registered in the index; pages
drop out when their last owner releases them. Divergence — a write
landing in a page whose refcount is > 1, which in practice is a
sharer's first decode token into a partially-covered shared last
page — triggers a COW fork: allocate a private page, copy the K/V
slice on device, swap the page-table entry, drop the shared ref.
Preempting a sharer only releases its references (pages other
requests still own stay resident and indexed).

The engine keeps a VIRTUAL clock priced by the ARTEMIS cost model
(`hwsim.simulate_model`, token_PP dataflow): every executed step
advances time by the simulated latency of its composed batch, so
arrival interleaving, latency percentiles and the scheduler's
decisions are deterministic functions of (trace, seed) — wall-clock
throughput is measured separately by the benchmark. Greedy sampling
end-to-end: the engine's outputs are token-identical to decoding each
request alone on the dense-cache path, including through preemption
landing mid-prefill and through prefix sharing, COW forks, and
preemption of sharers (tests/test_serve.py pins this).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ArithmeticPolicy
from repro.launch import steps as stepslib
from repro.models import model
from repro.models.config import ModelConfig
from repro.serve.cost import ArtemisCostModel
from repro.serve.paged_cache import (
    TRASH_PAGE,
    PrefixIndex,
    cow_copy_page,
    init_paged_cache,
)
from repro.serve.paged_model import (
    make_paged_chunked_prefill,
    make_paged_decode,
)
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import Action, Scheduler, SchedulerConfig
from repro.serve.traffic import TraceItem


def percentile(sorted_vals, p: float) -> float:
    """Nearest-rank percentile over an ascending-sorted sequence:
    element ceil(p/100 * n) of the 1-indexed list (so p50 of two values
    is the LOWER one, and p100 is the max — no off-by-one upward)."""
    n = len(sorted_vals)
    if n == 0:
        return 0.0
    k = min(max(math.ceil(p / 100.0 * n), 1), n)
    return float(sorted_vals[k - 1])


@functools.lru_cache(maxsize=None)
def _compiled_steps(cfg: ModelConfig, policy: ArithmeticPolicy):
    """Jitted paged steps shared across engines with the same
    (cfg, policy): a fresh jax.jit wrapper per engine would recompile
    per instance, which both slows tests and lets compile time leak
    into benchmark drains (the warmup engine would warm nothing)."""
    # donate the KV pool (arg 2): both steps return the updated pool
    # and the engine overwrites self.cache.kv with it, so XLA can
    # update pages in place instead of copying the whole pool
    return (jax.jit(make_paged_chunked_prefill(cfg, policy),
                    donate_argnums=(2,)),
            jax.jit(make_paged_decode(cfg, policy),
                    donate_argnums=(2,)))


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    page_size: int = 8
    n_pages: int = 128             # includes the reserved trash page 0
    max_batch: int = 4             # batch lanes (compiled batch width)
    max_pages_per_seq: int = 16    # block-table width
    prefill_chunk: int = 32        # prompt tokens per prefill chunk
    cache_dtype: str = "float32"
    scheduler: str = "cost"        # "cost" | "fcfs"
    scheme: str = "token_PP"       # hwsim dataflow used for pricing
    prefix_sharing: bool = True    # COW page sharing for common prefixes

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page 0 is the reserved trash "
                f"page), got {self.n_pages}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_pages_per_seq < 1:
            raise ValueError(
                f"max_pages_per_seq must be >= 1, got "
                f"{self.max_pages_per_seq}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.scheduler not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        jnp.dtype(self.cache_dtype)   # raises on nonsense dtypes


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None,
                 policy: ArithmeticPolicy = ArithmeticPolicy(),
                 ecfg: EngineConfig = EngineConfig(), seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        if params is None:
            params = model.init(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.cache = init_paged_cache(
            cfg, ecfg.n_pages, ecfg.page_size,
            dtype=jnp.dtype(ecfg.cache_dtype))
        self.cost = ArtemisCostModel(cfg, scheme=ecfg.scheme)
        self.prefix = PrefixIndex(ecfg.page_size)
        self.scheduler = Scheduler(
            SchedulerConfig(policy=ecfg.scheduler),
            self.cost, ecfg.page_size, ecfg.prefill_chunk,
            prefix_probe=self._probe_prefix)
        self._prefill, self._decode = _compiled_steps(cfg, policy)
        self.requests: dict[int, Request] = {}
        self.lanes: list[Request | None] = [None] * ecfg.max_batch
        self.now = 0.0
        self.events: list[tuple] = []
        self._next_rid = 0
        self._admit_seq = 0
        self._admit_order: dict[int, int] = {}   # rid -> admission counter
        self._util_sum = 0.0
        self._logical_util_sum = 0.0
        self._util_samples = 0
        self._n_prefix_hits = 0      # admissions that shared >= 1 token
        self._shared_tokens = 0      # prompt tokens covered by sharing
        self._prompt_tokens = 0      # prompt tokens over all admissions
        self._n_cow = 0              # copy-on-write page forks
        # rid -> (index generation, matched, pages): the scheduler
        # probes every visible queued request each decide(), so match
        # results are memoized until the index mutates (a queued
        # request's effective prompt is fixed; invalidated on preempt)
        self._match_memo: dict[int, tuple[int, int, list[int]]] = {}

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               arrival_time: float = 0.0) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("prompt must have at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        # last cache write lands at position prompt+gen-2 (the final
        # sampled token is never fed back), so this bounds page usage
        worst_pages = self.cache.allocator.pages_for(
            len(prompt) + max_new_tokens - 1)
        if worst_pages > self.ecfg.max_pages_per_seq:
            raise ValueError(
                f"request needs up to {worst_pages} pages, block table "
                f"holds {self.ecfg.max_pages_per_seq}")
        if worst_pages > self.ecfg.n_pages - 1:
            raise ValueError(
                f"request needs up to {worst_pages} pages, pool has "
                f"{self.ecfg.n_pages - 1}")
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_time=float(arrival_time))
        return rid

    def submit_trace(self, items: list[TraceItem]) -> list[int]:
        return [self.submit(it.prompt, it.max_new_tokens, it.arrival_time)
                for it in items]

    # -- stepping -----------------------------------------------------------

    def _queued_visible(self) -> list[Request]:
        qs = [r for r in self.requests.values()
              if r.state is RequestState.QUEUED
              and r.arrival_time <= self.now]
        return sorted(qs, key=lambda r: (r.arrival_time, r.rid))

    def _next_arrival(self) -> float | None:
        future = [r.arrival_time for r in self.requests.values()
                  if r.state is RequestState.QUEUED
                  and r.arrival_time > self.now]
        return min(future) if future else None

    def _laned(self) -> list[Request]:
        return [r for r in self.lanes if r is not None]

    def _decoding(self) -> list[Request]:
        return [r for r in self.lanes
                if r is not None and r.state is RequestState.DECODE]

    def _prefilling(self) -> list[Request]:
        pf = [r for r in self.lanes
              if r is not None and r.state is RequestState.PREFILL]
        return sorted(pf, key=lambda r: self._admit_order[r.rid])

    def step(self) -> tuple | None:
        """Execute one scheduler action; returns the event or None when
        there is nothing left to do."""
        action = self.scheduler.decide(
            self._queued_visible(), self._next_arrival(),
            self._prefilling(), self._decoding(),
            self.lanes.count(None), self.cache.allocator.n_free)
        if action.kind == "idle":
            return None
        if action.kind == "advance":
            self.now = action.next_time
            ev = ("advance", action.next_time)
        else:
            ev = self._do_mixed(action)
        if ev is not None:
            self.events.append(ev)
            if ev[0] not in ("advance", "preempt_all"):
                # utilization of EXECUTED batches
                self._util_sum += self.cache.utilization()
                self._logical_util_sum += self.cache.logical_utilization()
                self._util_samples += 1
        return ev

    def drain(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if all(r.state is RequestState.DONE
                   for r in self.requests.values()):
                return
            # a ("preempt_all", ...) step executes nothing but DOES
            # make progress (freed pages re-admit the evicted
            # requests), so only a genuinely idle None stalls
            if self.step() is None:
                break
        undone = [r.rid for r in self.requests.values()
                  if r.state is not RequestState.DONE]
        if undone:
            raise RuntimeError(f"drain stalled with requests {undone}")

    # -- actions ------------------------------------------------------------

    def _newest_victim(self, exclude: Request | None) -> Request | None:
        victims = [r for r in self._laned() if r is not exclude]
        if not victims:
            return None
        return max(victims, key=lambda r: self._admit_order[r.rid])

    def _release(self, pages: list[int], rid: int) -> None:
        """Drop `rid`'s ownership of `pages`; pages whose last owner
        left go back to the pool AND out of the prefix index."""
        released = self.cache.allocator.free(pages, owner=rid)
        self.prefix.forget(released)

    def _match_prefix(self, req: Request) -> tuple[int, list[int]]:
        """Memoized PrefixIndex.match for a queued request (one match
        serves both the scheduler's budget probe and admission)."""
        gen = self.prefix.generation
        hit = self._match_memo.get(req.rid)
        if hit is None or hit[0] != gen:
            matched, pages = self.prefix.match(req.effective_prompt())
            hit = (gen, matched, pages)
            self._match_memo[req.rid] = hit
        return hit[1], hit[2]

    def _probe_prefix(self, req: Request) -> int:
        """Scheduler hook: leading effective-prompt tokens already
        resident in shareable pages (read-only, no side effects)."""
        if not self.ecfg.prefix_sharing:
            return 0
        return self._match_prefix(req)[0]

    def _preempt(self, req: Request) -> None:
        phase = "prefill" if req.state is RequestState.PREFILL else "decode"
        # a sharer's pages may be co-owned: only this request's
        # references are dropped, co-owned pages stay resident
        self._release(req.pages, req.rid)
        req.pages = []
        req.seq_len = 0
        req.prefill_pos = 0
        req.shared_len = 0
        self.lanes[req.lane] = None
        req.lane = -1
        req.state = RequestState.QUEUED
        req.n_preemptions += 1
        # its effective prompt grew by the generated tokens, so any
        # memoized prefix match is stale even at the same generation
        self._match_memo.pop(req.rid, None)
        self.events.append(("preempt", req.rid, phase, self.now))

    def _grow_decode_lanes(self) -> None:
        """Prepare every decode lane's write target, oldest admissions
        first so eviction pressure lands on the newest request: lanes
        at a page boundary get a fresh page; lanes about to write into
        a SHARED page (another request references it) COW-fork it to a
        private copy first."""
        page = self.ecfg.page_size
        for req in sorted(self._decoding(),
                          key=lambda r: self._admit_order[r.rid]):
            if req.state is not RequestState.DECODE:
                continue   # evicted earlier in this very loop
            if req.seq_len >= len(req.pages) * page:
                self._grow(req)
            else:
                self._divert_write(req, req.seq_len // page)

    def _make_room(self, req: Request) -> bool:
        """Free at least one page by preempting latest-admitted laned
        requests (evicting a sharer may release nothing physical, so
        keep going). False if req itself was evicted."""
        alloc = self.cache.allocator
        while not alloc.can_alloc(1):
            victim = self._newest_victim(exclude=None)
            if victim is None:
                # unreachable from engine flow (req itself is laned),
                # but external allocator users can drain the pool
                raise MemoryError(
                    "page pool dry with no evictable lane")
            self._preempt(victim)
            if victim is req:
                return False
        return True

    def _grow(self, req: Request) -> bool:
        """Give `req` one more page, preempting latest-admitted laned
        requests under cache pressure. False if req itself was evicted."""
        if not self._make_room(req):
            return False
        req.pages.extend(self.cache.allocator.alloc(1, req.rid))
        return True

    def _divert_write(self, req: Request, j: int) -> bool:
        """req is about to write into its page j, whose content other
        places may still rely on. Two cases: co-owned (refcount > 1) —
        COW-fork to a private device copy so the write cannot clobber
        co-owners' K/V; sole-owned but still in the prefix index (the
        co-owners left, e.g. the original writer finished) — the write
        diverges the page from its indexed content, so the index entry
        is dropped before a future admission can match stale K/V.
        False if req itself was evicted while making room for a fork."""
        if self.cache.allocator.refcount(req.pages[j]) <= 1:
            self.prefix.forget([req.pages[j]])
            return True
        return self._cow_fork(req, j)

    def _cow_fork(self, req: Request, j: int) -> bool:
        """Copy-on-write: replace `req`'s shared page j with a private
        device copy so its next write cannot clobber co-owners' K/V.
        False if req itself was evicted while making room."""
        if not self._make_room(req):
            return False
        alloc = self.cache.allocator
        old = req.pages[j]
        if alloc.refcount(old) <= 1:
            # co-owners were evicted while making room; the page may
            # still be indexed, and the write is about to diverge it
            self.prefix.forget([old])
            return True
        [new] = alloc.alloc(1, req.rid)
        self.cache.kv = cow_copy_page(
            self.cache.kv, jnp.int32(old), jnp.int32(new))
        req.pages[j] = new
        self._release([old], req.rid)
        self._n_cow += 1
        self.events.append(("cow", req.rid, old, new, self.now))
        return True

    def _alloc_chunk(self, req: Request, want: int) -> int:
        """Allocate pages so `req` can write `want` more prompt tokens.
        Under pressure, only requests admitted AFTER `req` are
        preempted (pressure always lands on the newest, so a fresh
        admission can never evict an older request). Returns the
        granted token count — possibly < want, or 0, when the pool
        cannot fund the chunk without touching older requests."""
        page = self.ecfg.page_size
        alloc = self.cache.allocator
        end = req.prefill_pos + want
        while len(req.pages) * page < end:
            if alloc.can_alloc(1):
                req.pages.extend(alloc.alloc(1, req.rid))
                continue
            victim = self._newest_victim(exclude=req)
            if (victim is None or self._admit_order[victim.rid]
                    < self._admit_order[req.rid]):
                break
            self._preempt(victim)
        n = min(want, len(req.pages) * page - req.prefill_pos)
        if n <= 0:
            return 0
        # copy-on-write: this chunk WRITES positions [ws, we) (rerun
        # positions below shared_len only read); any of those pages
        # still co-owned must be forked before the scatter runs
        ws = max(req.prefill_pos, req.shared_len)
        we = req.prefill_pos + n
        if ws < we:
            for j in range(ws // page, -(-we // page)):
                if not self._divert_write(req, j):
                    return 0       # req itself evicted making room
        return n

    def _admit_shared(self, req: Request) -> None:
        """Admission-time prefix matching: share every resident page
        covering a leading run of the request's effective prompt, start
        the prefill cursor past the shared tokens (capped so the last
        prompt token always reruns for its logits), and count the hit."""
        ep = req.effective_prompt()
        self._prompt_tokens += len(ep)
        if not self.ecfg.prefix_sharing:
            return
        matched, spages = self._match_prefix(req)
        self._match_memo.pop(req.rid, None)   # ep changes once laned
        if matched <= 0:
            return
        self.cache.allocator.share(spages, req.rid)
        req.pages = list(spages)
        req.shared_len = matched
        req.seq_len = matched
        req.prefill_pos = min(matched, len(ep) - 1)
        self._n_prefix_hits += 1
        self._shared_tokens += matched
        self.events.append(("share", req.rid, matched, self.now))

    def _register_full_pages(self, req: Request, from_seq: int) -> None:
        """Index every page that BECAME full while req's resident
        coverage grew from from_seq to req.seq_len (prefill only —
        decode-filled pages hold generated tokens no other prompt is
        likely to revisit, and keeping them out keeps forgetting
        simple)."""
        if not self.ecfg.prefix_sharing:
            return
        page = self.ecfg.page_size
        ep = req.effective_prompt()
        for j in range(from_seq // page, req.seq_len // page):
            self.prefix.register(ep[:(j + 1) * page], req.pages[j])

    def _do_mixed(self, action: Action) -> tuple | None:
        """Execute a prefill / decode / mixed step: allocate all pages
        first (decode growth, then prefill chunks — preemption between
        the halves is resolved before anything runs), then the decode
        and chunked-prefill forwards, then advance the clock ONCE by
        the price of the composed token count."""
        preempted_before = sum(r.n_preemptions
                               for r in self.requests.values())

        # 1. decode page-boundary growth, oldest admissions first so
        #    eviction pressure lands on the newest request
        if action.decode:
            self._grow_decode_lanes()

        page = self.ecfg.page_size
        # 2. prefill chunk allocation (plan order = admission order,
        #    then FCFS admissions); a request that was evicted after
        #    the plan was made is skipped
        chunks: list[tuple[Request, int]] = []
        for rid, want in action.prefill:
            req = self.requests[rid]
            if req.state is RequestState.QUEUED and req.lane < 0:
                if None not in self.lanes:
                    continue   # lanes filled by an earlier admission
                lane = self.lanes.index(None)
                req.lane = lane
                self.lanes[lane] = req
                req.state = RequestState.PREFILL
                self._admit_order[req.rid] = self._admit_seq
                self._admit_seq += 1
                self._admit_shared(req)
            elif req.state is not RequestState.PREFILL:
                continue       # preempted between plan and execution
            remaining = len(req.effective_prompt()) - req.prefill_pos
            n = self._alloc_chunk(req, min(want, remaining))
            if n <= 0:
                continue
            chunks.append((req, n))
        # a COW fork funding a later chunk may have evicted an earlier
        # member of this very batch — never run a chunk on freed pages
        chunks = [(r, n) for r, n in chunks
                  if r.state is RequestState.PREFILL]

        # 3. decode forward over the lanes that survived allocation.
        #    If the planned chunks could not be funded at all — the
        #    missing pages are held by OLDER requests, which eviction
        #    never touches — fall back to a decode round so those
        #    holders keep progressing and eventually free the pages
        #    the chunk is waiting on (drain must never stall while
        #    runnable lanes exist)
        run_decode = bool(action.decode)
        if not chunks and not run_decode and self._decoding():
            self._grow_decode_lanes()
            run_decode = True
        dec_batch: list[Request] = []
        dec_next = None
        if run_decode:
            dec_batch = self._decoding()
        if dec_batch:
            b, pmax = self.ecfg.max_batch, self.ecfg.max_pages_per_seq
            tokens = np.zeros((b, 1), np.int32)
            tables = np.full((b, pmax), TRASH_PAGE, np.int32)
            seq_lens = np.zeros((b,), np.int32)
            active = np.zeros((b,), bool)
            for req in dec_batch:
                tokens[req.lane, 0] = req.generated[-1]
                tables[req.lane, :len(req.pages)] = req.pages
                seq_lens[req.lane] = req.seq_len
                active[req.lane] = True
            logits, kv = self._decode(
                self.params, jnp.asarray(tokens), self.cache.kv,
                jnp.asarray(tables), jnp.asarray(seq_lens),
                jnp.asarray(active))
            self.cache.kv = kv
            dec_next = np.asarray(stepslib.greedy_sample(logits))

        # 4. chunked + batched prefill forward
        chunk_logits = None
        if chunks:
            b, c = self.ecfg.max_batch, self.ecfg.prefill_chunk
            pmax = self.ecfg.max_pages_per_seq
            tokens = np.zeros((b, c), np.int32)
            tables = np.full((b, pmax), TRASH_PAGE, np.int32)
            start = np.zeros((b,), np.int32)
            lens = np.zeros((b,), np.int32)
            active = np.zeros((b,), bool)
            wfrom = np.zeros((b,), np.int32)
            for i, (req, n) in enumerate(chunks):
                ep = req.effective_prompt()
                tokens[i, :n] = ep[req.prefill_pos:req.prefill_pos + n]
                tables[i, :len(req.pages)] = req.pages
                start[i] = req.prefill_pos
                lens[i] = n
                active[i] = True
                # positions below shared_len are resident in (possibly
                # shared) pages: rerun the query, skip the write
                wfrom[i] = req.shared_len
            chunk_logits, kv = self._prefill(
                self.params, jnp.asarray(tokens), self.cache.kv,
                jnp.asarray(tables), jnp.asarray(start),
                jnp.asarray(lens), jnp.asarray(active),
                jnp.asarray(wfrom))
            self.cache.kv = kv

        # 5. one clock advance for the whole composed step
        n_total = len(dec_batch) + sum(n for _, n in chunks)
        if n_total == 0:
            preempted = sum(r.n_preemptions
                            for r in self.requests.values())
            if preempted > preempted_before:
                # nothing ran, but freed pages make the re-queued
                # requests immediately prefillable — progress, not
                # a stall (drain keeps going)
                return ("preempt_all", self.now)
            return None
        self.now += self.cost.price(n_total) * 1e-9

        # 6. apply decode results
        dec_rids = []
        for req in dec_batch:
            req.generated.append(int(dec_next[req.lane]))
            req.seq_len += 1
            dec_rids.append(req.rid)
            if req.done:
                self._finish(req)

        # 7. apply prefill results: advance cursors; a chunk that
        #    completes its prompt samples the next token from the last
        #    VALID chunk position and flips the request to DECODE
        chunk_plan = []
        for i, (req, n) in enumerate(chunks):
            old_seq = req.seq_len
            req.prefill_pos += n
            # a sharer rerunning inside its shared prefix already has
            # seq_len past the cursor — coverage never shrinks
            req.seq_len = max(req.seq_len, req.prefill_pos)
            self._register_full_pages(req, old_seq)
            chunk_plan.append((req.rid, n))
            if req.prefill_pos < len(req.effective_prompt()):
                continue
            nxt = int(stepslib.greedy_sample(chunk_logits[i, n - 1]))
            req.generated.append(nxt)
            if req.t_first_token is None:
                req.t_first_token = self.now
            if req.done:
                self._finish(req)
            else:
                req.state = RequestState.DECODE

        if action.kind == "decode" or not chunk_plan:
            return ("decode", tuple(dec_rids), self.now)
        if action.kind == "prefill" or not dec_rids:
            return ("prefill", tuple(chunk_plan), self.now)
        return ("mixed", tuple(chunk_plan), tuple(dec_rids), self.now)

    def _finish(self, req: Request) -> None:
        if req.pages:
            self._release(req.pages, req.rid)
            req.pages = []
        if req.lane >= 0:
            self.lanes[req.lane] = None
            req.lane = -1
        req.state = RequestState.DONE
        req.t_done = self.now

    # -- results ------------------------------------------------------------

    def results(self) -> dict[int, np.ndarray]:
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in sorted(self.requests.items())}

    def metrics(self) -> dict:
        done = [r for r in self.requests.values()
                if r.state is RequestState.DONE]
        lats = sorted(r.latency() for r in done)
        # every request the engine admits generates >= 1 token (submit
        # rejects max_new_tokens < 1), so done requests always have a
        # first-token time — but never let a None skew the percentile
        # sort if an external driver bypasses submit()
        ttfts = sorted(t for t in (r.ttft() for r in done)
                       if t is not None)
        n_tok = sum(len(r.generated) for r in done)
        return {
            "n_done": len(done),
            "n_generated_tokens": n_tok,
            "virtual_time_s": self.now,
            "virtual_tok_per_s": n_tok / max(self.now, 1e-12),
            "p50_latency_s": percentile(lats, 50),
            "p99_latency_s": percentile(lats, 99),
            "mean_ttft_s": (float(np.mean(ttfts)) if ttfts else 0.0),
            "p50_ttft_s": percentile(ttfts, 50),
            "p99_ttft_s": percentile(ttfts, 99),
            "n_preemptions": sum(r.n_preemptions
                                 for r in self.requests.values()),
            "cache_utilization": (self._util_sum
                                  / max(self._util_samples, 1)),
            "logical_cache_utilization": (self._logical_util_sum
                                          / max(self._util_samples, 1)),
            "n_prefix_hits": self._n_prefix_hits,
            "prefix_hit_rate": (self._shared_tokens
                                / max(self._prompt_tokens, 1)),
            "n_cow_forks": self._n_cow,
            "physical_pages_allocated":
                self.cache.allocator.total_allocated,
        }
