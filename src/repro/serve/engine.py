"""Continuous-batching serving engine: submit() / step() / drain().

The engine is BACKEND-AGNOSTIC: every model family is served through
the same request lifecycle, scheduler, and step loop, and all
sequence-memory mechanics (how K/V or recurrent state is stored,
shared, grown, and reclaimed) live behind the `SequenceBackend`
protocol (repro.serve.backend) — attention families get the paged-KV
backend, recurrent families get the state-slot backend, and this
module never branches on either.

One `step()` executes one scheduler action on the device:

  prefill — one fixed-size chunk of prompt tokens for up to max_batch
            requests AT ONCE through the backend's single compiled
            chunk step ((B, C) shapes are engine constants, so chunked
            prefill compiles exactly once). A request whose prompt
            exceeds the chunk size sits in PREFILL across steps,
            `prefill_pos` marking its cursor; memory is funded
            chunk-by-chunk. When a chunk completes the prompt, the
            first token is sampled from the last valid chunk logit and
            the request flips to DECODE on the lane it reserved at
            admission.
  decode  — every decode lane advances one token through the backend's
            single compiled decode step (fixed max-batch shape; idle
            lanes are backend-masked). The backend first makes every
            lane's write target safe; if that needs memory the pool
            doesn't have, the latest-admitted request is preempted
            (memory released, recompute-style requeue) until it fits.
  mixed   — prefill chunks AND a decode round in the same step, priced
            as ONE pass over the composed token count — the ARTEMIS
            token-parallel dataflow prices a batch by its total
            concurrent tokens, so sharing a pass is exactly where the
            hardware model wins. The two halves touch disjoint memory,
            so execution order inside the step is irrelevant to the
            results.

Admission may come with a PREFIX-SHARE DISCOUNT: a backend that can
recognize an already-resident leading run of the prompt (the paged-KV
backend's copy-on-write prefix index) starts the new request past it,
and the scheduler's budget probe charges admission only for the
unshared remainder. Backends without shareable memory report a zero
discount and everything still composes.

The engine keeps a VIRTUAL clock priced by the ARTEMIS cost model
(`hwsim.simulate_model`, token_PP dataflow): every executed step
advances time by the simulated latency of its composed batch, so
arrival interleaving, latency percentiles and the scheduler's
decisions are deterministic functions of (trace, seed) — wall-clock
throughput is measured separately by the benchmark.

SAMPLING: every token the engine emits — decode rounds and
prefill-completion first tokens alike — goes through the one batched
fixed-shape sampler (`repro.serve.sampler.sample_tokens`) at the
compiled (max_batch, vocab) shape, each lane on its own RNG lane
keyed by (request seed, tokens generated so far). Greedy
(`temperature=0`, the default) lanes reduce to plain argmax,
bit-identical to the pre-sampling `greedy_sample` path, and a sampled
request's stream is deterministic and independent of batch
composition, chunking, scheduler policy, and recompute-style
preemption: the engine's outputs are token-identical to decoding each
request alone, greedy pinned against the sequential single-request
path and sampled pinned against a solo engine run
(tests/test_serve.py, tests/test_sampling.py and
tests/test_serve_backend.py pin this for both backends).

OBSERVABILITY: everything the engine publishes flows through one
`repro.serve.obs.Tracer` — typed lifecycle events (queued / admit /
prefill chunk / decode round / preempt / COW fork / finish, plus the
scheduler's decision audit) and a metrics registry of counters and
exact-percentile streaming histograms. At the default
`EngineConfig.observability="metrics"` only the registry is fed and no
per-event objects are retained; `observability="trace"` keeps the full
event log for span assembly and Chrome trace export
(`repro.serve.obs.export_chrome_trace`). Every executed step's ARTEMIS
price/energy is split across its participating lanes into each
request's `PhaseAttribution`, so per-request joules and
virtual-seconds by phase sum back to the run's total simulated energy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ArithmeticPolicy
from repro.models import model
from repro.models.config import ModelConfig
from repro.serve import sampler
from repro.serve.backend import EngineConfig, make_backend
from repro.serve.cost import ArtemisCostModel
from repro.serve.mesh import make_serve_mesh
from repro.serve.obs import (
    PHASES,
    AdmitEvent,
    AdvanceEvent,
    DecodeStepEvent,
    FinishEvent,
    MixedStepEvent,
    PreemptAllEvent,
    PreemptEvent,
    PrefillStepEvent,
    QueuedEvent,
    Tracer,
    percentile,
)
from repro.serve.request import Request, RequestState, SamplingParams
from repro.serve.scheduler import Action, Scheduler, SchedulerConfig
from repro.serve.traffic import TraceItem

__all__ = ["ServeEngine", "percentile"]


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params=None,
                 policy: ArithmeticPolicy = ArithmeticPolicy(),
                 ecfg: EngineConfig = EngineConfig(), seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.policy = policy
        if params is None:
            # One-time parameter init, not a sampling key: per-request
            # sampling keys are derived exclusively in sampler.lane_key
            # (fold_in(PRNGKey(request.seed), tokens_generated)).
            # repro: allow[rng-key-discipline]
            params = model.init(jax.random.PRNGKey(seed), cfg)
        self.params = params
        self.cost = ArtemisCostModel(cfg, scheme=ecfg.scheme,
                                     n_shards=ecfg.mesh_shards)
        self.obs = Tracer(level=ecfg.observability)
        self.now = 0.0
        self.mesh = make_serve_mesh(ecfg.mesh_shards)
        self.backend = make_backend(
            cfg, ecfg, policy, params,
            obs=self.obs, clock=lambda: self.now, mesh=self.mesh)
        self.scheduler = Scheduler(
            SchedulerConfig(policy=ecfg.scheduler),
            self.cost, ecfg.prefill_chunk,
            obs=self.obs, clock=lambda: self.now)
        self.requests: dict[int, Request] = {}
        self.lanes: list[Request | None] = [None] * ecfg.max_batch
        self._next_rid = 0
        self._admit_seq = 0
        self._admit_order: dict[int, int] = {}   # rid -> admission counter

    @property
    def events(self) -> list:
        """The retained structured event log — populated only at
        `observability="trace"`; empty at the default metrics level
        (the whole point: a metrics-level drain keeps no per-event
        objects)."""
        return self.obs.events

    # -- submission ---------------------------------------------------------

    def _validate_prompt(self, prompt) -> np.ndarray:
        """Accept np.ndarray or list/tuple of ints; reject non-integer
        dtypes (a float array used to silently round-trip into the
        cache) and out-of-vocab token ids."""
        if isinstance(prompt, np.ndarray):
            if not np.issubdtype(prompt.dtype, np.integer):
                raise ValueError(
                    f"prompt array must have an integer dtype, got "
                    f"{prompt.dtype}")
            arr = prompt.reshape(-1)
        elif isinstance(prompt, (list, tuple)):
            bad = [t for t in prompt
                   if not isinstance(t, (int, np.integer))
                   or isinstance(t, bool)]
            if bad:
                raise ValueError(
                    f"prompt list must contain only ints, got "
                    f"{type(bad[0]).__name__} {bad[0]!r}")
            try:
                arr = np.asarray(prompt, np.int64).reshape(-1)
            except OverflowError as e:
                raise ValueError(
                    f"prompt token out of any integer token range: "
                    f"{e}") from e
        else:
            raise TypeError(
                f"prompt must be an np.ndarray or a list of ints, got "
                f"{type(prompt).__name__}")
        if arr.size < 1:
            raise ValueError("prompt must have at least one token")
        # range-check BEFORE the int32 cast so a wide-dtype token can't
        # wrap into the valid range
        lo, hi = int(arr.min()), int(arr.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"prompt tokens must satisfy 0 <= t < vocab_size "
                f"({self.cfg.vocab_size}), got range [{lo}, {hi}]")
        return arr.astype(np.int32)

    def submit(self, prompt, max_new_tokens: int,
               arrival_time: float = 0.0,
               sampling: SamplingParams | None = None) -> int:
        prompt = self._validate_prompt(prompt)
        sampling = sampling if sampling is not None else SamplingParams()
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.backend.validate(len(prompt), max_new_tokens)
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(
            rid=rid, prompt=prompt, max_new_tokens=max_new_tokens,
            arrival_time=float(arrival_time), sampling=sampling)
        if self.obs.tracing:
            self.obs.emit(QueuedEvent(
                ts=float(arrival_time), rid=rid,
                prompt_len=len(prompt), max_new_tokens=max_new_tokens))
        return rid

    def submit_trace(self, items: list[TraceItem]) -> list[int]:
        return [self.submit(it.prompt, it.max_new_tokens, it.arrival_time,
                            sampling=it.sampling)
                for it in items]

    # -- stepping -----------------------------------------------------------

    def _queued_visible(self) -> list[Request]:
        qs = [r for r in self.requests.values()
              if r.state is RequestState.QUEUED
              and r.arrival_time <= self.now]
        return sorted(qs, key=lambda r: (r.arrival_time, r.rid))

    def _next_arrival(self) -> float | None:
        future = [r.arrival_time for r in self.requests.values()
                  if r.state is RequestState.QUEUED
                  and r.arrival_time > self.now]
        return min(future) if future else None

    def _laned(self) -> list[Request]:
        return [r for r in self.lanes if r is not None]

    def _decoding(self) -> list[Request]:
        return [r for r in self.lanes
                if r is not None and r.state is RequestState.DECODE]

    def _prefilling(self) -> list[Request]:
        pf = [r for r in self.lanes
              if r is not None and r.state is RequestState.PREFILL]
        return sorted(pf, key=lambda r: self._admit_order[r.rid])

    def step(self):
        """Execute one scheduler action; returns the event (a typed
        `repro.serve.obs` event, tuple-compatible with the legacy log)
        or None when there is nothing left to do."""
        action = self.scheduler.decide(
            self._queued_visible(), self._next_arrival(),
            self._prefilling(), self._decoding(),
            self.lanes.count(None), self.backend.budget())
        if action.kind == "idle":
            return None
        if action.kind == "advance":
            self.now = action.next_time
            return self.obs.emit(AdvanceEvent(ts=action.next_time))
        ev = self._do_mixed(action)
        if ev is not None and ev.kind != "preempt_all":
            # utilization of EXECUTED batches
            phys, logical = self.backend.utilization()
            reg = self.obs.registry
            reg.inc("engine/util_phys_sum", phys)
            reg.inc("engine/util_logical_sum", logical)
            reg.inc("engine/util_samples")
        return ev

    def drain(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if all(r.state is RequestState.DONE
                   for r in self.requests.values()):
                return
            # a ("preempt_all", ...) step executes nothing but DOES
            # make progress (the released memory re-admits the evicted
            # requests), so only a genuinely idle None stalls
            if self.step() is None:
                break
        undone = [r.rid for r in self.requests.values()
                  if r.state is not RequestState.DONE]
        if undone:
            raise RuntimeError(f"drain stalled with requests {undone}")

    # -- actions ------------------------------------------------------------

    def _evict_newest(self, exclude: Request | None = None,
                      newer_than: Request | None = None,
                      reason: str = "memory_pressure") -> bool:
        """Backend eviction hook: preempt the latest-admitted laned
        request (optionally excluding one, optionally only requests
        admitted after `newer_than`). Returns False when no such
        victim exists — the backend decides what that means."""
        victims = [r for r in self._laned() if r is not exclude]
        if newer_than is not None:
            bar = self._admit_order[newer_than.rid]
            victims = [r for r in victims
                       if self._admit_order[r.rid] > bar]
        if not victims:
            return False
        self._preempt(max(victims,
                          key=lambda r: self._admit_order[r.rid]),
                      reason=reason)
        return True

    def _preempt(self, req: Request,
                 reason: str = "memory_pressure") -> None:
        phase = "prefill" if req.state is RequestState.PREFILL else "decode"
        # the backend drops only THIS request's memory (anything shared
        # with other requests stays resident)
        self.backend.release(req)
        req.seq_len = 0
        req.prefill_pos = 0
        self.lanes[req.lane] = None
        req.lane = -1
        req.state = RequestState.QUEUED
        req.n_preemptions += 1
        self.obs.registry.inc("engine/n_preemptions")
        self.obs.emit(PreemptEvent(ts=self.now, rid=req.rid,
                                   phase=phase, reason=reason))

    def _decode_growth_order(self) -> list[Request]:
        """Decode lanes oldest-admission first, so the backend's
        memory-pressure eviction lands on the newest request."""
        return sorted(self._decoding(),
                      key=lambda r: self._admit_order[r.rid])

    # -- sampling -----------------------------------------------------------

    def _sample_rows(self, logits, rows: list[tuple[int, Request]]
                     ) -> np.ndarray:
        """Sample one token per (row, request) from `(max_batch, V)`
        logits through the batched fixed-shape sampler. Each request
        draws on its own RNG lane keyed by (its seed, its token count
        so far) — never the engine step or the row — so its stream is
        batch-invariant and preemption-replayable; greedy lanes reduce
        to argmax, bit-identical to the pre-sampling greedy path.
        Unlisted rows are sampled as greedy garbage and ignored."""
        b = self.ecfg.max_batch
        temp = np.zeros((b,), np.float32)
        top_k = np.zeros((b,), np.int32)
        top_p = np.ones((b,), np.float32)
        seed = np.zeros((b,), np.uint32)
        pos = np.zeros((b,), np.int32)
        reg = self.obs.registry
        for row, req in rows:
            sp = req.sampling
            temp[row] = sp.temperature
            top_k[row] = sp.top_k
            top_p[row] = sp.top_p
            seed[row] = sp.seed
            pos[row] = len(req.generated)
            if sp.greedy:
                reg.inc(sampler.N_GREEDY_KEY)
            else:
                reg.inc(sampler.N_SAMPLED_KEY)
                # the virtual clock prices only the model forward, so
                # the sampling phase carries the token mix at zero
                # energy/time (see PhaseAttribution)
                req.attr.add("sampling", 1, 0.0, 0.0)
        return np.asarray(sampler.sample_tokens(
            logits, temp, top_k, top_p, seed, pos))

    def _do_mixed(self, action: Action):
        """Execute a prefill / decode / mixed step: fund all memory
        first (decode write targets, then prefill chunks — preemption
        between the halves is resolved before anything runs), then the
        decode and chunked-prefill forwards, then advance the clock
        ONCE by the price of the composed token count."""
        preempted_before = sum(r.n_preemptions
                               for r in self.requests.values())

        def evict_decode(**kw):
            return self._evict_newest(reason="decode_pressure", **kw)

        def evict_prefill(**kw):
            return self._evict_newest(reason="prefill_funding", **kw)

        # 1. make decode write targets safe, oldest admissions first
        #    so eviction pressure lands on the newest request
        if action.decode:
            self.backend.prepare_decode(self._decode_growth_order(),
                                        evict_decode)

        # 2. prefill chunk funding (plan order = admission order, then
        #    FCFS admissions); a request that was evicted after the
        #    plan was made is skipped
        chunks: list[tuple[Request, int]] = []
        for rid, want in action.prefill:
            req = self.requests[rid]
            if req.state is RequestState.QUEUED and req.lane < 0:
                if None not in self.lanes:
                    continue   # lanes filled by an earlier admission
                lane = self.lanes.index(None)
                req.lane = lane
                self.lanes[lane] = req
                req.state = RequestState.PREFILL
                self._admit_order[req.rid] = self._admit_seq
                self._admit_seq += 1
                plan = self.backend.admit(req)
                if self.obs.tracing:
                    self.obs.emit(AdmitEvent(
                        ts=self.now, rid=req.rid, lane=lane,
                        shared_tokens=plan.shared_tokens))
            elif req.state is not RequestState.PREFILL:
                continue       # preempted between plan and execution
            remaining = len(req.effective_prompt()) - req.prefill_pos
            n = self.backend.fund_prefill(req, min(want, remaining),
                                          evict_prefill)
            if n <= 0:
                continue
            chunks.append((req, n))
        # funding a later chunk may have evicted an earlier member of
        # this very batch — never run a chunk on released memory
        chunks = [(r, n) for r, n in chunks
                  if r.state is RequestState.PREFILL]

        # 3. decode forward over the lanes that survived funding. If
        #    the planned chunks could not be funded at all — the
        #    missing memory is held by OLDER requests, which eviction
        #    never touches — fall back to a decode round so those
        #    holders keep progressing and eventually release what the
        #    chunk is waiting on (drain must never stall while
        #    runnable lanes exist)
        run_decode = bool(action.decode)
        if not chunks and not run_decode and self._decoding():
            self.backend.prepare_decode(self._decode_growth_order(),
                                        evict_decode)
            run_decode = True
        dec_batch: list[Request] = []
        dec_next = None
        if run_decode:
            dec_batch = self._decoding()
        if dec_batch:
            logits = self.backend.decode_step(dec_batch)
            dec_next = self._sample_rows(
                logits, [(r.lane, r) for r in dec_batch])

        # 4. chunked + batched prefill forward (the backend advances
        #    each request's prefill_pos / seq_len)
        chunk_logits = None
        if chunks:
            chunk_logits = self.backend.prefill_step(chunks)

        # 5. one clock advance for the whole composed step, priced and
        #    energy-attributed once over the composed token count
        n_total = len(dec_batch) + sum(n for _, n in chunks)
        if n_total == 0:
            preempted = sum(r.n_preemptions
                            for r in self.requests.values())
            if preempted > preempted_before:
                # nothing ran, but the released memory makes the
                # re-queued requests immediately prefillable —
                # progress, not a stall (drain keeps going)
                return self.obs.emit(PreemptAllEvent(ts=self.now))
            return None
        price_ns = self.cost.price(n_total)
        energy_pj = self.cost.energy(n_total)
        dur_s = price_ns * 1e-9
        self.now += dur_s
        reg = self.obs.registry
        reg.inc("engine/busy_virtual_s", dur_s)
        reg.inc("engine/energy_pj", energy_pj)
        reg.observe("engine/step_tokens", n_total)
        # split the step's price/energy across participating lanes by
        # token share — summed over all requests this reproduces the
        # run's total simulated energy exactly (modulo fp)
        e_tok_J = energy_pj * 1e-12 / n_total
        t_tok_s = dur_s / n_total
        for req in dec_batch:
            req.attr.add("decode", 1, e_tok_J, t_tok_s)
        for req, n in chunks:
            req.attr.add("prefill", n, n * e_tok_J, n * t_tok_s)

        # the step event is emitted BEFORE results apply, so in the
        # trace its execution slices precede the finish/preempt marks
        # they lead to (span assembly relies on that nesting)
        dec_rids = tuple(r.rid for r in dec_batch)
        chunk_plan = tuple((req.rid, n) for req, n in chunks)
        fields = dict(ts=self.now, chunks=chunk_plan,
                      decode_rids=dec_rids, n_tokens=n_total,
                      dur_s=dur_s, price_ns=price_ns,
                      energy_pj=energy_pj)
        if action.kind == "decode" or not chunk_plan:
            ev = DecodeStepEvent(**fields)
        elif action.kind == "prefill" or not dec_rids:
            ev = PrefillStepEvent(**fields)
        else:
            ev = MixedStepEvent(**fields)
        self.obs.emit(ev)

        # 6. apply decode results
        for req in dec_batch:
            req.generated.append(int(dec_next[req.lane]))
            req.seq_len += 1
            if req.done:
                self._finish(req)

        # 7. apply prefill results: a chunk that completes its prompt
        #    samples the next token from the last VALID chunk position
        #    and flips the request to DECODE. The completing rows'
        #    last-position logits are gathered into one (max_batch, V)
        #    buffer so prefill first-tokens go through the SAME
        #    compiled sampler shape as decode rounds.
        completing = [(i, req) for i, (req, n) in enumerate(chunks)
                      if req.prefill_pos >= len(req.effective_prompt())]
        if completing:
            # device-side gather of row i's last valid position (only
            # the completing rows matter; the rest sample as ignored
            # greedy garbage) — never pull the whole (B, C, V) chunk
            # logits to host for a handful of rows
            b = self.ecfg.max_batch
            pos = np.zeros((b,), np.int32)
            for i, req in completing:
                pos[i] = chunks[i][1] - 1
            last = chunk_logits[jnp.arange(b), jnp.asarray(pos)]
            nxts = self._sample_rows(last, completing)
            for i, req in completing:
                req.generated.append(int(nxts[i]))
                if req.t_first_token is None:
                    req.t_first_token = self.now
                if req.done:
                    self._finish(req)
                else:
                    req.state = RequestState.DECODE

        return ev

    def _finish(self, req: Request) -> None:
        self.backend.release(req)
        if req.lane >= 0:
            self.lanes[req.lane] = None
            req.lane = -1
        req.state = RequestState.DONE
        req.t_done = self.now
        reg = self.obs.registry
        reg.inc("engine/n_done")
        reg.inc("engine/n_generated_tokens", len(req.generated))
        reg.observe("engine/latency_s", req.latency())
        ttft = req.ttft()
        if ttft is not None:
            reg.observe("engine/ttft_s", ttft)
        if self.obs.tracing:
            a = req.attr
            self.obs.emit(FinishEvent(
                ts=self.now, rid=req.rid,
                n_generated=len(req.generated),
                prefill_energy_J=a.energy_J["prefill"],
                decode_energy_J=a.energy_J["decode"],
                sampling_energy_J=a.energy_J["sampling"],
                prefill_s=a.virtual_s["prefill"],
                decode_s=a.virtual_s["decode"]))

    # -- results ------------------------------------------------------------

    def results(self) -> dict[int, np.ndarray]:
        return {rid: np.asarray(r.generated, np.int32)
                for rid, r in sorted(self.requests.items())}

    def attribution(self) -> dict[int, dict]:
        """Per-request energy/cost attribution: rid -> the request's
        `PhaseAttribution.summary()` (tokens / joules / virtual-seconds
        split over prefill, decode, and sampling). Covers every
        submitted request, finished or not; summing `total_energy_J`
        over all rids reproduces `metrics()["total_energy_J"]` within
        fp tolerance."""
        return {rid: r.attr.summary()
                for rid, r in sorted(self.requests.items())}

    def metrics(self) -> dict:
        """Aggregate run metrics, read back from the obs registry
        (every pre-obs key keeps its exact value — the registry's
        histograms are exact under their bin budget, and counters
        accumulate in the same order the old ad-hoc fields did)."""
        reg = self.obs.registry
        lat_h = reg.hist("engine/latency_s")
        ttft_h = reg.hist("engine/ttft_s")
        # every request the engine admits generates >= 1 token (submit
        # rejects max_new_tokens < 1), so done requests always have a
        # first-token time — ttft_h simply has no entry otherwise
        ttfts = ttft_h.values() if ttft_h is not None else []
        n_tok = int(reg.count("engine/n_generated_tokens"))
        samples = reg.count("engine/util_samples")
        total_energy_J = reg.count("engine/energy_pj") * 1e-12
        phase_energy_J = {p: 0.0 for p in PHASES}
        phase_virtual_s = {p: 0.0 for p in PHASES}
        for r in self.requests.values():
            for p in PHASES:
                phase_energy_J[p] += r.attr.energy_J[p]
                phase_virtual_s[p] += r.attr.virtual_s[p]
        return {
            "n_done": int(reg.count("engine/n_done")),
            "n_generated_tokens": n_tok,
            "virtual_time_s": self.now,
            "virtual_tok_per_s": n_tok / max(self.now, 1e-12),
            "p50_latency_s": (lat_h.percentile(50) if lat_h else 0.0),
            "p99_latency_s": (lat_h.percentile(99) if lat_h else 0.0),
            "mean_ttft_s": (float(np.mean(ttfts)) if ttfts else 0.0),
            "p50_ttft_s": (ttft_h.percentile(50) if ttft_h else 0.0),
            "p99_ttft_s": (ttft_h.percentile(99) if ttft_h else 0.0),
            "n_preemptions": int(reg.count("engine/n_preemptions")),
            "n_sampled_tokens": int(reg.count(sampler.N_SAMPLED_KEY)),
            "cache_utilization": (reg.count("engine/util_phys_sum")
                                  / max(samples, 1)),
            "logical_cache_utilization": (
                reg.count("engine/util_logical_sum") / max(samples, 1)),
            # observability additions (PR 6)
            "n_events": int(reg.count("engine/n_events")),
            "busy_virtual_s": reg.count("engine/busy_virtual_s"),
            "total_energy_J": total_energy_J,
            "prefill_energy_J": phase_energy_J["prefill"],
            "decode_energy_J": phase_energy_J["decode"],
            "sampling_energy_J": phase_energy_J["sampling"],
            "prefill_virtual_s": phase_virtual_s["prefill"],
            "decode_virtual_s": phase_virtual_s["decode"],
            "energy_per_token_J": total_energy_J / max(n_tok, 1),
            **self.backend.snapshot_metrics(),
        }
