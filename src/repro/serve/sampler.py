"""Per-request stochastic sampling with batch-invariant RNG lanes.

One jitted, fixed-shape batched sampler serves every token the engine
ever samples — decode rounds AND prefill-completion first tokens, over
BOTH sequence backends — at the engine's one compiled
`(max_batch, vocab)` shape. Per lane it applies the standard chain

    temperature scaling -> top-k mask -> top-p (nucleus) mask
    -> Gumbel-max draw

and a `temperature == 0` lane short-circuits to plain argmax,
bit-identical to `launch.steps.greedy_sample` (the greedy
token-identity suites are the anchor this rides on).

## The RNG-lane determinism contract

The key for a draw is a pure function of exactly two values:

    key = fold_in(PRNGKey(request.seed), request_local_position)

where `request_local_position` is how many tokens the request has
generated so far (`len(req.generated)` at sampling time). Nothing else
ever enters the key — not the engine step count, not the batch lane,
not which other requests share the step, not whether the token comes
from a decode round or a prefill-completion chunk. Consequences, all
pinned by tests/test_sampling.py + tests/test_serve_backend.py:

  * batch invariance — a request samples the same tokens alone or
    packed with any other requests, under any chunk size;
  * preemption replay — recompute-style preemption re-prefills the
    effective prompt and re-samples position `len(generated)` with the
    SAME key it would have used un-preempted, so recovery is
    bit-identical (given the backends' per-lane logits are themselves
    batch-invariant — a contract `serve.backend` records);
  * scheduler independence — cost vs fcfs composition cannot change
    any request's sampled stream.

Each lane draws its own Gumbel noise from its own key (vmap of
per-lane draws == each lane drawn alone), so garbage rows for idle
lanes cannot perturb live ones and there is no shared RNG stream to
race on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Metrics-registry keys the engine publishes sampler activity under
# (repro.serve.obs.MetricsRegistry): one counter per token drawn on a
# non-greedy RNG lane, one per greedy argmax token. Defined here so the
# sampler's observable surface lives next to the sampling contract.
N_SAMPLED_KEY = "sampler/n_sampled_tokens"
N_GREEDY_KEY = "sampler/n_greedy_tokens"


def lane_key(seed, pos):
    """RNG key for a request's `pos`-th sampled token: a pure function
    of (request seed, request-local position) and nothing else — see
    the module docstring for why that is the whole determinism story."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), pos)


def _sample_lane(logits, temperature, top_k, top_p, seed, pos):
    """One lane: temperature -> top-k -> top-p -> Gumbel-max. Greedy
    (temperature <= 0) reduces to argmax over the RAW logits, which is
    exactly `greedy_sample`."""
    v = logits.shape[-1]
    greedy = temperature <= 0.0
    # greedy lanes still trace the sampled branch; give them a safe
    # divisor so no inf/nan can leak out of operations XLA may not
    # short-circuit
    t = jnp.where(greedy, jnp.ones((), jnp.float32),
                  temperature.astype(jnp.float32))
    scaled = logits.astype(jnp.float32) / t
    # top-k: keep the k largest scaled logits (0 = keep all)
    keff = jnp.clip(jnp.where(top_k > 0, top_k, v) - 1, 0, v - 1)
    kth = jnp.sort(scaled)[::-1][keff]
    keep = scaled >= kth
    # top-p on the top-k-masked distribution: keep the minimal
    # descending-prob set whose mass reaches top_p (the top token
    # always survives: its exclusive cumulative mass is 0)
    probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf))
    sp = jnp.sort(probs)[::-1]
    exclusive = jnp.cumsum(sp) - sp
    cutoff = jnp.min(jnp.where(exclusive < top_p, sp, jnp.inf))
    keep = keep & (probs >= cutoff)
    g = jax.random.gumbel(lane_key(seed, pos), (v,), jnp.float32)
    sampled = jnp.argmax(jnp.where(keep, scaled, -jnp.inf) + g)
    return jnp.where(greedy, jnp.argmax(logits), sampled).astype(jnp.int32)


@jax.jit
def sample_tokens(logits, temperature, top_k, top_p, seed, pos):
    """Batched sampler: `(B, V)` logits + per-lane `(B,)` params ->
    `(B,)` i32 tokens. The engine calls this at its fixed
    `(max_batch, vocab)` shape, so it compiles once per geometry; rows
    the caller does not use (idle lanes, non-completing chunks) cost
    nothing but flops — every lane's draw is independent."""
    return jax.vmap(_sample_lane)(
        jnp.asarray(logits), jnp.asarray(temperature, jnp.float32),
        jnp.asarray(top_k, jnp.int32), jnp.asarray(top_p, jnp.float32),
        jnp.asarray(seed, jnp.uint32), jnp.asarray(pos, jnp.int32))
