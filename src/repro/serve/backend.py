"""Backend-agnostic sequence-memory API for the serving engine.

The engine, scheduler, and request lifecycle never touch pages, block
tables, prefix hashes, copy-on-write, or state slots directly: they
talk to a `SequenceBackend` through the narrow protocol below, and the
backend owns every family-specific device structure. Two backends
implement it:

  PagedKVBackend   — attention families (dense / moe). K/V grows with
                     the sequence, so memory is a pool of fixed-size
                     token pages: refcounting allocator, PrefixIndex
                     admission matching, copy-on-write forks, trash
                     page 0 for jit-stable idle lanes (a mechanical
                     extraction of the pre-backend engine, behavior
                     pinned token-identical by tests/test_serve.py).
  StateSlotBackend — recurrent families (rwkv6 / zamba2). Per-sequence
                     state is FIXED-SIZE (wkv matrices / SSD + conv
                     states / a bounded attention ring), so memory is a
                     pool of whole state slots: a request holds exactly
                     one slot from admission to release, decode can
                     never run out mid-flight, and preemption recovers
                     by recompute (the slot is dropped and the
                     effective prompt re-prefills into a fresh one).

## Protocol contract

Engine-owned request fields: `state`, `lane`, `generated`, `seq_len`,
`prefill_pos`. Backend-owned: `req.mem`, an opaque object the engine
must never inspect; it is created by `admit()` and destroyed by
`release()` (which must be idempotent — releasing a request without
`mem` is a no-op).

  validate(prompt_len, max_new_tokens)
      Raise ValueError if the request can never be served (exceeds the
      block table / pool / max_seq_len). Called at submit().
  admit(req) -> AdmitPlan
      Attach fresh sequence memory to an already-laned request. May
      start `req.prefill_pos`/`req.seq_len` past 0 when a leading run
      of the effective prompt is already resident (the prefix-share
      discount, reported as AdmitPlan.shared_tokens). Must not evict.
  probe_shared(req) -> int
      Read-only admission probe: leading effective-prompt tokens
      already resident in shareable memory. No side effects; safe to
      call every scheduling round (backends may memoize).
  budget() -> BudgetProbe
      A planning snapshot of free capacity for ONE scheduler decide():
      the scheduler charges candidate chunks/admissions against it
      without touching real allocator state.
  can_fund(req, n_tokens) -> bool
      Read-only: could the backend absorb n_tokens more tokens for
      `req` from FREE capacity, with no eviction?
  prepare_decode(reqs, evict)
      Make every listed decode request writable for one more token
      (grow a page at a boundary, COW-fork a shared page, ...).
      `reqs` arrive oldest-admission first; under memory pressure the
      backend calls `evict(exclude=..., newer_than=...) -> bool` and
      the ENGINE picks + preempts the newest victim (preemption policy
      stays engine-owned). Skip requests whose state changed mid-loop.
  fund_prefill(req, want, evict) -> int
      Reserve memory so `req` can absorb up to `want` more effective-
      prompt tokens; returns the granted count (possibly 0). May evict
      only requests admitted after `req` (via `evict(newer_than=req)`).
  prefill_step(chunks) -> logits (max_batch, C, V)
      Execute one composed chunk batch ([(req, n)] with n > 0, already
      funded) against device state, ADVANCE each request's
      `prefill_pos`/`seq_len`, and return per-position logits (row i =
      chunks[i]; the engine samples row i at position n-1 when a chunk
      completes its prompt). Device state is backend-internal — the
      engine never sees it.
  decode_step(reqs) -> logits (max_batch, V)
      One token for every request (row = req.lane; idle lanes are
      backend-masked). The engine samples, appends, and bumps
      `seq_len` — the backend must have made the write target safe in
      prepare_decode().

      BATCH-INVARIANCE CONSTRAINT: a request's per-lane logits from
      decode_step AND from prefill_step's last valid position must
      depend only on the request's own token history — bit-identical
      regardless of batch composition, lane placement, chunk
      boundaries, and recompute-after-preemption. The engine samples
      every emitted token through `repro.serve.sampler`, whose
      per-request RNG lanes make sampled streams deterministic ONLY
      under this contract (greedy argmax tolerates logit noise;
      sampled decode does not). Both existing backends satisfy it by
      construction (per-lane independent forwards at fixed compiled
      shapes); the sampled conformance suite in
      tests/test_serve_backend.py pins it for any future backend.
  release(req)
      Drop all of req's sequence memory (refcounts for shared pages, a
      whole slot, ...) and clear `req.mem`. Called on preemption and
      completion.
  utilization() -> (physical, logical)
      Fractions of the memory pool in use, sampled per executed step;
      logical >= physical when memory is shared across requests.
  snapshot_metrics() -> dict
      Backend-specific counters merged into engine.metrics().
  check_invariants()
      Assert internal consistency (no aliasing/leaks, indexed memory
      resident, ...); the conformance suite calls it after every step.

## Event-emission contract (observability)

`make_backend` hands every backend the engine's `repro.serve.obs`
Tracer (`obs`) and virtual-clock read (`clock() -> float`). A backend
participates in observability through exactly two channels:

  events — memory-lifecycle transitions the backend alone can see are
      emitted as TYPED obs events stamped with `clock()`, never as raw
      tuples: today `ShareEvent` (admission matched a resident prefix)
      and `CowForkEvent` (a write forked a co-owned page). Emit
      through `obs.emit(...)`; the Tracer decides whether the event is
      retained (level="trace") or only counted (level="metrics") — the
      backend must not branch on the level itself. Events must be
      emitted AT the transition (inside admit()/fund_prefill()/
      prepare_decode()), so span assembly sees them between the
      request's admit and finish/preempt markers, and their
      timestamps must be the current clock() — never a remembered one.
  registry — monotone counters go into `obs.registry` under the
      "backend/" prefix (the ONE namespace allowed to differ between
      backends; every other registry namespace must be
      backend-independent — the conformance suite pins this).
      `snapshot_metrics()` reads the registry back so its dict stays
      derivable from the registry alone.

A new backend that has nothing to share or fork simply emits nothing —
span assembly and the trace exporter treat backend events as optional
annotations, never required structure.

Adding a third backend (e.g. hybrid paged+slot for models mixing
attention and SSM layers) means implementing this class and routing
its families in `make_backend` — engine and scheduler need no changes.

## Static enforcement (`repro.analysis`)

The machine-checkable half of these contracts is enforced by the AST
checker (`PYTHONPATH=src python -m repro.analysis`, CI job `analyze`):
`backend-protocol` pins implementer signatures against the abstract
protocol below; `registry-namespace` pins the "backend/"-only registry
rule above (and the four serve namespaces everywhere else);
`wall-clock-in-serve` / `rng-key-discipline` / `host-sync-in-jit` /
`retrace-hazard` guard the virtual clock, the sampler's RNG-lane
derivation, and the compile-once jit design this module's
`_paged_steps`/`_slot_steps` factories implement. See the "Static
analysis" section of README.md for rules and suppression syntax.
"""
from __future__ import annotations

import abc
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import ArithmeticPolicy
from repro.models.config import ModelConfig
from repro.serve.mesh import (
    ServeMesh,
    kv_pool_sharding,
    make_serve_mesh,
    param_shardings,
)
from repro.serve.obs import CowForkEvent, ShareEvent, Tracer
from repro.serve.paged_cache import (
    TRASH_PAGE,
    PageAllocator,
    PrefixIndex,
    cow_copy_page,
    init_paged_cache,
)
from repro.serve.paged_model import (
    make_fused_paged_core,
    make_paged_chunked_prefill,
    make_paged_decode,
)
from repro.serve.request import Request, RequestState
from repro.serve.state_model import (
    TRASH_SLOT,
    init_slot_pool,
    make_slot_decode,
    make_slot_prefill_chunk,
    reset_slot,
)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serve configuration: engine-level knobs (batch lanes, chunk
    size, scheduler policy) plus the memory-pool geometry each backend
    interprets — paged backends read the page_* fields, state-slot
    backends read n_slots/max_seq_len."""
    page_size: int = 8
    n_pages: int = 128             # includes the reserved trash page 0
    max_batch: int = 4             # batch lanes (compiled batch width)
    max_pages_per_seq: int = 16    # block-table width
    prefill_chunk: int = 32        # prompt tokens per prefill chunk
    cache_dtype: str = "float32"
    scheduler: str = "cost"        # "cost" | "fcfs"
    scheme: str = "token_PP"       # hwsim dataflow used for pricing
    prefix_sharing: bool = True    # COW page sharing for common prefixes
    n_slots: int = 0               # state-slot pool size incl. trash
    #                                slot 0 (0 = auto: max_batch + 1)
    max_seq_len: int = 512         # per-sequence prompt+gen cap for
    #                                state-slot backends (sizes zamba2's
    #                                attention ring)
    observability: str = "metrics"   # "metrics" = counters/histograms
    #                                  only, no per-event retention;
    #                                  "trace" = keep the full typed
    #                                  event log for span assembly and
    #                                  Chrome trace export
    mesh_shards: int = 1             # tensor-parallel degree: 1 = the
    #                                  single-device strict no-op; > 1
    #                                  routes paged families through
    #                                  ShardedPagedBackend on a
    #                                  serve-mesh (serve/mesh.py)
    attn_impl: str = "gather"        # paged attention core: "gather"
    #                                  materializes the block table into
    #                                  a contiguous KV view (reference
    #                                  path); "fused" walks the block
    #                                  table inside the Pallas paged-
    #                                  attention kernel (exact-policy,
    #                                  single-device; interpreted off-TPU)

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page 0 is the reserved trash "
                f"page), got {self.n_pages}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_pages_per_seq < 1:
            raise ValueError(
                f"max_pages_per_seq must be >= 1, got "
                f"{self.max_pages_per_seq}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.scheduler not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        if self.n_slots != 0 and self.n_slots < 2:
            raise ValueError(
                f"n_slots must be 0 (auto) or >= 2 (slot 0 is the "
                f"reserved trash slot), got {self.n_slots}")
        if self.max_seq_len < 2:
            raise ValueError(
                f"max_seq_len must be >= 2, got {self.max_seq_len}")
        if self.observability not in Tracer.LEVELS:
            raise ValueError(
                f"observability must be one of {Tracer.LEVELS}, got "
                f"{self.observability!r}")
        if self.mesh_shards < 1:
            raise ValueError(
                f"mesh_shards must be >= 1, got {self.mesh_shards}")
        if self.attn_impl not in ("gather", "fused"):
            raise ValueError(
                f"attn_impl must be 'gather' or 'fused', got "
                f"{self.attn_impl!r}")
        jnp.dtype(self.cache_dtype)   # raises on nonsense dtypes


@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """What admission bought: `shared_tokens` effective-prompt tokens
    were already resident (the prefix-share discount — 0 for backends
    that cannot share sequence memory)."""
    shared_tokens: int = 0


class BudgetProbe(abc.ABC):
    """One scheduler decide()'s worth of free-capacity planning. The
    probe is a SNAPSHOT: granting decrements the probe's own budget,
    never the backend's real allocator — the engine funds the plan for
    real at execution time."""

    @abc.abstractmethod
    def grant_continue(self, req: Request, want: int,
                       forced: bool = False) -> int:
        """Tokens (<= want) a mid-prefill request's next chunk can
        absorb within the remaining budget. `forced` plans the chunk
        regardless of budget (the engine funds the oldest prefiller by
        evicting newer requests, so it is always plannable)."""

    @abc.abstractmethod
    def grant_admit(self, req: Request, want: int) -> int:
        """Tokens (<= want) a queued request's FIRST chunk can absorb
        if admitted now, charging the budget for the unshared part; 0
        means the admission is not fundable this step."""


class SequenceBackend(abc.ABC):
    """See the module docstring for the full protocol contract."""

    families: tuple[str, ...] = ()

    @abc.abstractmethod
    def validate(self, prompt_len: int, max_new_tokens: int) -> None: ...

    @abc.abstractmethod
    def admit(self, req: Request) -> AdmitPlan: ...

    @abc.abstractmethod
    def probe_shared(self, req: Request) -> int: ...

    @abc.abstractmethod
    def budget(self) -> BudgetProbe: ...

    @abc.abstractmethod
    def can_fund(self, req: Request, n_tokens: int) -> bool: ...

    @abc.abstractmethod
    def prepare_decode(self, reqs: list[Request], evict) -> None: ...

    @abc.abstractmethod
    def fund_prefill(self, req: Request, want: int, evict) -> int: ...

    @abc.abstractmethod
    def prefill_step(self, chunks: list[tuple[Request, int]]): ...

    @abc.abstractmethod
    def decode_step(self, reqs: list[Request]): ...

    @abc.abstractmethod
    def release(self, req: Request) -> None: ...

    @abc.abstractmethod
    def utilization(self) -> tuple[float, float]: ...

    @abc.abstractmethod
    def snapshot_metrics(self) -> dict: ...

    @abc.abstractmethod
    def check_invariants(self) -> None: ...


# ---------------------------------------------------------------------------
# paged KV backend (attention families)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _paged_steps(cfg: ModelConfig, policy: ArithmeticPolicy,
                 attn_impl: str = "gather"):
    """Jitted paged steps shared across backends with the same
    (cfg, policy, attn_impl): a fresh jax.jit wrapper per engine would
    recompile per instance, which both slows tests and lets compile
    time leak into benchmark drains (the warmup engine would warm
    nothing).  attn_impl="fused" swaps the step builders' `paged_core`
    seam for the Pallas block-table-walking kernel; the engine and
    scheduler never see the difference."""
    paged_core = (make_fused_paged_core(cfg, policy)
                  if attn_impl == "fused" else None)
    # donate the KV pool (arg 2): both steps return the updated pool
    # and the backend overwrites self.cache.kv with it, so XLA can
    # update pages in place instead of copying the whole pool
    return (jax.jit(make_paged_chunked_prefill(cfg, policy,
                                               paged_core=paged_core),
                    donate_argnums=(2,)),
            jax.jit(make_paged_decode(cfg, policy,
                                      paged_core=paged_core),
                    donate_argnums=(2,)))


@dataclasses.dataclass
class PagedSeqState:
    """PagedKVBackend's per-request `req.mem`."""
    pages: list[int] = dataclasses.field(default_factory=list)
    shared_len: int = 0          # leading tokens resident via prefix
    #                              sharing at admission: prefill skips
    #                              their writes, seq_len covers them


class PagedBudget(BudgetProbe):
    """Page-pool planning: charges whole pages, prefix-sharing aware —
    an admission is billed only for the UNSHARED pages of its first
    chunk (a fully-resident prompt admits at zero page cost; it only
    reruns its last token for logits)."""

    def __init__(self, page_size: int, free_pages: int, probe=None):
        self.page_size = page_size
        self.free = free_pages
        self.probe = probe or (lambda r: 0)

    def grant_continue(self, req: Request, want: int,
                       forced: bool = False) -> int:
        page = self.page_size
        pos = req.prefill_pos
        shared = req.mem.shared_len if req.mem is not None else 0
        # resident coverage: chunks written so far plus any shared
        # prefix (a sharer's cursor can sit BELOW its resident tokens
        # while it reruns the last prompt token for logits)
        covered = max(pos, shared)
        held = -(-covered // page)       # pages already allocated
        headroom = held * page - pos     # free slots in held pages
        n = want if forced else min(want, headroom + self.free * page)
        if n <= 0:
            return 0
        self.free -= max(0, -(-(pos + n) // page) - held)
        self.free = max(self.free, 0)
        return n

    def grant_admit(self, req: Request, want: int) -> int:
        page = self.page_size
        ep_len = len(req.effective_prompt())
        shared = min(self.probe(req), ep_len)
        # at least the last prompt token must run for its logits, so a
        # full prefix hit still admits a 1-token rerun chunk
        start = min(shared, ep_len - 1)
        held = -(-shared // page)        # pages sharing will grant
        n = min(want, ep_len - start,
                held * page + self.free * page - start)
        if n <= 0:
            return 0
        self.free -= max(0, -(-(start + n) // page) - held)
        return n


class PagedKVBackend(SequenceBackend):
    """Paged KV cache with refcounted copy-on-write prefix sharing.

    Memory = fixed-size token pages (`paged_cache.PageAllocator` +
    `PrefixIndex`); forwards = the jit-stable chunked-prefill / decode
    steps of `paged_model`. At admission the effective prompt is
    matched against the index of already-resident pages: matched pages
    are SHARED (refcount + 1) instead of re-prefilled, prefill skips
    their writes via the chunk's write_from mask, and a write landing
    in a co-owned page COW-forks it to a private device copy first.

    Device placement flows through the `serve.mesh` seam: parameters
    and the KV pool carry shardings from `parallel.sharding`
    (`_place_params` / `init_paged_cache(sharding=...)`), and on the
    default single-device mesh every placement helper is None — a
    strict no-op, bit-pinned by the conformance suite. Page ids,
    block tables, the allocator, and the PrefixIndex are LOGICAL
    (host-side), so the sharing/COW machinery is mesh-oblivious;
    `ShardedPagedBackend` (serve/sharded_backend.py) only overrides
    the jitted step factory.
    """

    families = ("dense", "moe")

    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: ArithmeticPolicy, params, obs: Tracer, clock,
                 mesh: ServeMesh | None = None):
        self.cfg = cfg
        self.ecfg = ecfg
        self.mesh = mesh if mesh is not None \
            else make_serve_mesh(ecfg.mesh_shards)
        self.params = self._place_params(params)
        self.cache = init_paged_cache(
            cfg, ecfg.n_pages, ecfg.page_size,
            dtype=jnp.dtype(ecfg.cache_dtype),
            sharding=kv_pool_sharding(self.mesh, cfg))
        self.prefix = PrefixIndex(ecfg.page_size)
        self._prefill_fn, self._decode_fn = self._steps(policy)
        self._obs = obs             # Tracer: events + metrics registry
        self._now = clock           # virtual-clock read: now() -> float
        # rid -> (index generation, matched, pages): the scheduler
        # probes every visible queued request each decide(), so match
        # results are memoized until the index mutates (a queued
        # request's effective prompt is fixed; invalidated on release)
        self._match_memo: dict[int, tuple[int, int, list[int]]] = {}

    # -- mesh seam ----------------------------------------------------------

    def _place_params(self, params):
        """Pin parameters to the mesh's TP shardings; identity (no
        device_put at all) on the single-device mesh."""
        shardings = param_shardings(self.mesh, self.cfg, params)
        if shardings is None:
            return params
        return jax.device_put(params, shardings)

    def _steps(self, policy: ArithmeticPolicy):
        """Jitted (prefill, decode) step pair. The single-device base
        uses the shared `_paged_steps` cache (routing the engine
        config's `attn_impl` to the gather or fused attention core);
        `ShardedPagedBackend` overrides this with mesh-sharded steps."""
        return _paged_steps(self.cfg, policy, self.ecfg.attn_impl)

    # -- admission ----------------------------------------------------------

    def validate(self, prompt_len: int, max_new_tokens: int) -> None:
        # last cache write lands at position prompt+gen-2 (the final
        # sampled token is never fed back), so this bounds page usage
        worst_pages = self.cache.allocator.pages_for(
            prompt_len + max_new_tokens - 1)
        if worst_pages > self.ecfg.max_pages_per_seq:
            raise ValueError(
                f"request needs up to {worst_pages} pages, block table "
                f"holds {self.ecfg.max_pages_per_seq}")
        if worst_pages > self.ecfg.n_pages - 1:
            raise ValueError(
                f"request needs up to {worst_pages} pages, pool has "
                f"{self.ecfg.n_pages - 1}")

    def _match_prefix(self, req: Request) -> tuple[int, list[int]]:
        """Memoized PrefixIndex.match for a queued request (one match
        serves both the scheduler's budget probe and admission)."""
        gen = self.prefix.generation
        hit = self._match_memo.get(req.rid)
        if hit is None or hit[0] != gen:
            matched, pages = self.prefix.match(req.effective_prompt())
            hit = (gen, matched, pages)
            self._match_memo[req.rid] = hit
        return hit[1], hit[2]

    def probe_shared(self, req: Request) -> int:
        if not self.ecfg.prefix_sharing:
            return 0
        return self._match_prefix(req)[0]

    def admit(self, req: Request) -> AdmitPlan:
        """Attach a page table; share every resident page covering a
        leading run of the effective prompt, start the prefill cursor
        past the shared tokens (capped so the last prompt token always
        reruns for its logits), and count the hit."""
        req.mem = PagedSeqState()
        ep = req.effective_prompt()
        reg = self._obs.registry
        reg.inc("backend/n_admissions")
        reg.inc("backend/prompt_tokens", len(ep))
        if not self.ecfg.prefix_sharing:
            return AdmitPlan()
        matched, spages = self._match_prefix(req)
        self._match_memo.pop(req.rid, None)   # ep changes once laned
        if matched <= 0:
            return AdmitPlan()
        self.cache.allocator.share(spages, req.rid)
        req.mem.pages = list(spages)
        req.mem.shared_len = matched
        req.seq_len = matched
        req.prefill_pos = min(matched, len(ep) - 1)
        reg.inc("backend/n_prefix_hits")
        reg.inc("backend/shared_tokens", matched)
        self._obs.emit(ShareEvent(ts=self._now(), rid=req.rid,
                                  matched=matched))
        return AdmitPlan(shared_tokens=matched)

    def budget(self) -> PagedBudget:
        return PagedBudget(self.ecfg.page_size,
                           self.cache.allocator.n_free,
                           probe=self.probe_shared)

    def can_fund(self, req: Request, n_tokens: int) -> bool:
        page = self.ecfg.page_size
        held = len(req.mem.pages) if req.mem is not None else 0
        pos = max(req.prefill_pos, req.seq_len)
        need = -(-(pos + n_tokens) // page) - held
        return need <= self.cache.allocator.n_free

    # -- memory pressure ----------------------------------------------------

    def _forget_released(self, pages: list[int], rid: int) -> None:
        """Drop `rid`'s ownership of `pages`; pages whose last owner
        left go back to the pool AND out of the prefix index."""
        released = self.cache.allocator.free(pages, owner=rid)
        self.prefix.forget(released)

    def _make_room(self, req: Request, evict) -> bool:
        """Free at least one page via the engine's eviction policy
        (evicting a sharer may release nothing physical, so keep
        going). False if req itself was evicted."""
        alloc = self.cache.allocator
        while not alloc.can_alloc(1):
            if not evict():
                # unreachable from engine flow (req itself is laned),
                # but external allocator users can drain the pool
                raise MemoryError("page pool dry with no evictable lane")
            if req.mem is None:
                return False      # req itself was the victim
        return True

    def _grow(self, req: Request, evict) -> bool:
        """Give `req` one more page, evicting under cache pressure.
        False if req itself was evicted."""
        if not self._make_room(req, evict):
            return False
        req.mem.pages.extend(self.cache.allocator.alloc(1, req.rid))
        return True

    def _divert_write(self, req: Request, j: int, evict) -> bool:
        """req is about to write into its page j, whose content other
        places may still rely on. Two cases: co-owned (refcount > 1) —
        COW-fork to a private device copy so the write cannot clobber
        co-owners' K/V; sole-owned but still in the prefix index (the
        co-owners left, e.g. the original writer finished) — the write
        diverges the page from its indexed content, so the index entry
        is dropped before a future admission can match stale K/V.
        False if req itself was evicted while making room for a fork."""
        if self.cache.allocator.refcount(req.mem.pages[j]) <= 1:
            self.prefix.forget([req.mem.pages[j]])
            return True
        return self._cow_fork(req, j, evict)

    def _cow_fork(self, req: Request, j: int, evict) -> bool:
        """Copy-on-write: replace `req`'s shared page j with a private
        device copy so its next write cannot clobber co-owners' K/V.
        False if req itself was evicted while making room."""
        if not self._make_room(req, evict):
            return False
        alloc = self.cache.allocator
        old = req.mem.pages[j]
        if alloc.refcount(old) <= 1:
            # co-owners were evicted while making room; the page may
            # still be indexed, and the write is about to diverge it
            self.prefix.forget([old])
            return True
        [new] = alloc.alloc(1, req.rid)
        self.cache.kv = cow_copy_page(
            self.cache.kv, jnp.int32(old), jnp.int32(new))
        req.mem.pages[j] = new
        self._forget_released([old], req.rid)
        self._obs.registry.inc("backend/n_cow_forks")
        self._obs.emit(CowForkEvent(ts=self._now(), rid=req.rid,
                                    old_page=old, new_page=new))
        return True

    def prepare_decode(self, reqs: list[Request], evict) -> None:
        """Prepare every decode lane's write target, oldest admissions
        first so eviction pressure lands on the newest request: lanes
        at a page boundary get a fresh page; lanes about to write into
        a SHARED page (another request references it) COW-fork it to a
        private copy first."""
        page = self.ecfg.page_size
        for req in reqs:
            if req.state is not RequestState.DECODE:
                continue   # evicted earlier in this very loop
            if req.seq_len >= len(req.mem.pages) * page:
                self._grow(req, evict)
            else:
                self._divert_write(req, req.seq_len // page, evict)

    def fund_prefill(self, req: Request, want: int, evict) -> int:
        """Allocate pages so `req` can absorb `want` more prompt
        tokens. Under pressure, only requests admitted AFTER `req` are
        evicted (pressure always lands on the newest, so a fresh
        admission can never evict an older request). Returns the
        granted token count — possibly < want, or 0, when the pool
        cannot fund the chunk without touching older requests."""
        page = self.ecfg.page_size
        alloc = self.cache.allocator
        end = req.prefill_pos + want
        while len(req.mem.pages) * page < end:
            if alloc.can_alloc(1):
                req.mem.pages.extend(alloc.alloc(1, req.rid))
                continue
            if not evict(exclude=req, newer_than=req):
                break
        n = min(want, len(req.mem.pages) * page - req.prefill_pos)
        if n <= 0:
            return 0
        # copy-on-write: this chunk WRITES positions [ws, we) (rerun
        # positions below shared_len only read); any of those pages
        # still co-owned must be forked before the scatter runs
        ws = max(req.prefill_pos, req.mem.shared_len)
        we = req.prefill_pos + n
        if ws < we:
            for j in range(ws // page, -(-we // page)):
                if not self._divert_write(req, j, evict):
                    return 0       # req itself evicted making room
        return n

    # -- forwards -----------------------------------------------------------

    def _register_full_pages(self, req: Request, from_seq: int) -> None:
        """Index every page that BECAME full while req's resident
        coverage grew from from_seq to req.seq_len (prefill only —
        decode-filled pages hold generated tokens no other prompt is
        likely to revisit, and keeping them out keeps forgetting
        simple)."""
        if not self.ecfg.prefix_sharing:
            return
        page = self.ecfg.page_size
        ep = req.effective_prompt()
        for j in range(from_seq // page, req.seq_len // page):
            self.prefix.register(ep[:(j + 1) * page], req.mem.pages[j])

    def prefill_step(self, chunks: list[tuple[Request, int]]):
        b, c = self.ecfg.max_batch, self.ecfg.prefill_chunk
        pmax = self.ecfg.max_pages_per_seq
        tokens = np.zeros((b, c), np.int32)
        tables = np.full((b, pmax), TRASH_PAGE, np.int32)
        start = np.zeros((b,), np.int32)
        lens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        wfrom = np.zeros((b,), np.int32)
        for i, (req, n) in enumerate(chunks):
            ep = req.effective_prompt()
            tokens[i, :n] = ep[req.prefill_pos:req.prefill_pos + n]
            tables[i, :len(req.mem.pages)] = req.mem.pages
            start[i] = req.prefill_pos
            lens[i] = n
            active[i] = True
            # positions below shared_len are resident in (possibly
            # shared) pages: rerun the query, skip the write
            wfrom[i] = req.mem.shared_len
        logits, kv = self._prefill_fn(
            self.params, jnp.asarray(tokens), self.cache.kv,
            jnp.asarray(tables), jnp.asarray(start),
            jnp.asarray(lens), jnp.asarray(active),
            jnp.asarray(wfrom))
        self.cache.kv = kv
        for req, n in chunks:
            old_seq = req.seq_len
            req.prefill_pos += n
            # a sharer rerunning inside its shared prefix already has
            # seq_len past the cursor — coverage never shrinks
            req.seq_len = max(req.seq_len, req.prefill_pos)
            self._register_full_pages(req, old_seq)
        return logits

    def decode_step(self, reqs: list[Request]):
        b, pmax = self.ecfg.max_batch, self.ecfg.max_pages_per_seq
        tokens = np.zeros((b, 1), np.int32)
        tables = np.full((b, pmax), TRASH_PAGE, np.int32)
        seq_lens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for req in reqs:
            tokens[req.lane, 0] = req.generated[-1]
            tables[req.lane, :len(req.mem.pages)] = req.mem.pages
            seq_lens[req.lane] = req.seq_len
            active[req.lane] = True
        logits, kv = self._decode_fn(
            self.params, jnp.asarray(tokens), self.cache.kv,
            jnp.asarray(tables), jnp.asarray(seq_lens),
            jnp.asarray(active))
        self.cache.kv = kv
        return logits

    # -- release / accounting -----------------------------------------------

    def release(self, req: Request) -> None:
        """Drop req's page references; co-owned pages stay resident
        for the other sharers."""
        if req.mem is None:
            return
        if req.mem.pages:
            self._forget_released(req.mem.pages, req.rid)
        req.mem = None
        # the effective prompt grows with generated tokens, so any
        # memoized prefix match is stale even at the same generation
        self._match_memo.pop(req.rid, None)

    def utilization(self) -> tuple[float, float]:
        return self.cache.utilization(), self.cache.logical_utilization()

    def snapshot_metrics(self) -> dict:
        reg = self._obs.registry
        return {
            "n_prefix_hits": int(reg.count("backend/n_prefix_hits")),
            "prefix_hit_rate": (
                reg.count("backend/shared_tokens")
                / max(reg.count("backend/prompt_tokens"), 1)),
            "n_cow_forks": int(reg.count("backend/n_cow_forks")),
            "physical_pages_allocated":
                self.cache.allocator.total_allocated,
        }

    def check_invariants(self) -> None:
        self.cache.allocator.check_invariants()
        for p in self.prefix.pages():
            assert self.cache.allocator.refcount(p) >= 1, \
                f"prefix index advertises non-resident page {p}"


# ---------------------------------------------------------------------------
# state-slot backend (recurrent families)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _slot_steps(cfg: ModelConfig, policy: ArithmeticPolicy):
    """Jitted state-slot steps shared across backends with the same
    (cfg, policy) — same sharing rationale as _paged_steps. The slot
    pool (arg 2) is donated: both steps return the updated pool and
    the backend overwrites self.pool with it."""
    return (jax.jit(make_slot_prefill_chunk(cfg, policy),
                    donate_argnums=(2,)),
            jax.jit(make_slot_decode(cfg, policy),
                    donate_argnums=(2,)))


@dataclasses.dataclass
class SlotSeqState:
    """StateSlotBackend's per-request `req.mem`."""
    slot: int


class SlotBudget(BudgetProbe):
    """Slot-pool planning: a sequence costs exactly ONE slot for its
    whole lifetime, so continuing chunks are free (the slot is already
    held) and an admission charges one slot."""

    def __init__(self, free_slots: int):
        self.free = free_slots

    def grant_continue(self, req: Request, want: int,
                       forced: bool = False) -> int:
        return want

    def grant_admit(self, req: Request, want: int) -> int:
        if self.free <= 0:
            return 0
        self.free -= 1
        return min(want, len(req.effective_prompt()))


class StateSlotBackend(SequenceBackend):
    """Fixed pool of per-lane recurrent state slots.

    A request holds exactly one slot from admission to release; the
    slot is reset to the family's pristine initial cache on
    allocation, chunked prefill absorbs the effective prompt into it
    (per-token, exact for any per-lane chunk length — see
    `state_model`), and decode advances it one token per step. State
    is a dense mixture of the whole history, so there is nothing to
    prefix-share (probe_shared == 0) and nothing to grow — once
    admitted, a request can ALWAYS decode to completion, so the only
    eviction this backend ever sees is externally forced, and
    preemption recovers by recompute into a fresh slot.
    """

    families = ("rwkv6", "zamba2")

    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: ArithmeticPolicy, params, obs: Tracer, clock):
        self.cfg = cfg
        self.ecfg = ecfg
        self.params = params
        self.n_slots = ecfg.n_slots or ecfg.max_batch + 1
        # the page allocator is a generic refcounting free list over
        # ids [1, n); reused here as the slot allocator (slot "size" 1,
        # refcounts stay at 1 — slots are never shared)
        self.allocator = PageAllocator(self.n_slots, 1)
        self.pool, self.init_slot = init_slot_pool(
            cfg, self.n_slots, ecfg.max_seq_len,
            dtype=jnp.dtype(ecfg.cache_dtype))
        self._prefill_fn, self._decode_fn = _slot_steps(cfg, policy)
        self._obs = obs
        self._now = clock

    # -- admission ----------------------------------------------------------

    def validate(self, prompt_len: int, max_new_tokens: int) -> None:
        # the final sampled token is never fed back into the state
        total = prompt_len + max_new_tokens - 1
        if total > self.ecfg.max_seq_len:
            raise ValueError(
                f"request absorbs up to {total} tokens, max_seq_len "
                f"is {self.ecfg.max_seq_len}")

    def admit(self, req: Request) -> AdmitPlan:
        if not self.allocator.can_alloc(1):
            # unreachable from engine flow: the scheduler budgets
            # admissions against free slots via SlotBudget
            raise MemoryError("state-slot pool dry at admission")
        [slot] = self.allocator.alloc(1, req.rid)
        # a freed slot holds its previous occupant's state; reset to
        # the pristine initial cache before the new prompt lands
        self.pool = reset_slot(self.pool, self.init_slot,
                               jnp.int32(slot))
        req.mem = SlotSeqState(slot=slot)
        reg = self._obs.registry
        reg.inc("backend/n_admissions")
        reg.inc("backend/prompt_tokens", len(req.effective_prompt()))
        return AdmitPlan()

    def probe_shared(self, req: Request) -> int:
        return 0

    def budget(self) -> SlotBudget:
        return SlotBudget(self.allocator.n_free)

    def can_fund(self, req: Request, n_tokens: int) -> bool:
        if req.mem is not None:
            return True          # the slot absorbs any token count
        return self.allocator.can_alloc(1)

    def prepare_decode(self, reqs: list[Request], evict) -> None:
        pass                     # fixed-size state never grows

    def fund_prefill(self, req: Request, want: int, evict) -> int:
        return want              # the slot was funded at admission

    # -- forwards -----------------------------------------------------------

    def prefill_step(self, chunks: list[tuple[Request, int]]):
        b, c = self.ecfg.max_batch, self.ecfg.prefill_chunk
        tokens = np.zeros((b, c), np.int32)
        slot_ids = np.full((b,), TRASH_SLOT, np.int32)
        lens = np.zeros((b,), np.int32)
        active = np.zeros((b,), bool)
        for i, (req, n) in enumerate(chunks):
            ep = req.effective_prompt()
            tokens[i, :n] = ep[req.prefill_pos:req.prefill_pos + n]
            slot_ids[i] = req.mem.slot
            lens[i] = n
            active[i] = True
        logits, pool = self._prefill_fn(
            self.params, jnp.asarray(tokens), self.pool,
            jnp.asarray(slot_ids), jnp.asarray(lens),
            jnp.asarray(active))
        self.pool = pool
        for req, n in chunks:
            req.prefill_pos += n
            req.seq_len = req.prefill_pos
        return logits

    def decode_step(self, reqs: list[Request]):
        b = self.ecfg.max_batch
        tokens = np.zeros((b, 1), np.int32)
        slot_ids = np.full((b,), TRASH_SLOT, np.int32)
        for req in reqs:
            tokens[req.lane, 0] = req.generated[-1]
            slot_ids[req.lane] = req.mem.slot
        logits, pool = self._decode_fn(
            self.params, jnp.asarray(tokens), self.pool,
            jnp.asarray(slot_ids))
        self.pool = pool
        return logits

    # -- release / accounting -----------------------------------------------

    def release(self, req: Request) -> None:
        if req.mem is None:
            return
        self.allocator.free([req.mem.slot], owner=req.rid)
        req.mem = None

    def utilization(self) -> tuple[float, float]:
        u = self.allocator.n_used / max(self.n_slots - 1, 1)
        return u, u              # slots are never shared

    def snapshot_metrics(self) -> dict:
        return {
            "n_state_slots": self.n_slots - 1,
            "state_slots_allocated": self.allocator.total_allocated,
        }

    def check_invariants(self) -> None:
        self.allocator.check_invariants()
        assert self.allocator.n_logical == self.allocator.n_used, \
            "state slots must never be shared across requests"


# ---------------------------------------------------------------------------
# family routing
# ---------------------------------------------------------------------------


def make_backend(cfg: ModelConfig, ecfg: EngineConfig,
                 policy: ArithmeticPolicy, params, obs: Tracer,
                 clock, mesh: ServeMesh | None = None) -> SequenceBackend:
    """Route a model family (and mesh) to its sequence backend. `obs`
    is the engine's observability hub (repro.serve.obs.Tracer:
    typed-event sink + metrics registry), `clock` reads the engine's
    virtual time (clock() -> float) — see the module docstring's
    event-emission contract. `mesh` is the engine's serve-mesh seam
    (defaults from ecfg.mesh_shards); a multi-shard mesh routes paged
    families through the tensor-parallel `ShardedPagedBackend`."""
    mesh = mesh if mesh is not None else make_serve_mesh(ecfg.mesh_shards)
    if not mesh.is_single:
        from repro.serve.sharded_backend import ShardedPagedBackend
        if cfg.family in ShardedPagedBackend.families:
            return ShardedPagedBackend(cfg, ecfg, policy, params, obs,
                                       clock, mesh=mesh)
        raise ValueError(
            f"family {cfg.family!r} has no multi-device backend "
            f"(state-slot families serve single-device; set "
            f"mesh_shards=1)")
    for backend_cls in (PagedKVBackend, StateSlotBackend):
        if cfg.family in backend_cls.families:
            return backend_cls(cfg, ecfg, policy, params, obs, clock)
    served = PagedKVBackend.families + StateSlotBackend.families
    raise ValueError(
        f"no sequence backend serves family {cfg.family!r} "
        f"(available: {served})")
