"""Block-based paged KV cache — free-list allocator + device page pool.

The dense cache in `models/transformer.py` keys every request to one
(B, Smax) rectangle with a single shared write index, which is exactly
what continuous batching cannot use: requests enter and leave the batch
at different sequence lengths. Here KV storage is a pool of fixed-size
pages shared by all in-flight requests:

  k/v pool : (L, n_pages, page_size, KV, Dh)   device arrays
  allocator: host-side free list handing out page ids
  per-request page table: ordered page ids; the j-th page of a request
             holds its token positions [j*page_size, (j+1)*page_size).

Page 0 is RESERVED as the trash page: jit'd decode steps run at a fixed
max-batch shape, and inactive batch lanes scatter their (garbage) K/V
into page 0 / read from it behind the length mask — so the compiled
step never sees a data-dependent shape.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.models.config import ModelConfig

TRASH_PAGE = 0


class PageAllocator:
    """Free-list allocator over `n_pages` fixed-size pages.

    Page ids are ints in [1, n_pages); page 0 (TRASH_PAGE) is never
    handed out. Allocation is LIFO on the free list so tests can pin
    down exact page reuse; correctness only needs the invariants:
    no page is owned twice, and freed pages return to the pool.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO: low page ids come back first (deterministic)
        self._free = list(range(n_pages - 1, 0, -1))
        self._owner: dict[int, int] = {}   # page id -> request id

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._owner)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold n_tokens."""
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: int) -> list[int]:
        """Take n pages for request `owner`; raises if the pool is dry."""
        if n > len(self._free):
            raise MemoryError(
                f"paged cache exhausted: want {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if p not in self._owner:
                raise ValueError(f"double free of page {p}")
            del self._owner[p]
            self._free.append(p)

    def owner_of(self, page: int) -> int | None:
        return self._owner.get(page)

    def check_invariants(self) -> None:
        """No aliasing, no leaks: free + used partition [1, n_pages)."""
        free = set(self._free)
        used = set(self._owner)
        assert len(free) == len(self._free), "duplicate pages on free list"
        assert not (free & used), f"aliased pages {free & used}"
        assert free | used == set(range(1, self.n_pages)), "leaked pages"
        assert TRASH_PAGE not in free and TRASH_PAGE not in used


@dataclasses.dataclass
class PagedKVCache:
    """Device page pool + its host-side allocator."""
    kv: dict                 # {"k","v"}: (L, n_pages, page, KV, Dh)
    allocator: PageAllocator

    @property
    def page_size(self) -> int:
        return self.kv["k"].shape[2]

    @property
    def n_pages(self) -> int:
        return self.kv["k"].shape[1]

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by requests."""
        return self.allocator.n_used / max(self.allocator.n_pages - 1, 1)


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32) -> PagedKVCache:
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"paged KV cache needs an attention family, got {cfg.family!r}")
    kv_heads, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, n_pages, page_size, kv_heads, hd)
    kv = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    return PagedKVCache(kv=kv, allocator=PageAllocator(n_pages, page_size))


def pad_to_page(n_tokens: int, page_size: int) -> int:
    """Prompt lengths are bucketed to page multiples so the jitted
    prefill retraces once per bucket, not once per length."""
    return max(page_size, -(-n_tokens // page_size) * page_size)
