"""Block-based paged KV cache — refcounting allocator + prefix index.

The dense cache in `models/transformer.py` keys every request to one
(B, Smax) rectangle with a single shared write index, which is exactly
what continuous batching cannot use: requests enter and leave the batch
at different sequence lengths. Here KV storage is a pool of fixed-size
pages shared by all in-flight requests:

  k/v pool : (L, n_pages, page_size, KV, Dh)   device arrays
  allocator: host-side refcounting free list handing out page ids
  per-request page table: ordered page ids; the j-th page of a request
             holds its token positions [j*page_size, (j+1)*page_size).

Page 0 is RESERVED as the trash page: jit'd decode steps run at a fixed
max-batch shape, and inactive batch lanes scatter their (garbage) K/V
into page 0 / read from it behind the length mask — so the compiled
step never sees a data-dependent shape.

PREFIX SHARING: a page's K/V content is a pure function of the token
sequence [0, page_end) that produced it (attention makes every layer's
K/V depend on the whole prefix, not just the page's own tokens), so two
requests whose prompts agree on that whole prefix can share the page.
`PageAllocator` therefore refcounts: `alloc` hands out pages at
refcount 1, `share` adds an owner to a resident page, and `free`
decrements — the page returns to the free list only when its LAST
owner releases it. `PrefixIndex` maps chained hashes of full-page
token runs to resident page ids so admission can find shareable pages;
divergence (writing into a page another request still references) is
resolved by the paged-KV backend (`repro.serve.backend`) with
`cow_copy_page` — allocate a private page, copy the K/V slice on
device, swap the page-table entry.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

TRASH_PAGE = 0


class PageAllocator:
    """Refcounting free-list allocator over `n_pages` fixed-size pages.

    Page ids are ints in [1, n_pages); page 0 (TRASH_PAGE) is never
    handed out. A page may have MULTIPLE owners (prefix sharing):
    `alloc` creates it at refcount 1, `share` adds owners, `free`
    removes one owner per call and returns the page to the pool only
    when the refcount hits zero. Allocation is LIFO on the free list so
    tests can pin down exact page reuse; within one `free` call the
    released pages re-enter the free list in sorted-DESCENDING order
    (so the next pops return the lowest id first) — reuse order must
    not depend on each call site's incidental list ordering.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        self.n_pages = n_pages
        self.page_size = page_size
        # LIFO: low page ids come back first (deterministic)
        self._free = list(range(n_pages - 1, 0, -1))
        self._owners: dict[int, set[int]] = {}   # page id -> owner rids
        self.total_allocated = 0   # monotone count of pages handed out

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        """PHYSICAL pages currently live (shared pages count once)."""
        return len(self._owners)

    @property
    def n_logical(self) -> int:
        """Sum of refcounts — what n_used would be without sharing."""
        return sum(len(o) for o in self._owners.values())

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold n_tokens."""
        return max(1, -(-n_tokens // self.page_size))

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int, owner: int) -> list[int]:
        """Take n pages for request `owner`; raises if the pool is dry."""
        if n > len(self._free):
            raise MemoryError(
                f"paged cache exhausted: want {n}, free {len(self._free)}")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owners[p] = {owner}
        self.total_allocated += n
        return pages

    def share(self, pages: list[int], owner: int) -> None:
        """Add `owner` as a co-owner of already-resident pages
        (refcount + 1 each). Sharing a free page or double-sharing the
        same page for one owner is a bug, not a no-op."""
        for p in pages:
            owners = self._owners.get(p)
            if owners is None:
                raise ValueError(f"cannot share free page {p}")
            if owner in owners:
                raise ValueError(
                    f"request {owner} already owns page {p}")
        for p in pages:
            self._owners[p].add(owner)

    def free(self, pages: list[int], owner: int | None = None) -> list[int]:
        """Release one ownership of each page. Pages whose refcount hits
        zero return to the free list (sorted descending within this
        call, see class docstring) and are returned to the caller so a
        prefix index can forget them. `owner=None` is accepted only for
        unshared pages (the single owner is implied)."""
        drop: list[tuple[int, int]] = []
        seen: dict[int, int] = {}
        for p in pages:
            owners = self._owners.get(p)
            if owners is None or seen.get(p, 0) >= len(owners):
                raise ValueError(f"double free of page {p}")
            if owner is not None:
                if seen.get(p):
                    raise ValueError(f"double free of page {p}")
                if owner not in owners:
                    raise ValueError(
                        f"request {owner} does not own page {p}")
                drop.append((p, owner))
            else:
                if len(owners) > 1:
                    raise ValueError(
                        f"page {p} is shared ({len(owners)} owners): "
                        f"free needs an explicit owner")
                drop.append((p, next(iter(owners))))
            seen[p] = seen.get(p, 0) + 1
        released = []
        for p, o in drop:
            owners = self._owners[p]
            owners.discard(o)
            if not owners:
                del self._owners[p]
                released.append(p)
        self._free.extend(sorted(released, reverse=True))
        return released

    def refcount(self, page: int) -> int:
        return len(self._owners.get(page, ()))

    def owners_of(self, page: int) -> frozenset[int]:
        return frozenset(self._owners.get(page, ()))

    def check_invariants(self) -> None:
        """No aliasing, no leaks: free + used partition [1, n_pages);
        every live page has refcount >= 1 (owner sets are non-empty and
        shared pages are counted once physically)."""
        free = set(self._free)
        used = set(self._owners)
        assert len(free) == len(self._free), "duplicate pages on free list"
        assert not (free & used), f"aliased pages {free & used}"
        assert free | used == set(range(1, self.n_pages)), "leaked pages"
        assert TRASH_PAGE not in free and TRASH_PAGE not in used
        for p, owners in self._owners.items():
            assert owners, f"live page {p} with refcount 0"
        assert self.n_logical >= self.n_used, "refcount accounting broken"


class PrefixIndex:
    """Token-run -> resident-page index for prefix sharing.

    A page holding positions [j*page, (j+1)*page) is keyed by the hash
    of the WHOLE token prefix [0, (j+1)*page) — K/V content depends on
    everything before it, so the chain key, not the page's own tokens,
    identifies shareable content. Matching walks the chain page by
    page; the stored per-page tokens are compared on every hit so a
    hash collision can never corrupt outputs. A final PARTIAL match is
    allowed when the prompt ends mid-page: a resident page whose token
    run starts with the prompt's remainder covers it (the sharer masks
    the tail by seq_len, and its first divergent write COW-forks the
    page).

    First writer wins: registering content that is already indexed is a
    no-op, and a page is never indexed twice. `forget` must be called
    with pages the allocator actually released.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self._chain: dict[bytes, int] = {}      # prefix digest -> page
        # page -> (own key, parent key, this page's tokens)
        self._entries: dict[int, tuple[bytes, bytes, np.ndarray]] = {}
        self._children: dict[bytes, list[int]] = {}  # parent key -> pages
        # bumped on every mutation so callers can memoize match results
        self.generation = 0

    def __len__(self) -> int:
        return len(self._entries)

    def pages(self) -> list[int]:
        """Currently-indexed page ids (for invariant checks: every
        indexed page must still be resident in the allocator)."""
        return list(self._entries)

    @staticmethod
    def _digest(tokens: np.ndarray) -> bytes:
        buf = np.ascontiguousarray(tokens, dtype=np.int32).tobytes()
        return hashlib.sha1(buf).digest()

    def register(self, prefix: np.ndarray, page: int) -> bool:
        """Index `page` as holding the last `page_size` tokens of
        `prefix` (whose length must be a positive page multiple).
        Returns False when the content is already indexed (first
        writer wins) or the page already has an entry."""
        prefix = np.asarray(prefix, np.int32).reshape(-1)
        ps = self.page_size
        if len(prefix) < ps or len(prefix) % ps:
            raise ValueError(
                f"prefix length {len(prefix)} is not a positive multiple "
                f"of page_size {ps}")
        if page in self._entries:
            return False
        key = self._digest(prefix)
        if key in self._chain:
            return False
        parent = self._digest(prefix[:-ps])
        self._chain[key] = page
        self._entries[page] = (key, parent, prefix[-ps:].copy())
        self._children.setdefault(parent, []).append(page)
        self.generation += 1
        return True

    def forget(self, pages: list[int]) -> None:
        """Drop released pages from the index (pages never indexed are
        ignored — private/partial pages are a normal case)."""
        for p in pages:
            entry = self._entries.pop(p, None)
            if entry is None:
                continue
            key, parent, _ = entry
            if self._chain.get(key) == p:
                del self._chain[key]
            kids = self._children.get(parent)
            if kids is not None:
                kids.remove(p)
                if not kids:
                    del self._children[parent]
            self.generation += 1

    def match(self, prompt: np.ndarray) -> tuple[int, list[int]]:
        """Longest resident prefix of `prompt`: returns (matched_len,
        pages). Full pages match by chain key (token-verified); if the
        prompt then ends mid-page, a resident sibling page whose run
        starts with the remainder extends the match to the whole
        prompt (registration order breaks ties deterministically)."""
        prompt = np.ascontiguousarray(prompt, np.int32).reshape(-1)
        ps = self.page_size
        pages: list[int] = []
        j = 0
        # ONE incremental hash walks the chain (digest() does not
        # finalize, so each level costs one page of hashing, not a
        # re-hash of the whole prefix)
        h = hashlib.sha1()
        matched_key = h.digest()
        while (j + 1) * ps <= len(prompt):
            h.update(prompt[j * ps:(j + 1) * ps].tobytes())
            key = h.digest()
            page = self._chain.get(key)
            if page is None:
                break
            if not np.array_equal(self._entries[page][2],
                                  prompt[j * ps:(j + 1) * ps]):
                break   # hash collision: treat as a miss
            pages.append(page)
            matched_key = key
            j += 1
        matched = j * ps
        rem = len(prompt) - matched
        if 0 < rem < ps:
            for page in self._children.get(matched_key, ()):
                if np.array_equal(self._entries[page][2][:rem],
                                  prompt[matched:]):
                    pages.append(page)
                    matched = len(prompt)
                    break
        return matched, pages


@functools.partial(jax.jit, donate_argnums=0)
def cow_copy_page(kv, src, dst):
    """Copy page `src` -> `dst` across all layers on device (the
    copy-on-write fork). src/dst are traced scalars so every fork
    shares one compiled scatter, whatever the page ids."""
    return {"k": kv["k"].at[:, dst].set(kv["k"][:, src]),
            "v": kv["v"].at[:, dst].set(kv["v"][:, src])}


@dataclasses.dataclass
class PagedKVCache:
    """Device page pool + its host-side allocator."""
    kv: dict                 # {"k","v"}: (L, n_pages, page, KV, Dh)
    allocator: PageAllocator

    @property
    def page_size(self) -> int:
        return self.kv["k"].shape[2]

    @property
    def n_pages(self) -> int:
        return self.kv["k"].shape[1]

    def utilization(self) -> float:
        """Fraction of allocatable pages PHYSICALLY live (shared pages
        count once — this is what bounds admission)."""
        return self.allocator.n_used / max(self.allocator.n_pages - 1, 1)

    def logical_utilization(self) -> float:
        """Per-request page-table footprint over the pool size: what
        utilization would be WITHOUT sharing. logical - physical is the
        capacity the prefix sharing bought."""
        return (self.allocator.n_logical
                / max(self.allocator.n_pages - 1, 1))


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.float32, sharding=None) -> PagedKVCache:
    """Build the device page pool. `sharding` (a NamedSharding from
    `serve.mesh.kv_pool_sharding`, or None) places the pool across a
    device mesh; the allocator / block tables stay host-side either
    way, so page ids are LOGICAL and mesh-oblivious."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"paged KV cache needs an attention family, got {cfg.family!r}")
    kv_heads, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, n_pages, page_size, kv_heads, hd)
    kv = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if sharding is not None:
        kv = jax.device_put(kv, sharding)
    return PagedKVCache(kv=kv, allocator=PageAllocator(n_pages, page_size))


def pad_to_page(n_tokens: int, page_size: int) -> int:
    """Prompt lengths are bucketed to page multiples so the jitted
    prefill retraces once per bucket, not once per length."""
    return max(page_size, -(-n_tokens // page_size) * page_size)
