"""Tensor-parallel paged serving — `ShardedPagedBackend`.

The multi-device sibling of `PagedKVBackend`, reached only through the
`serve.mesh` seam (`make_backend` routes paged families here when the
engine's `ServeMesh` has more than one shard). Everything host-side is
INHERITED UNCHANGED: the page allocator, block tables, PrefixIndex
admission matching, COW forks, eviction, and the scheduler's
`PagedBudget` all operate on LOGICAL page ids, so prefix sharing and
preemption are mesh-oblivious by construction — a shared logical page
is shared on every shard at once, and `PagedBudget`'s whole-page
charging already prices the mesh-wide allocation (each shard holds the
same logical pages, a head/sequence slice each). What this subclass
changes is exactly one thing: the jitted step factory (`_steps`).

Device layout (pure TP over one mesh axis, `parallel.sharding` rules
with FSDP off):

  parameters   committed via `mesh.param_shardings` in the base
               class's `_place_params` (attention heads / FFN columns
               over "model")
  KV pool      committed via `mesh.kv_pool_sharding`: partitioned on
               the KV-HEAD axis when `n_kv_heads % n_shards == 0`,
               replicated otherwise
  page tables  host-side numpy, never sharded

With the pool head-partitioned, the unmodified paged forward is
already tensor-parallel: jit sees committed operands plus pinned
`out_shardings` and GSPMD partitions the attention einsums along the
head axis — no custom collectives. When KV heads do NOT divide the TP
degree (small models, wide meshes), the pool stays replicated and the
step builders swap the paged forward's `attn_core` seam for the
ARTEMIS token dataflow expressed over the mesh: decode merges
per-shard partial attention with `parallel.split_kv_attention`'s
psum/pmax LSE reduction, and prefill chunks ring the gathered KV view
with `parallel.ring_attention` (paper Fig 5(b), banks -> devices).

Exactness: both cores compute the same masked softmax-attention as the
default `_attn_core` up to float reassociation; the conformance suite
(tests/test_serve_backend.py) pins a sharded drain token-identical to
the single-device `PagedKVBackend` reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map

from repro.core.policy import ArithmeticPolicy
from repro.models.config import ModelConfig
from repro.parallel.ring_attention import ring_attention
from repro.parallel.split_kv import split_kv_attention
from repro.serve.backend import EngineConfig, PagedKVBackend
from repro.serve.mesh import (ServeMesh, kv_pool_sharding,
                              make_serve_mesh, replicated,
                              replicated_spec, seq_sharded_spec)
from repro.serve.obs import ShardStepEvent, Tracer
from repro.serve.paged_model import (
    make_paged_chunked_prefill,
    make_paged_decode,
)
from repro.serve.request import Request

__all__ = ["ShardedPagedBackend"]


def _dataflow_attn_core(smesh: ServeMesh):
    """An `attn_core` for `paged_model`'s pluggable seam that runs the
    token dataflow over the serve mesh. Used when the KV pool is
    replicated (KV heads don't divide the TP degree): parallelism
    comes from sharding the SEQUENCE axis of the gathered KV view.

    The gathered view's kv position IS its slot index t (page j of a
    block table covers positions [j*page, (j+1)*page)), and every
    valid query position >= its own written slots, so the plain causal
    mask q_pos >= kv_pos reproduces the default core's `t <=
    positions` masking — trash-page and padding slots all sit at
    t > position for every valid query.
    """
    mesh, ax = smesh.handle, smesh.axis
    # placement vocabulary comes from the mesh seam, not ad-hoc specs
    # (shard-spec-discipline): rep = replicated, seq = the gathered
    # view's sequence axis over the TP axis
    rep = replicated_spec(smesh)
    seq = seq_sharded_spec(smesh)

    def core(qg, kall, vall, positions, cfg: ModelConfig, policy):
        b, s, kvh, g, hd = qg.shape
        h = kvh * g
        smax = kall.shape[1]
        # merged head index = kv*g + j, so q head i reads kv head i//g
        # — the same grouping _repeat_kv applies to K/V
        q = qg.reshape(b, s, h, hd)
        kv_pos = jnp.broadcast_to(
            jnp.arange(smax, dtype=jnp.int32)[None], (b, smax))
        if s > 1:
            # prefill chunk: queries sequence-sharded, each device's KV
            # slice travels the ring past every query shard (its
            # positions ride along, so masking is exact on every hop)
            def ring(qc, kc, vc, qp, kp):
                return ring_attention(qc, kc, vc, axis_name=ax,
                                      causal=True, q_positions=qp,
                                      kv_positions=kp)
            ctx = shard_map(
                ring, mesh=mesh,
                in_specs=(seq, seq, seq, seq, seq),
                out_specs=seq)(q, kall, vall, positions, kv_pos)
        else:
            # decode: one query per lane, replicated; each shard scores
            # its KV slice and one pmax + two psums merge the LSE stats
            def split(qc, kc, vc, qp, kp):
                return split_kv_attention(qc, kc, vc, axis_name=ax,
                                          q_positions=qp,
                                          kv_positions_local=kp)
            ctx = shard_map(
                split, mesh=mesh,
                in_specs=(rep, seq, seq, rep, seq),
                out_specs=rep)(q, kall, vall, positions, kv_pos)
        return ctx.reshape(b, s, kvh, g, hd)

    return core


@functools.lru_cache(maxsize=None)
def _sharded_paged_steps(cfg: ModelConfig, policy: ArithmeticPolicy,
                         smesh: ServeMesh, chunk: int, smax: int):
    """Jitted mesh-sharded paged steps, cached per
    (cfg, policy, mesh, geometry) — same share-the-compile rationale
    as `backend._paged_steps`. Output shardings are pinned (logits
    replicated, KV pool per `paged_pool_spec`) so donation reuses the
    committed pool buffers; inputs inherit placement from the
    committed params/pool and the host-side batch arrays."""
    n = smesh.n_shards
    heads_tp = cfg.n_kv_heads % n == 0
    core = None
    if (not heads_tp and not cfg.attn_window
            and smax % n == 0 and chunk % n == 0):
        core = _dataflow_attn_core(smesh)
    repl = replicated(smesh)
    kv_ns = kv_pool_sharding(smesh, cfg)
    kv_sh = {"k": kv_ns, "v": kv_ns}
    prefill = jax.jit(
        make_paged_chunked_prefill(cfg, policy, attn_core=core),
        donate_argnums=(2,), out_shardings=(repl, kv_sh))
    decode = jax.jit(
        make_paged_decode(cfg, policy, attn_core=core),
        donate_argnums=(2,), out_shardings=(repl, kv_sh))
    return prefill, decode


class ShardedPagedBackend(PagedKVBackend):
    """Tensor-parallel paged KV backend (see module docstring).

    Inherits the whole `SequenceBackend` protocol implementation from
    `PagedKVBackend` — admission, sharing, COW, funding, release, and
    invariants are logical-page operations that never see the mesh.
    Overrides: `_steps` (mesh-sharded jitted forwards) and the two
    execution entry points, which additionally account per-shard work
    (`backend/shard_*` registry counters + one `ShardStepEvent` per
    shard per forward for the Chrome trace's shard tracks)."""

    families = ("dense", "moe")

    def __init__(self, cfg: ModelConfig, ecfg: EngineConfig,
                 policy: ArithmeticPolicy, params, obs: Tracer, clock,
                 mesh: ServeMesh | None = None):
        mesh = mesh if mesh is not None \
            else make_serve_mesh(ecfg.mesh_shards)
        if mesh.is_single:
            raise ValueError(
                "ShardedPagedBackend needs a multi-shard ServeMesh; "
                "single-device serving uses PagedKVBackend "
                "(mesh_shards=1)")
        if ecfg.attn_impl != "gather":
            raise ValueError(
                f"attn_impl={ecfg.attn_impl!r} has no multi-device "
                f"path (the fused paged kernel is single-device; the "
                f"mesh cores own the sharded gather view) — set "
                f"attn_impl='gather' or mesh_shards=1")
        super().__init__(cfg, ecfg, policy, params, obs, clock,
                         mesh=mesh)
        reg = obs.registry
        reg.set_gauge("backend/shard_count", mesh.n_shards)
        reg.set_gauge(
            "backend/shard_kv_heads",
            cfg.n_kv_heads // mesh.n_shards
            if cfg.n_kv_heads % mesh.n_shards == 0 else cfg.n_kv_heads)

    def _steps(self, policy: ArithmeticPolicy):
        smax = self.ecfg.max_pages_per_seq * self.ecfg.page_size
        return _sharded_paged_steps(self.cfg, policy, self.mesh,
                                    self.ecfg.prefill_chunk, smax)

    # -- execution (adds per-shard accounting) ------------------------------

    def prefill_step(self, chunks: list[tuple[Request, int]]):
        logits = super().prefill_step(chunks)
        self._note_shard_step("prefill", sum(n for _, n in chunks))
        return logits

    def decode_step(self, reqs: list[Request]):
        logits = super().decode_step(reqs)
        self._note_shard_step("decode", len(reqs))
        return logits

    def _note_shard_step(self, phase: str, n_tokens: int) -> None:
        reg = self._obs.registry
        reg.inc("backend/shard_steps")
        reg.inc("backend/shard_tokens", n_tokens)
        now = self._now()
        for shard in range(self.mesh.n_shards):
            self._obs.emit(ShardStepEvent(
                ts=now, shard=shard, n_shards=self.mesh.n_shards,
                phase=phase, n_tokens=n_tokens))

    def snapshot_metrics(self) -> dict:
        m = super().snapshot_metrics()
        reg = self._obs.registry
        m["n_shards"] = self.mesh.n_shards
        m["shard_steps"] = int(reg.count("backend/shard_steps"))
        return m
