"""Synthetic traffic for the serving engine.

Poisson arrivals (exponential inter-arrival gaps) with configurable
prompt/generation length distributions — the many-concurrent-requests
regime the ROADMAP north-star targets, in deterministic, seedable form
so scheduler tests can replay the exact same trace.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    arrival_rate: float = 50.0       # requests / virtual second
    prompt_len_min: int = 4
    prompt_len_max: int = 48
    gen_len_min: int = 4
    gen_len_max: int = 24
    vocab_size: int = 256
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class TraceItem:
    arrival_time: float
    prompt: np.ndarray               # (S,) i32
    max_new_tokens: int


def synth_trace(tc: TrafficConfig) -> list[TraceItem]:
    """Deterministic Poisson trace; sorted by arrival time."""
    rng = np.random.default_rng(tc.seed)
    gaps = rng.exponential(1.0 / max(tc.arrival_rate, 1e-9),
                           size=tc.n_requests)
    arrivals = np.cumsum(gaps)
    items = []
    for i in range(tc.n_requests):
        plen = int(rng.integers(tc.prompt_len_min, tc.prompt_len_max + 1))
        glen = int(rng.integers(tc.gen_len_min, tc.gen_len_max + 1))
        # token ids start at 2 (0/1 conventionally pad/bos in the repo's
        # synthetic batches — see launch/serve.py)
        prompt = rng.integers(2, tc.vocab_size, size=plen).astype(np.int32)
        items.append(TraceItem(float(arrivals[i]), prompt, glen))
    return items
