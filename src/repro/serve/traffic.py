"""Synthetic traffic for the serving engine.

Poisson arrivals (exponential inter-arrival gaps) with configurable
prompt/generation length distributions — the many-concurrent-requests
regime the ROADMAP north-star targets, in deterministic, seedable form
so scheduler tests can replay the exact same trace.

Two prompt modes:

  independent (n_prefix_groups == 0) — every prompt fully random.
  shared-prefix (n_prefix_groups > 0) — `n_prefix_groups` random
      prefixes of `prefix_len` tokens are drawn once; each request
      picks a group and appends a per-request random suffix of
      [prompt_len_min, prompt_len_max] tokens. This is the few-shot /
      system-prompt traffic shape that prefix sharing in the paged KV
      cache multiplies capacity on.

Orthogonally, SAMPLED-DECODE traffic (sampled_fraction > 0): each
request is independently marked sampled with that probability and
carries `SamplingParams(temperature, top_k, top_p)` plus a
per-request RNG seed drawn from the trace rng (or the fixed
`sample_seed` when >= 0) — the mixed greedy/sampled composition real
serving sees. With sampled_fraction == 0 the trace stream is
byte-identical to the pre-sampling generator, so every greedy
token-identity suite replays unchanged.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.serve.request import SamplingParams


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    n_requests: int = 16
    arrival_rate: float = 50.0       # requests / virtual second
    prompt_len_min: int = 4          # suffix bounds in shared-prefix mode
    prompt_len_max: int = 48
    gen_len_min: int = 4
    gen_len_max: int = 24
    vocab_size: int = 256
    seed: int = 0
    n_prefix_groups: int = 0         # 0 = independent prompts
    prefix_len: int = 0              # tokens shared within a group
    sampled_fraction: float = 0.0    # P(request decodes sampled)
    temperature: float = 0.8         # SamplingParams for sampled reqs
    top_k: int = 0
    top_p: float = 1.0
    sample_seed: int = -1            # -1 = per-request seed from the
    #                                  trace rng; >= 0 = every sampled
    #                                  request uses exactly this seed

    def __post_init__(self):
        # mirror EngineConfig: bad bounds used to fail deep inside
        # np.random with confusing errors
        if self.n_requests < 1:
            raise ValueError(
                f"n_requests must be >= 1, got {self.n_requests}")
        if not self.arrival_rate > 0:
            raise ValueError(
                f"arrival_rate must be > 0, got {self.arrival_rate}")
        if self.prompt_len_min < 1:
            raise ValueError(
                f"prompt_len_min must be >= 1, got {self.prompt_len_min}")
        if self.prompt_len_min > self.prompt_len_max:
            raise ValueError(
                f"prompt_len_min {self.prompt_len_min} > prompt_len_max "
                f"{self.prompt_len_max}")
        if self.gen_len_min < 1:
            raise ValueError(
                f"gen_len_min must be >= 1, got {self.gen_len_min}")
        if self.gen_len_min > self.gen_len_max:
            raise ValueError(
                f"gen_len_min {self.gen_len_min} > gen_len_max "
                f"{self.gen_len_max}")
        if self.vocab_size < 3:
            raise ValueError(
                f"vocab_size must be >= 3 (ids start at 2), got "
                f"{self.vocab_size}")
        if self.n_prefix_groups < 0:
            raise ValueError(
                f"n_prefix_groups must be >= 0, got "
                f"{self.n_prefix_groups}")
        if self.n_prefix_groups > 0 and self.prefix_len < 1:
            raise ValueError(
                f"prefix_len must be >= 1 when n_prefix_groups > 0, "
                f"got {self.prefix_len}")
        if self.n_prefix_groups == 0 and self.prefix_len != 0:
            raise ValueError(
                f"prefix_len {self.prefix_len} needs n_prefix_groups > 0")
        if not 0.0 <= self.sampled_fraction <= 1.0:
            raise ValueError(
                f"sampled_fraction must be in [0, 1], got "
                f"{self.sampled_fraction}")
        if self.sampled_fraction > 0:
            if self.temperature <= 0:
                raise ValueError(
                    f"sampled traffic needs temperature > 0, got "
                    f"{self.temperature}")
            # surface bad top_k/top_p/sample_seed at config time, not
            # per-item deep inside synth_trace
            SamplingParams(temperature=self.temperature,
                           top_k=self.top_k, top_p=self.top_p,
                           seed=max(self.sample_seed, 0))


@dataclasses.dataclass(frozen=True)
class TraceItem:
    arrival_time: float
    prompt: np.ndarray               # (S,) i32
    max_new_tokens: int
    prefix_group: int = -1           # -1 = independent prompt
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)


def trace_stats(items: list[TraceItem]) -> dict:
    """Summary statistics of a trace — the workload-shape metadata the
    launch CLI stamps into exported Chrome traces so a serve_trace.json
    is self-describing."""
    if not items:
        return {"n_requests": 0}
    return {
        "n_requests": len(items),
        "total_prompt_tokens": int(sum(len(it.prompt) for it in items)),
        "total_max_new_tokens": int(sum(it.max_new_tokens
                                        for it in items)),
        "n_sampled_requests": int(sum(1 for it in items
                                      if not it.sampling.greedy)),
        "first_arrival_s": float(min(it.arrival_time for it in items)),
        "last_arrival_s": float(max(it.arrival_time for it in items)),
    }


def synth_trace(tc: TrafficConfig) -> list[TraceItem]:
    """Deterministic Poisson trace; sorted by arrival time."""
    rng = np.random.default_rng(tc.seed)
    gaps = rng.exponential(1.0 / tc.arrival_rate, size=tc.n_requests)
    arrivals = np.cumsum(gaps)
    # token ids start at 2 (0/1 conventionally pad/bos in the repo's
    # synthetic batches — see launch/serve.py)
    prefixes = [
        rng.integers(2, tc.vocab_size, size=tc.prefix_len).astype(np.int32)
        for _ in range(tc.n_prefix_groups)]
    items = []
    for i in range(tc.n_requests):
        plen = int(rng.integers(tc.prompt_len_min, tc.prompt_len_max + 1))
        glen = int(rng.integers(tc.gen_len_min, tc.gen_len_max + 1))
        suffix = rng.integers(2, tc.vocab_size, size=plen).astype(np.int32)
        group = -1
        if tc.n_prefix_groups:
            group = int(rng.integers(0, tc.n_prefix_groups))
            prompt = np.concatenate([prefixes[group], suffix])
        else:
            prompt = suffix
        # sampled_fraction == 0 draws nothing, keeping the pre-sampling
        # trace stream byte-identical for the greedy suites; above 0
        # the draws are unconditional so neither the sampled coin nor
        # a fixed sample_seed shifts the stream for later requests —
        # the SAME prompts/lengths are emitted either way
        sampling = SamplingParams()
        if tc.sampled_fraction > 0:
            sampled = rng.random() < tc.sampled_fraction
            seed = int(rng.integers(0, 2 ** 31))
            if tc.sample_seed >= 0:
                seed = tc.sample_seed
            if sampled:
                sampling = SamplingParams(
                    temperature=tc.temperature, top_k=tc.top_k,
                    top_p=tc.top_p, seed=seed)
        items.append(TraceItem(float(arrivals[i]), prompt, glen, group,
                               sampling))
    return items
