"""repro.serve — continuous-batching serving engine.

A layer between the kernels and the launch CLI: request lifecycle
(`request`), block-based paged KV cache with refcounted copy-on-write
prefix sharing (`paged_cache`), jit-stable chunked+batched prefill and
decode forwards (`paged_model`), ARTEMIS-cost-aware mixed-step
scheduling (`scheduler` + `cost`, priced by `repro.hwsim` over the
composed token count), synthetic Poisson traffic with a shared-prefix
mode (`traffic`), and the engine driver (`engine`).

Entry point: `python -m repro.launch.serve --mode engine`.
"""
from repro.serve.cost import ArtemisCostModel
from repro.serve.engine import EngineConfig, ServeEngine, percentile
from repro.serve.paged_cache import (
    PageAllocator,
    PagedKVCache,
    PrefixIndex,
    cow_copy_page,
    init_paged_cache,
    pad_to_page,
)
from repro.serve.paged_model import (
    make_paged_chunked_prefill,
    make_paged_decode,
    make_paged_prefill,
)
from repro.serve.request import Request, RequestState
from repro.serve.scheduler import Action, Scheduler, SchedulerConfig
from repro.serve.traffic import TraceItem, TrafficConfig, synth_trace

__all__ = [
    "ArtemisCostModel", "EngineConfig", "ServeEngine", "percentile",
    "PageAllocator", "PagedKVCache", "PrefixIndex", "cow_copy_page",
    "init_paged_cache", "pad_to_page",
    "make_paged_chunked_prefill", "make_paged_decode", "make_paged_prefill",
    "Request", "RequestState",
    "Action", "Scheduler", "SchedulerConfig",
    "TraceItem", "TrafficConfig", "synth_trace",
]
