"""repro.serve — continuous-batching serving engine.

A layer between the kernels and the launch CLI: request lifecycle
(`request`), the backend-agnostic sequence-memory API (`backend`:
`SequenceBackend`, implemented by the paged-KV backend for attention
families and the state-slot backend for recurrent families),
jit-stable forwards per memory model (`paged_model` / `state_model`),
the paged-cache primitives (`paged_cache`: refcounting allocator,
prefix index, copy-on-write), ARTEMIS-cost-aware mixed-step scheduling
(`scheduler` + `cost`, priced by `repro.hwsim` over the composed token
count), per-request stochastic sampling with batch-invariant RNG lanes
(`sampler`: temperature / top-k / top-p at one compiled
`(max_batch, vocab)` shape), synthetic Poisson traffic with
shared-prefix and mixed greedy/sampled modes (`traffic`), the
observability layer (`obs`: typed lifecycle events, metrics registry
with exact-percentile streaming histograms, per-request energy
attribution, span assembly, Chrome trace export over the virtual
clock), the device-mesh seam with its tensor-parallel paged backend
(`mesh` / `sharded_backend`: single-device default is a strict no-op,
`mesh_shards > 1` serves attention families tensor-parallel), and the
engine driver (`engine`).

Entry point: `python -m repro.launch.serve --mode engine` (any family).
"""
from repro.serve.backend import (
    AdmitPlan,
    BudgetProbe,
    EngineConfig,
    PagedBudget,
    PagedKVBackend,
    SequenceBackend,
    SlotBudget,
    StateSlotBackend,
    make_backend,
)
from repro.serve.cost import ArtemisCostModel
from repro.serve.engine import ServeEngine, percentile
from repro.serve.mesh import ServeMesh, make_serve_mesh
from repro.serve.obs import (
    Event,
    Histogram,
    MetricsRegistry,
    PhaseAttribution,
    RequestTrace,
    Tracer,
    assemble_spans,
    dumps_chrome_trace,
    export_chrome_trace,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.serve.paged_cache import (
    PageAllocator,
    PagedKVCache,
    PrefixIndex,
    cow_copy_page,
    init_paged_cache,
    pad_to_page,
)
from repro.serve.paged_model import (
    make_paged_chunked_prefill,
    make_paged_decode,
    make_paged_prefill,
)
from repro.serve.request import Request, RequestState, SamplingParams
from repro.serve.sampler import lane_key, sample_tokens
from repro.serve.sharded_backend import ShardedPagedBackend
from repro.serve.scheduler import Action, Scheduler, SchedulerConfig
from repro.serve.state_model import (
    init_slot_pool,
    make_slot_decode,
    make_slot_prefill_chunk,
)
from repro.serve.traffic import TraceItem, TrafficConfig, synth_trace

__all__ = [
    "AdmitPlan", "BudgetProbe", "EngineConfig", "PagedBudget",
    "PagedKVBackend", "SequenceBackend", "SlotBudget", "StateSlotBackend",
    "make_backend",
    "ArtemisCostModel", "ServeEngine", "percentile",
    "ServeMesh", "make_serve_mesh", "ShardedPagedBackend",
    "Event", "Histogram", "MetricsRegistry", "PhaseAttribution",
    "RequestTrace", "Tracer", "assemble_spans", "dumps_chrome_trace",
    "export_chrome_trace", "to_chrome_trace", "validate_chrome_trace",
    "PageAllocator", "PagedKVCache", "PrefixIndex", "cow_copy_page",
    "init_paged_cache", "pad_to_page",
    "make_paged_chunked_prefill", "make_paged_decode", "make_paged_prefill",
    "Request", "RequestState", "SamplingParams",
    "lane_key", "sample_tokens",
    "Action", "Scheduler", "SchedulerConfig",
    "init_slot_pool", "make_slot_decode", "make_slot_prefill_chunk",
    "TraceItem", "TrafficConfig", "synth_trace",
]
