"""ARTEMIS-cost-aware batch pricing — the hwsim bridge.

The scheduler doesn't invent its own latency heuristics: it prices each
candidate batch with the SAME simulator the paper's evaluation uses
(`hwsim.simulate_model` under the token_PP dataflow, i.e. the ARTEMIS
scheme of Fig 8). Token-based sharding spreads the in-flight tokens
over all banks, so batches with more concurrent tokens amortize the
ring K/V broadcast better — which is exactly the signal continuous
batching needs: a full decode lane-set prices cheaper per token than a
lone straggler, and a prefill's big token count competes on equal
footing.

The hook is pluggable: anything with `price(n_tokens) -> ns` (and
`energy(n_tokens) -> pJ` for tiebreaks) works; `None` disables
cost-aware ordering (pure FCFS).
"""
from __future__ import annotations

import collections
import dataclasses

from repro.hwsim import DataflowConfig, DramGeometry, simulate_model
from repro.hwsim.workloads import Workload
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArtemisCostModel:
    """Prices a candidate batch of `n_tokens` concurrent tokens through
    one full model pass on the ARTEMIS hardware model.

    Mesh-aware: with `n_shards > 1` (the engine's tensor-parallel serve
    mesh) each shard simulates only ITS slice of the model — heads and
    FFN width divided when divisible, parameters always — plus a priced
    all-reduce term for the two per-layer activation reductions TP
    inserts (attention output + FFN output), costed through the same
    `hwsim` link model the dataflow simulator uses. `n_shards == 1`
    contributes exactly 0.0 extra, so single-device pricing is
    bit-identical to the pre-mesh cost model."""
    cfg: ModelConfig
    scheme: str = "token_PP"
    n_shards: int = 1
    # bounded LRU memo over n_tokens (excluded from eq/hash; dies with
    # the instance): chunk sizes and decode batch widths repeat
    # constantly during a drain, but an adversarial token-count stream
    # must not grow the map without bound
    memo_size: int = 128
    _memo: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict, repr=False,
        compare=False)

    def __post_init__(self):
        if self.memo_size < 1:
            raise ValueError(
                f"memo_size must be >= 1, got {self.memo_size}")
        if self.n_shards < 1:
            raise ValueError(
                f"n_shards must be >= 1, got {self.n_shards}")

    def _workload(self, n_tokens: int) -> Workload:
        """One SHARD's slice of the model pass (the whole model at
        n_shards == 1): TP splits heads and FFN columns when they
        divide, and always holds 1/n of the parameters."""
        cfg, n = self.cfg, self.n_shards
        d_ff = cfg.d_ff
        if cfg.family == "moe" and cfg.d_ff_expert:
            # active FFN width per token (routed experts + shared)
            d_ff = cfg.d_ff_expert * (max(cfg.top_k, 1)
                                      + cfg.n_shared_experts)
        n_heads = cfg.n_heads // n if cfg.n_heads % n == 0 else cfg.n_heads
        if d_ff % n == 0:
            d_ff //= n
        return Workload(
            name=f"serve-{cfg.name}", params=float(cfg.param_count()) / n,
            n_layers=cfg.n_layers, n_tokens=int(n_tokens),
            n_heads=n_heads, d_model=cfg.d_model, d_ff=max(d_ff, 1))

    def _tp_collective(self, n_tokens: int) -> tuple[float, float]:
        """(latency_ns, energy_pj) of the TP all-reduces one model pass
        inserts: 2 per layer (attention output + FFN output), each over
        the (n_tokens, d_model) fp32 activation, ring-reduced so every
        shard moves 2*(n-1)/n of the tensor's bits over the inter-bank
        link. Exactly (0.0, 0.0) at n_shards == 1."""
        n = self.n_shards
        if n == 1:
            return (0.0, 0.0)
        geom = DramGeometry(DataflowConfig(scheme=self.scheme).hw)
        bits = int(n_tokens) * self.cfg.d_model * 32
        ring_bits = 2.0 * (n - 1) / n * bits
        lat = 2 * self.cfg.n_layers * geom.transfer_latency_ns(ring_bits)
        # every shard moves its ring share concurrently: latency is one
        # shard's serialization, energy is all n shards' traffic
        energy = 2 * self.cfg.n_layers \
            * geom.transfer_energy_pj(ring_bits) * n
        return (lat, energy)

    def _simulate(self, n_tokens: int):
        n = int(n_tokens)
        if n < 1:
            # an empty composition has no price; silently clamping to a
            # 1-token pass used to mask scheduler bugs that priced
            # nothing-to-run candidates
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        if n in self._memo:
            self._memo.move_to_end(n)
            return self._memo[n]
        res = simulate_model(
            self._workload(n), DataflowConfig(scheme=self.scheme))
        self._memo[n] = res
        while len(self._memo) > self.memo_size:
            self._memo.popitem(last=False)
        return res

    def price(self, n_tokens: int) -> float:
        """Latency (ns) of one model pass over n_tokens concurrent
        tokens under the configured dataflow scheme: one shard's slice
        plus the TP all-reduce term (both 0-extra at n_shards == 1)."""
        return (self._simulate(n_tokens).latency_ns
                + self._tp_collective(n_tokens)[0])

    def energy(self, n_tokens: int) -> float:
        """Energy (pJ) of the same pass — the scheduler's tiebreak when
        two candidate compositions price identically (the simulator's
        round-based latency plateaus make exact ties real). Mesh-aware:
        all n shards' compute plus the collective traffic."""
        return (self._simulate(n_tokens).energy_pj * self.n_shards
                + self._tp_collective(n_tokens)[1])

    def price_per_token(self, n_tokens: int) -> float:
        return self.price(n_tokens) / int(n_tokens)

    def energy_per_token(self, n_tokens: int) -> float:
        return self.energy(n_tokens) / int(n_tokens)
