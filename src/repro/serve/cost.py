"""ARTEMIS-cost-aware batch pricing — the hwsim bridge.

The scheduler doesn't invent its own latency heuristics: it prices each
candidate batch with the SAME simulator the paper's evaluation uses
(`hwsim.simulate_model` under the token_PP dataflow, i.e. the ARTEMIS
scheme of Fig 8). Token-based sharding spreads the in-flight tokens
over all banks, so batches with more concurrent tokens amortize the
ring K/V broadcast better — which is exactly the signal continuous
batching needs: a full decode lane-set prices cheaper per token than a
lone straggler, and a prefill's big token count competes on equal
footing.

The hook is pluggable: anything with `price(n_tokens) -> ns` (and
`energy(n_tokens) -> pJ` for tiebreaks) works; `None` disables
cost-aware ordering (pure FCFS).
"""
from __future__ import annotations

import collections
import dataclasses

from repro.hwsim import DataflowConfig, simulate_model
from repro.hwsim.workloads import Workload
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ArtemisCostModel:
    """Prices a candidate batch of `n_tokens` concurrent tokens through
    one full model pass on the ARTEMIS hardware model."""
    cfg: ModelConfig
    scheme: str = "token_PP"
    # bounded LRU memo over n_tokens (excluded from eq/hash; dies with
    # the instance): chunk sizes and decode batch widths repeat
    # constantly during a drain, but an adversarial token-count stream
    # must not grow the map without bound
    memo_size: int = 128
    _memo: collections.OrderedDict = dataclasses.field(
        default_factory=collections.OrderedDict, repr=False,
        compare=False)

    def __post_init__(self):
        if self.memo_size < 1:
            raise ValueError(
                f"memo_size must be >= 1, got {self.memo_size}")

    def _workload(self, n_tokens: int) -> Workload:
        cfg = self.cfg
        d_ff = cfg.d_ff
        if cfg.family == "moe" and cfg.d_ff_expert:
            # active FFN width per token (routed experts + shared)
            d_ff = cfg.d_ff_expert * (max(cfg.top_k, 1)
                                      + cfg.n_shared_experts)
        return Workload(
            name=f"serve-{cfg.name}", params=float(cfg.param_count()),
            n_layers=cfg.n_layers, n_tokens=int(n_tokens),
            n_heads=cfg.n_heads, d_model=cfg.d_model, d_ff=max(d_ff, 1))

    def _simulate(self, n_tokens: int):
        n = int(n_tokens)
        if n < 1:
            # an empty composition has no price; silently clamping to a
            # 1-token pass used to mask scheduler bugs that priced
            # nothing-to-run candidates
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        if n in self._memo:
            self._memo.move_to_end(n)
            return self._memo[n]
        res = simulate_model(
            self._workload(n), DataflowConfig(scheme=self.scheme))
        self._memo[n] = res
        while len(self._memo) > self.memo_size:
            self._memo.popitem(last=False)
        return res

    def price(self, n_tokens: int) -> float:
        """Latency (ns) of one model pass over n_tokens concurrent
        tokens under the configured dataflow scheme."""
        return self._simulate(n_tokens).latency_ns

    def energy(self, n_tokens: int) -> float:
        """Energy (pJ) of the same pass — the scheduler's tiebreak when
        two candidate compositions price identically (the simulator's
        round-based latency plateaus make exact ties real)."""
        return self._simulate(n_tokens).energy_pj

    def price_per_token(self, n_tokens: int) -> float:
        return self.price(n_tokens) / int(n_tokens)

    def energy_per_token(self, n_tokens: int) -> float:
        return self.energy(n_tokens) / int(n_tokens)
