"""Paged-attention forward passes for the serving engine.

Three step builders, all jit-stable under continuous batching:

  make_paged_prefill(cfg, policy) ->
      (params, tokens (1, S_pad), kv, page_ids (P_req,)) -> (logits, kv)
    Whole-prompt prefill for ONE request through the standard
    `model.apply` in-sequence attention path, K/V scattered into the
    request's pages afterwards. Kept as the reference path (tests pin
    paged numerics against it); the engine itself uses the chunked
    builder below.

  make_paged_chunked_prefill(cfg, policy) ->
      (params, tokens (B, C), kv, block_tables (B, Pmax),
       start_pos (B,), chunk_lens (B,), active (B,),
       write_from (B,)) -> (logits, kv)
    One fixed-size chunk of C prompt tokens for up to B requests AT
    ONCE. Row b holds chunk_lens[b] valid tokens of request b's
    effective prompt starting at absolute position start_pos[b]; each
    chunk token's K/V is scattered into the row's pages first, then the
    row's block table is gathered back so queries attend to the
    request's whole written prefix (earlier chunks + this one) under a
    causal mask. Shapes are (max_batch, C) constants, so chunked
    prefill compiles exactly once — no per-bucket retraces — and a
    prompt longer than C simply spans multiple engine steps.
    write_from[b] masks the SCATTER (not the queries) for positions
    below it: a prefix-sharing hit already has those positions' K/V
    resident in shared pages, so the chunk recomputes the query (its
    logits are needed to sample when the chunk completes a prompt) but
    must not write into pages other requests reference.

  make_paged_decode(cfg, policy) ->
      (params, tokens (B, 1), kv, block_tables (B, Pmax),
       seq_lens (B,), active (B,)) -> (logits (B, V), kv)
    One token for every lane of a FIXED max-batch — the chunked pass
    with C == 1 query and the position taken from seq_lens.

Inactive rows / padding chunk positions scatter into the reserved
trash page 0 and are excluded from every valid query's mask, so the
compiled steps never see a data-dependent shape.

Only attention families (dense / moe) are supported: paged KV is
meaningless for the recurrent-state families (rwkv6 / zamba2), which
serve through the state-slot pool (`state_model`) — `repro.serve.backend`
routes each family to its backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.kernels.paged_attention import paged_attention
from repro.models import layers as L
from repro.models import moe as M
from repro.models import model, transformer
from repro.models.config import ModelConfig
from repro.serve.paged_cache import TRASH_PAGE


def _check_family(cfg: ModelConfig) -> None:
    if cfg.family not in ("dense", "moe"):
        raise ValueError(
            f"paged serving supports dense/moe families, got {cfg.family!r}")
    if cfg.modality != "text":
        raise ValueError(
            f"paged serving supports text modality, got {cfg.modality!r}")


# ---------------------------------------------------------------------------
# whole-prompt prefill (reference path)
# ---------------------------------------------------------------------------


def make_paged_prefill(cfg: ModelConfig,
                       policy: ArithmeticPolicy = ArithmeticPolicy()):
    """Returns prefill(params, tokens, kv, page_ids) -> (logits, kv).

    tokens: (1, S_pad) i32, S_pad a page multiple; page_ids: (S_pad/page,)
    i32 pages owned by the request, in position order. Returns logits for
    ALL S_pad positions (the caller indexes the true last prompt position
    host-side) and the pool with the request's K/V written.
    """
    _check_family(cfg)

    def prefill(params, tokens, kv, page_ids):
        s_pad = tokens.shape[1]
        page = kv["k"].shape[2]
        dense = transformer.init_cache(cfg, 1, s_pad, kv["k"].dtype)
        logits, _, dense = model.apply(
            params, cfg, {"tokens": tokens}, policy=policy, cache=dense,
            remat=False)
        n_layers, _, _, kvh, hd = dense["k"].shape
        kp = dense["k"].reshape(n_layers, s_pad // page, page, kvh, hd)
        vp = dense["v"].reshape(n_layers, s_pad // page, page, kvh, hd)
        new_kv = {"k": kv["k"].at[:, page_ids].set(kp),
                  "v": kv["v"].at[:, page_ids].set(vp)}
        return logits[0], new_kv

    return prefill


# ---------------------------------------------------------------------------
# shared paged-attention step body (chunked prefill and decode)
# ---------------------------------------------------------------------------


def _attn_core(qg, kall, vall, positions, cfg: ModelConfig, policy):
    """Default (single-device) grouped-query attention over the
    gathered KV view. qg: (B, S, KV, G, Dh) grouped queries; kall/vall:
    (B, Smax, KV, Dh); positions: (B, S) absolute query positions.
    Returns the context tensor (B, S, KV, G, Dh).

    Pluggable seam: `ShardedPagedBackend` swaps in a mesh-sharded core
    (split-KV / ring attention over the same view) via the step
    builders' `attn_core` argument — the rest of the paged forward is
    layout-oblivious.
    """
    hd = qg.shape[-1]
    smax = kall.shape[1]
    scores = L.qeinsum("bskgd,btkd->bkgst", qg, kall, policy)
    scores = scores.astype(jnp.float32) * (hd ** -0.5)
    # page j of a block table holds positions [j*page, (j+1)*page), so
    # the gathered view's kv position IS its index t; causal within the
    # chunk because each query's own position bounds the mask
    t = jnp.arange(smax, dtype=jnp.int32)[None, None, :]  # (1, 1, Smax)
    keep = t <= positions[:, :, None]                     # (B, S, Smax)
    if cfg.attn_window:
        keep = keep & (t > positions[:, :, None] - cfg.attn_window)
    scores = jnp.where(keep[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    return L.qeinsum("bkgst,btkd->bskgd", probs, vall, policy)


def make_fused_paged_core(cfg: ModelConfig, policy: ArithmeticPolicy):
    """Build the fused-kernel occupant of the `paged_core` seam: a
    core(qg, ckl, cvl, block_tables, positions) -> (B, S, KV, G, Dh)
    that hands the RAW page pool to the Pallas paged-attention kernel
    (`repro.kernels.paged_attention`), which walks the block table
    in-kernel — no gathered (B, Smax, KV, Dh) view is ever built.

    The kernel computes exact fp32 masked softmax-attention, so it can
    only stand in for the default core under an exact arithmetic
    policy; quantized score/context einsums must keep the gather path.
    Interpret-mode resolution (compiled on TPU, interpreted on CPU)
    happens inside the kernel wrapper via the shared platform probe.
    """
    if policy.is_quantized():
        raise ValueError(
            f"attn_impl='fused' computes exact fp32 attention and "
            f"cannot reproduce quantized policy mode "
            f"{policy.mode!r}; use attn_impl='gather'")
    window = cfg.attn_window or None

    def core(qg, ckl, cvl, block_tables, positions):
        b, s, kvh, g, hd = qg.shape
        o = paged_attention(
            qg.reshape(b, s, kvh * g, hd), ckl, cvl, block_tables,
            positions, window=window, scale=hd ** -0.5)
        return o.astype(qg.dtype).reshape(b, s, kvh, g, hd)

    return core


def _paged_attn_block(lp, x, cfg: ModelConfig, policy, positions,
                      ckl, cvl, block_tables, page_idx, offset,
                      attn_core=None, paged_core=None):
    """One layer's attention with paged K/V. x: (B, S, d).

    ckl/cvl: this layer's page pool (P, page, KV, Dh); positions,
    page_idx, offset: (B, S) — the absolute position of every query
    token and its scatter coordinates in the pool (trash page for
    inactive / padding tokens). Returns (attn_out, new ckl, new cvl).

    Two occupants share the attention seam at this call site:
    `attn_core` consumes the GATHERED (B, Smax, KV, Dh) view (default
    `_attn_core`; the sharded backend's mesh cores), while
    `paged_core(qg, ckl, cvl, block_tables, positions)` consumes the
    raw pool + block tables so the fused kernel can walk pages
    in-kernel — when it is set, the gather below never happens.
    """
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = lp["attn"]
    qh = L.mm(x, p["wq"], policy).reshape(b, s, h, hd)
    kh = L.mm(x, p["wk"], policy).reshape(b, s, kvh, hd)
    vh = L.mm(x, p["wv"], policy).reshape(b, s, kvh, hd)
    if cfg.qk_norm:
        qh = L.headwise_rmsnorm(p["q_norm"], qh, cfg.norm_eps)
        kh = L.headwise_rmsnorm(p["k_norm"], kh, cfg.norm_eps)
    qh = L.apply_rope(qh, positions, cfg.rope_theta)
    kh = L.apply_rope(kh, positions, cfg.rope_theta)

    # scatter the new tokens' K/V into their (page, slot) coordinates
    ckl = ckl.at[page_idx, offset].set(kh.astype(ckl.dtype))
    cvl = cvl.at[page_idx, offset].set(vh.astype(cvl.dtype))

    g = h // kvh
    qg = qh.reshape(b, s, kvh, g, hd)
    if paged_core is not None:
        # fused path: the kernel reads the pool just written above, so
        # chunk tokens still attend to earlier tokens of the same chunk
        ctx = paged_core(qg, ckl, cvl, block_tables, positions)
    else:
        # gather each row's block table back to a contiguous KV view:
        # (B, Pmax, page, KV, Dh) -> (B, Smax, KV, Dh), position order —
        # this view already contains the K/V scattered just above, so
        # chunk tokens attend to earlier tokens of the same chunk
        pmax, page = block_tables.shape[1], ckl.shape[1]
        smax = pmax * page
        kall = ckl[block_tables].reshape(b, smax, kvh, hd).astype(x.dtype)
        vall = cvl[block_tables].reshape(b, smax, kvh, hd).astype(x.dtype)
        core = attn_core if attn_core is not None else _attn_core
        ctx = core(qg, kall, vall, positions, cfg, policy)
    ctx = ctx.reshape(b, s, h * hd)
    return L.mm(ctx, p["wo"], policy), ckl, cvl


def _paged_forward(params, cfg: ModelConfig, policy, tokens, kv,
                   block_tables, positions, page_idx, offset,
                   attn_core=None, paged_core=None):
    """Full-model paged step: embed -> layers -> logits (B, S, V)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = transformer._embed_tokens(params, cfg, tokens, dtype)   # (B, S, d)

    def ln(lnp, y):
        return L.rmsnorm(lnp, y, cfg.norm_eps)

    def body(carry, lp):
        x, ck, cv, li = carry
        ckl = jax.lax.dynamic_index_in_dim(ck, li, 0, False)
        cvl = jax.lax.dynamic_index_in_dim(cv, li, 0, False)
        h, ckl, cvl = _paged_attn_block(
            lp, ln(lp["ln1"], x), cfg, policy, positions,
            ckl, cvl, block_tables, page_idx, offset,
            attn_core=attn_core, paged_core=paged_core)
        x = x + h
        if cfg.family == "moe":
            f, _ = M.moe_ffn(lp["moe"], ln(lp["ln2"], x), cfg, policy)
        else:
            f = L.ffn(lp["ffn"], ln(lp["ln2"], x),
                      cfg.act, cfg.glu, policy)
        x = x + f
        ck = jax.lax.dynamic_update_index_in_dim(ck, ckl, li, 0)
        cv = jax.lax.dynamic_update_index_in_dim(cv, cvl, li, 0)
        return (x, ck, cv, li + 1), None

    (x, ck, cv, _), _ = jax.lax.scan(
        body, (x, kv["k"], kv["v"], jnp.zeros((), jnp.int32)),
        params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = transformer._logits(params, cfg, x)                # (B, S, V)
    return logits, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# chunked + batched prefill
# ---------------------------------------------------------------------------


def make_paged_chunked_prefill(cfg: ModelConfig,
                               policy: ArithmeticPolicy = ArithmeticPolicy(),
                               attn_core=None, paged_core=None):
    """Returns chunked_prefill(params, tokens, kv, block_tables,
    start_pos, chunk_lens, active, write_from) -> (logits (B, C, V), kv).

    Row b carries chunk_lens[b] valid prompt tokens of one request,
    starting at absolute position start_pos[b]; block_tables[b] must
    already contain the pages covering [0, start_pos[b] + chunk_lens[b])
    (unused slots: trash page). Logits are returned for every chunk
    position; the engine indexes the last VALID position host-side when
    a chunk completes its prompt. Padding positions, inactive rows, and
    positions below write_from[b] (already resident via prefix sharing)
    scatter to the trash page and never enter a valid query's mask —
    rerun positions still attend to their OWN K/V through the resident
    shared pages, which hold identical values by construction.
    """
    _check_family(cfg)

    def chunked_prefill(params, tokens, kv, block_tables, start_pos,
                        chunk_lens, active, write_from):
        b, c = tokens.shape
        page = kv["k"].shape[2]
        pmax = block_tables.shape[1]
        idx = jnp.arange(c, dtype=jnp.int32)[None, :]           # (1, C)
        positions = start_pos[:, None] + idx                    # (B, C)
        valid = active[:, None] & (idx < chunk_lens[:, None])
        do_write = valid & (positions >= write_from[:, None])
        slot = jnp.take_along_axis(
            block_tables, jnp.clip(positions // page, 0, pmax - 1), axis=1)
        page_idx = jnp.where(do_write, slot, TRASH_PAGE)
        offset = jnp.where(do_write, positions % page, 0)
        return _paged_forward(params, cfg, policy, tokens, kv,
                              block_tables, positions, page_idx, offset,
                              attn_core=attn_core, paged_core=paged_core)

    return chunked_prefill


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def make_paged_decode(cfg: ModelConfig,
                      policy: ArithmeticPolicy = ArithmeticPolicy(),
                      attn_core=None, paged_core=None):
    """Returns decode(params, tokens, kv, block_tables, seq_lens, active)
    -> (logits (B, V), kv). One token per lane at a fixed batch shape."""
    _check_family(cfg)

    def decode(params, tokens, kv, block_tables, seq_lens, active):
        page = kv["k"].shape[2]
        positions = seq_lens[:, None]                           # (B, 1)

        # scatter coordinates; inactive lanes write to the trash page
        page_slot = jnp.take_along_axis(
            block_tables, (seq_lens // page)[:, None], axis=1)[:, 0]
        page_idx = jnp.where(active, page_slot, TRASH_PAGE)[:, None]
        offset = jnp.where(active, seq_lens % page, 0)[:, None]
        logits, kv = _paged_forward(params, cfg, policy, tokens, kv,
                                    block_tables, positions, page_idx,
                                    offset, attn_core=attn_core,
                                    paged_core=paged_core)
        return logits[:, 0], kv

    return decode
