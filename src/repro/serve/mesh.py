"""Device-mesh seam for the serve stack.

Every serve-layer component that could care about device placement —
backend construction, parameter/KV placement, cost pricing — is
parameterized by a `ServeMesh` instead of asking jax about devices.
This module is the ONE place the serve tree is allowed to construct a
mesh or query the device inventory (statically enforced by the
`mesh-discipline` rule in `repro.analysis`); everything downstream
takes the seam as a value.

Two invariants the refactor hangs on:

  * The single-device mesh (`make_serve_mesh(1)`, the default) is a
    strict no-op: it constructs NO jax objects, performs NO device
    queries, and every placement helper below returns None — so the
    single-device serve path is bit-identical to the pre-mesh code.
  * A multi-shard mesh is pure tensor parallelism over one axis
    (`"model"`): parameters shard per `parallel.sharding.param_specs`
    (FSDP off — there is no data axis), the paged KV pool shards along
    the KV-head axis when it divides (`paged_pool_spec`), and page
    tables stay host-side, so the allocator / PrefixIndex / COW logic
    is mesh-oblivious.

Development and CI simulate the mesh on CPU:
`XLA_FLAGS=--xla_force_host_platform_device_count=8`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

__all__ = [
    "ServeMesh",
    "make_serve_mesh",
    "param_shardings",
    "kv_pool_sharding",
    "replicated",
    "replicated_spec",
    "seq_sharded_spec",
]


@dataclasses.dataclass(frozen=True)
class ServeMesh:
    """The serve layer's view of device topology.

    n_shards  tensor-parallel degree (1 = single device)
    axis      mesh axis name the TP collectives run over
    handle    the jax.sharding.Mesh when n_shards > 1, else None —
              the single-device seam never touches jax device state
    """
    n_shards: int = 1
    axis: str = "model"
    handle: Any = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if (self.handle is None) != (self.n_shards == 1):
            raise ValueError(
                "ServeMesh invariant: handle is None iff n_shards == 1 "
                f"(got n_shards={self.n_shards}, handle={self.handle!r})")

    @property
    def is_single(self) -> bool:
        return self.n_shards == 1


def make_serve_mesh(n_shards: int = 1, axis: str = "model") -> ServeMesh:
    """Build the serve mesh. n_shards == 1 is the strict no-op default."""
    if n_shards == 1:
        return ServeMesh()
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    import jax
    try:
        handle = jax.make_mesh((n_shards,), (axis,))
    except ValueError as e:
        raise ValueError(
            f"cannot build a {n_shards}-way serve mesh: {e}. On CPU, "
            f"simulate devices with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} "
            f"(set BEFORE jax initializes)") from e
    return ServeMesh(n_shards=n_shards, axis=axis, handle=handle)


# ---------------------------------------------------------------------------
# placement helpers — all return None on the single-device mesh so the
# default path stays a strict no-op
# ---------------------------------------------------------------------------


def param_shardings(mesh: ServeMesh, cfg, params):
    """NamedSharding pytree for the model parameters (pure TP: the
    `parallel.sharding` rules with FSDP off), or None on the single
    mesh."""
    if mesh.is_single:
        return None
    from repro.parallel import sharding as sh
    rules = sh.ShardingRules(fsdp=False)
    specs = sh.param_specs(cfg, params, mesh.handle, rules)
    return sh.named(mesh.handle, specs)


def kv_pool_sharding(mesh: ServeMesh, cfg):
    """NamedSharding for the paged KV pool (L, n_pages, page, KV, hd):
    per-shard K/V partitioned along heads when KV heads divide the TP
    degree, replicated otherwise. None on the single mesh."""
    if mesh.is_single:
        return None
    import jax
    from repro.parallel import sharding as sh
    spec = sh.paged_pool_spec(cfg, mesh.handle)
    return jax.sharding.NamedSharding(mesh.handle, spec)


def replicated(mesh: ServeMesh):
    """Fully-replicated NamedSharding over the mesh, or None on the
    single mesh."""
    if mesh.is_single:
        return None
    import jax
    from jax.sharding import PartitionSpec
    return jax.sharding.NamedSharding(mesh.handle, PartitionSpec())


def replicated_spec(mesh: ServeMesh):
    """Bare replicated PartitionSpec for shard_map in/out specs (the
    sharded backend's attention cores), or None on the single mesh.
    Consumers take specs from here instead of constructing them — the
    `shard-spec-discipline` analysis rule enforces it."""
    if mesh.is_single:
        return None
    from jax.sharding import PartitionSpec
    return PartitionSpec()


def seq_sharded_spec(mesh: ServeMesh):
    """PartitionSpec sharding axis 1 — the SEQUENCE axis of a gathered
    (batch, seq, ...) KV view — over the mesh's TP axis, or None on
    the single mesh. This is the token-dataflow layout the dataflow
    attention cores (`ring_attention` / `split_kv_attention`) consume."""
    if mesh.is_single:
        return None
    from jax.sharding import PartitionSpec
    return PartitionSpec(None, mesh.axis)
