"""Recurrent-state forward passes for the serving engine.

The attention families grow K/V with the sequence, so `paged_model`
pools fixed-size token pages. The recurrent families (rwkv6 / zamba2)
carry FIXED-SIZE per-sequence state — a wkv matrix + token-shift
activations, or Mamba SSD/conv states plus a bounded attention ring —
so the serve-side pool is a stack of whole state SLOTS, one per
in-flight sequence, and "allocation" is picking a free slot index.

Layout: every leaf of the family's single-sequence decode cache
(`model.init_cache(cfg, batch=1, max_len)`) gains a leading
`(n_slots,)` axis. Slot 0 is RESERVED as the trash slot, mirroring the
paged trash page: the compiled steps run at a fixed `max_batch` lane
shape, and idle lanes gather/scatter slot 0 so shapes never depend on
how many lanes are live.

Both step builders jit-compile exactly once per (cfg, policy):

  make_slot_decode(cfg, policy) ->
      (params, tokens (B, 1), pool, slot_ids (B,)) -> (logits (B, V), pool)
    One token per lane through the family's own `model.apply`, vmapped
    over lanes at batch=1 — per-lane vmap (rather than one batched
    apply) is what lets each lane carry its OWN absolute position /
    ring index inside its slot, which a shared scalar cache index
    cannot express once lanes decode at different sequence lengths.

  make_slot_prefill_chunk(cfg, policy) ->
      (params, tokens (B, C), pool, slot_ids (B,), chunk_lens (B,),
       active (B,)) -> (logits (B, C, V), pool)
    One fixed-size chunk of C prompt tokens per lane, absorbed into the
    lane's slot by a lax.scan of single-token applies. Recurrent state
    is order-dependent, so padding cannot be masked out of a batched
    multi-token apply the way paged attention masks its scatter;
    instead each scanned step keeps the PREVIOUS state for positions at
    or beyond chunk_lens[b] (and for inactive lanes), making arbitrary
    per-lane chunk lengths exact at one compiled shape. Logits are
    returned for every chunk position; the engine samples the last
    VALID one when a chunk completes its prompt.

Only recurrent families (rwkv6 / zamba2) are supported: attention
families want token pages, not whole-state slots — `repro.serve.backend`
routes each family to its backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import model
from repro.models.config import ModelConfig

TRASH_SLOT = 0

RECURRENT_FAMILIES = ("rwkv6", "zamba2")


def _check_family(cfg: ModelConfig) -> None:
    if cfg.family not in RECURRENT_FAMILIES:
        raise ValueError(
            f"state-slot serving supports recurrent families "
            f"{RECURRENT_FAMILIES}, got {cfg.family!r}")
    if cfg.modality != "text":
        raise ValueError(
            f"state-slot serving supports text modality, got "
            f"{cfg.modality!r}")


def init_slot_pool(cfg: ModelConfig, n_slots: int, max_seq_len: int,
                   dtype=jnp.float32):
    """(pool, init_slot): `pool` stacks `n_slots` copies of the
    family's batch=1 decode cache along a new leading axis (slot 0 is
    the trash slot); `init_slot` is the pristine single cache, kept
    around so freed slots can be reset on re-allocation (a zeroed slot
    is NOT pristine for every family — zamba2's ring positions
    initialize to int32 max so unwritten K/V stays masked)."""
    _check_family(cfg)
    if n_slots < 2:
        raise ValueError("need >= 2 slots (slot 0 is the trash slot)")
    if max_seq_len < 2:
        raise ValueError(f"max_seq_len must be >= 2, got {max_seq_len}")
    init_slot = model.init_cache(cfg, 1, max_seq_len, dtype=dtype)
    pool = jax.tree.map(
        lambda a: jnp.repeat(a[None], n_slots, axis=0), init_slot)
    return pool, init_slot


@functools.partial(jax.jit, donate_argnums=(0,))
def reset_slot(pool, init_slot, slot):
    """Restore `slot` to the pristine initial cache (the slot-pool
    analog of handing out a fresh page). `slot` is a traced scalar so
    every reset shares one compiled scatter; the pool is donated so
    the reset updates it in place instead of copying every slot."""
    return jax.tree.map(
        lambda p, ini: p.at[slot].set(ini), pool, init_slot)


def make_slot_decode(cfg: ModelConfig,
                     policy: ArithmeticPolicy = ArithmeticPolicy()):
    """Returns decode(params, tokens, pool, slot_ids) ->
    (logits (B, V), pool). tokens: (B, 1) i32; slot_ids: (B,) i32, the
    slot each lane owns (idle lanes: TRASH_SLOT — their garbage state
    evolves in slot 0 and is never read by a live lane)."""
    _check_family(cfg)

    def decode(params, tokens, pool, slot_ids):
        def one_lane(tok, st):
            # tok: (1,) — one token at batch=1 through the family's own
            # apply, so the slot's internal index/ring bookkeeping is
            # fully per-lane
            logits, _, new_st = model.apply(
                params, cfg, {"tokens": tok[None]}, policy=policy,
                cache=st, remat=False)
            return logits[0, -1], new_st

        states = jax.tree.map(lambda a: a[slot_ids], pool)
        logits, new_states = jax.vmap(one_lane)(tokens, states)
        new_pool = jax.tree.map(
            lambda p, n: p.at[slot_ids].set(n), pool, new_states)
        return logits, new_pool

    return decode


def make_slot_prefill_chunk(cfg: ModelConfig,
                            policy: ArithmeticPolicy = ArithmeticPolicy()):
    """Returns chunk(params, tokens, pool, slot_ids, chunk_lens, active)
    -> (logits (B, C, V), pool). Row b absorbs chunk_lens[b] valid
    prompt tokens into lane b's slot; positions at or beyond
    chunk_lens[b] (and whole inactive rows) leave the state untouched,
    so the fixed (B, C) shape serves every per-lane chunk length."""
    _check_family(cfg)

    def chunk(params, tokens, pool, slot_ids, chunk_lens, active):
        def one_lane(tok_row, st, n_valid, act):
            c = tok_row.shape[0]

            def body(st, xs):
                tok_t, t = xs
                logits, _, new_st = model.apply(
                    params, cfg, {"tokens": tok_t[None, None]},
                    policy=policy, cache=st, remat=False)
                keep = act & (t < n_valid)
                st = jax.tree.map(
                    lambda new, old: jnp.where(keep, new, old),
                    new_st, st)
                return st, logits[0, 0]

            st_f, logits_seq = jax.lax.scan(
                body, st, (tok_row, jnp.arange(c, dtype=jnp.int32)))
            return logits_seq, st_f

        states = jax.tree.map(lambda a: a[slot_ids], pool)
        logits, new_states = jax.vmap(one_lane)(
            tokens, states, chunk_lens, active)
        new_pool = jax.tree.map(
            lambda p, n: p.at[slot_ids].set(n), pool, new_states)
        return logits, new_pool

    return chunk
