"""Continuous-batching scheduler — admission control + step composition.

Each engine step the scheduler composes ONE action:

  prefill — run one fixed-size chunk (<= prefill_chunk tokens) for a
            BATCH of requests: every request already mid-prefill
            continues its next chunk, and head-of-line queued requests
            (strict FCFS) are admitted into free lanes while the
            memory budget lasts. A prompt longer than the chunk size
            spans multiple steps instead of stalling the decode lanes.
  decode  — one token for every active decode lane.
  mixed   — prefill chunks AND decode composed into a single step,
            priced as one pass over the combined token count — the
            ARTEMIS token-parallel dataflow spreads all concurrent
            tokens over the banks, so heterogeneous compositions are
            exactly what the hardware model rewards.
  advance — nothing runnable now; jump the virtual clock to the next
            arrival.

Two policies:

  fcfs — prefill chunks whenever any exist, else decode (vLLM's
         default prompt-first ordering, never mixing).
  cost — price every candidate composition (decode-only, prefill-only,
         mixed) with the ARTEMIS cost model over its TOTAL token count
         and take the cheapest per token; exact latency ties (the
         simulator's round-based latency plateaus make them real) break
         toward lower simulated energy per token, then toward the
         composition that makes more progress. The simulated per-token
         price is U-shaped in tokens-per-pass, so small chunks ride the
         falling edge and mixing usually wins — while an UNCHUNKED
         giant prompt (prefill_chunk >= prompt) still prices worse per
         token than a busy decode batch and is deferred, preserving
         the original head-of-line guarantee when chunking is off.

The scheduler is a pure function of its inputs — determinism under a
fixed trace is a test invariant. It knows NOTHING about how sequence
memory is organized: each decide() receives a fresh `BudgetProbe` from
the engine's `SequenceBackend` (see repro.serve.backend) and charges
candidate chunks and admissions against it — page math, state-slot
counting, and the prefix-share discount (an admission is billed only
for memory its shared prefix doesn't already cover) all live behind
the probe. Eviction under memory pressure lives in the engine. One
exception to the budget: the OLDEST mid-prefill request is always
planned (`forced=True`), because the engine funds it by evicting newer
requests (mirroring decode-growth eviction order), so a tight pool can
never deadlock a half-prefilled request. When even that fails — the
missing memory is held by requests OLDER than the prefiller, which
eviction never touches — the engine executes a decode round in the
chunk batch's place so the holders keep progressing.
"""
from __future__ import annotations

import dataclasses

from repro.serve.cost import ArtemisCostModel
from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str            # "prefill" | "decode" | "mixed" | "advance" | "idle"
    # (rid, n_tokens) chunk plan, in execution order: continuing
    # mid-prefill requests first (oldest admission first), then new
    # FCFS admissions
    prefill: tuple[tuple[int, int], ...] = ()
    decode: bool = False
    next_time: float | None = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "cost"       # "cost" | "fcfs"

    def __post_init__(self):
        if self.policy not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler policy {self.policy!r}")


class Scheduler:
    def __init__(self, sched_cfg: SchedulerConfig,
                 cost: ArtemisCostModel | None, prefill_chunk: int = 32):
        if sched_cfg.policy == "cost" and cost is None:
            raise ValueError("cost policy needs a cost model")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.cfg = sched_cfg
        self.cost = cost
        self.prefill_chunk = prefill_chunk

    def _plan_chunks(self, queued: list[Request],
                     prefilling: list[Request], free_lanes: int,
                     budget) -> tuple[tuple[int, int], ...]:
        """Compose this step's prefill chunk batch within the lane
        budget and the backend's memory budget. Continuing requests
        already own a lane; queued admissions consume one free lane
        each."""
        chunk = self.prefill_chunk
        plan: list[tuple[int, int]] = []
        for i, r in enumerate(prefilling):
            remaining = len(r.effective_prompt()) - r.prefill_pos
            n = budget.grant_continue(r, min(chunk, remaining),
                                      forced=(i == 0))
            if n <= 0:
                continue
            plan.append((r.rid, n))
        lanes_left = free_lanes
        for r in queued:
            if lanes_left <= 0:
                break
            n = budget.grant_admit(r, chunk)
            if n <= 0:
                break   # strict FCFS: never skip the head to admit later
            lanes_left -= 1
            plan.append((r.rid, n))
        return tuple(plan)

    def decide(self, queued: list[Request], next_arrival: float | None,
               prefilling: list[Request], decoding: list[Request],
               free_lanes: int, budget) -> Action:
        """queued: arrived, FCFS-ordered QUEUED requests; prefilling:
        mid-prefill requests in admission order; decoding: active
        decode-lane requests; budget: a fresh BudgetProbe from the
        engine's backend (consumed by this decide())."""
        plan = self._plan_chunks(queued, prefilling, free_lanes, budget)
        n_chunk = sum(n for _, n in plan)
        n_dec = len(decoding)

        if not n_chunk and not n_dec:
            if next_arrival is not None:
                return Action("advance", next_time=next_arrival)
            return Action("idle")

        if self.cfg.policy == "fcfs":
            if n_chunk:
                return Action("prefill", prefill=plan)
            return Action("decode", decode=True)

        # cost: rank candidate compositions by simulated price per
        # token, tie-broken by energy per token, then by progress
        candidates = []
        if n_chunk and n_dec:
            candidates.append((0, "mixed", n_chunk + n_dec))
        if n_chunk:
            candidates.append((1, "prefill", n_chunk))
        if n_dec:
            candidates.append((2, "decode", n_dec))
        kind = min(
            candidates,
            key=lambda c: (self.cost.price_per_token(c[2]),
                           self.cost.energy_per_token(c[2]), c[0]))[1]
        if kind == "mixed":
            return Action("mixed", prefill=plan, decode=True)
        if kind == "prefill":
            return Action("prefill", prefill=plan)
        return Action("decode", decode=True)
