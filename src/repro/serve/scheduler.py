"""Continuous-batching scheduler — admission control + step composition.

Each engine step the scheduler composes ONE action:

  prefill — run one fixed-size chunk (<= prefill_chunk tokens) for a
            BATCH of requests: every request already mid-prefill
            continues its next chunk, and head-of-line queued requests
            (strict FCFS) are admitted into free lanes while the page
            budget lasts. A prompt longer than the chunk size spans
            multiple steps instead of stalling the decode lanes.
  decode  — one token for every active decode lane.
  mixed   — prefill chunks AND decode composed into a single step,
            priced as one pass over the combined token count — the
            ARTEMIS token-parallel dataflow spreads all concurrent
            tokens over the banks, so heterogeneous compositions are
            exactly what the hardware model rewards.
  advance — nothing runnable now; jump the virtual clock to the next
            arrival.

Two policies:

  fcfs — prefill chunks whenever any exist, else decode (vLLM's
         default prompt-first ordering, never mixing).
  cost — price every candidate composition (decode-only, prefill-only,
         mixed) with the ARTEMIS cost model over its TOTAL token count
         and take the cheapest per token; exact latency ties (the
         simulator's round-based latency plateaus make them real) break
         toward lower simulated energy per token, then toward the
         composition that makes more progress. The simulated per-token
         price is U-shaped in tokens-per-pass, so small chunks ride the
         falling edge and mixing usually wins — while an UNCHUNKED
         giant prompt (prefill_chunk >= prompt) still prices worse per
         token than a busy decode batch and is deferred, preserving
         the original head-of-line guarantee when chunking is off.

The scheduler is a pure function of its inputs — determinism under a
fixed trace is a test invariant. It plans page usage against the free
count but never touches the allocator; eviction under cache pressure
lives in the engine. Admission budgeting is PREFIX-SHARING AWARE: the
engine passes a `prefix_probe` that reports how many leading prompt
tokens of a queued candidate are already resident in shareable pages,
and the plan charges the free-page budget only for the UNSHARED pages
of the candidate's first chunk (a fully-resident prompt admits at zero
page cost — it only reruns its last token for logits). One exception to the page budget: the OLDEST
mid-prefill request is always planned, because the engine funds it by
preempting newer requests (mirroring decode-growth eviction order), so
a tight pool can never deadlock a half-prefilled request. When even
that fails — the missing pages are held by requests OLDER than the
prefiller, which eviction never touches — the engine executes a decode
round in the chunk batch's place so the holders keep progressing.
"""
from __future__ import annotations

import dataclasses

from repro.serve.cost import ArtemisCostModel
from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str            # "prefill" | "decode" | "mixed" | "advance" | "idle"
    # (rid, n_tokens) chunk plan, in execution order: continuing
    # mid-prefill requests first (oldest admission first), then new
    # FCFS admissions
    prefill: tuple[tuple[int, int], ...] = ()
    decode: bool = False
    next_time: float | None = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "cost"       # "cost" | "fcfs"

    def __post_init__(self):
        if self.policy not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler policy {self.policy!r}")


class Scheduler:
    def __init__(self, sched_cfg: SchedulerConfig,
                 cost: ArtemisCostModel | None, page_size: int,
                 prefill_chunk: int = 32, prefix_probe=None):
        if sched_cfg.policy == "cost" and cost is None:
            raise ValueError("cost policy needs a cost model")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.cfg = sched_cfg
        self.cost = cost
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        # prefix_probe(request) -> leading prompt tokens already resident
        # in shareable pages (0 = no sharing); must be read-only
        self.prefix_probe = prefix_probe or (lambda r: 0)

    def _plan_chunks(self, queued: list[Request],
                     prefilling: list[Request], free_lanes: int,
                     free_pages: int) -> tuple[tuple[int, int], ...]:
        """Compose this step's prefill chunk batch within the page and
        lane budgets. Continuing requests already own a lane; queued
        admissions consume one free lane each."""
        page, chunk = self.page_size, self.prefill_chunk
        budget = free_pages
        plan: list[tuple[int, int]] = []
        for i, r in enumerate(prefilling):
            pos = r.prefill_pos
            remaining = len(r.effective_prompt()) - pos
            # resident coverage: chunks written so far plus any shared
            # prefix (a sharer's cursor can sit BELOW its resident
            # tokens while it reruns the last prompt token for logits)
            covered = max(pos, r.shared_len)
            held = -(-covered // page)       # pages already allocated
            headroom = held * page - pos     # free slots in held pages
            if i == 0:
                n = min(chunk, remaining)    # engine preempts to fund it
            else:
                n = min(chunk, remaining, headroom + budget * page)
            if n <= 0:
                continue
            budget -= max(0, -(-(pos + n) // page) - held)
            budget = max(budget, 0)
            plan.append((r.rid, n))
        lanes_left = free_lanes
        for r in queued:
            if lanes_left <= 0:
                break
            ep_len = len(r.effective_prompt())
            # at least the last prompt token must run for its logits,
            # so a full prefix hit still admits a 1-token rerun chunk
            shared = min(self.prefix_probe(r), ep_len)
            start = min(shared, ep_len - 1)
            held = -(-shared // page)        # pages sharing will grant
            n = min(chunk, ep_len - start,
                    held * page + budget * page - start)
            if n <= 0:
                break   # strict FCFS: never skip the head to admit later
            budget -= max(0, -(-(start + n) // page) - held)
            lanes_left -= 1
            plan.append((r.rid, n))
        return tuple(plan)

    def decide(self, queued: list[Request], next_arrival: float | None,
               prefilling: list[Request], decoding: list[Request],
               free_lanes: int, free_pages: int) -> Action:
        """queued: arrived, FCFS-ordered QUEUED requests; prefilling:
        mid-prefill requests in admission order; decoding: active
        decode-lane requests."""
        plan = self._plan_chunks(queued, prefilling, free_lanes,
                                 free_pages)
        n_chunk = sum(n for _, n in plan)
        n_dec = len(decoding)

        if not n_chunk and not n_dec:
            if next_arrival is not None:
                return Action("advance", next_time=next_arrival)
            return Action("idle")

        if self.cfg.policy == "fcfs":
            if n_chunk:
                return Action("prefill", prefill=plan)
            return Action("decode", decode=True)

        # cost: rank candidate compositions by simulated price per
        # token, tie-broken by energy per token, then by progress
        candidates = []
        if n_chunk and n_dec:
            candidates.append((0, "mixed", n_chunk + n_dec))
        if n_chunk:
            candidates.append((1, "prefill", n_chunk))
        if n_dec:
            candidates.append((2, "decode", n_dec))
        kind = min(
            candidates,
            key=lambda c: (self.cost.price_per_token(c[2]),
                           self.cost.energy_per_token(c[2]), c[0]))[1]
        if kind == "mixed":
            return Action("mixed", prefill=plan, decode=True)
        if kind == "prefill":
            return Action("prefill", prefill=plan)
        return Action("decode", decode=True)
