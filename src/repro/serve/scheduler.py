"""Continuous-batching scheduler — admission control + action choice.

Each engine step the scheduler picks ONE action from the feasible set:

  prefill(r) — admit the head-of-line queued request (strict FCFS within
               the queue): needs a free decode lane and enough free
               pages for its padded prompt.
  decode     — run one token for every active decode lane.
  advance(t) — nothing runnable now; jump the virtual clock to the next
               arrival.

Two policies:

  fcfs — prefill whenever admissible, else decode (vLLM's default
         prompt-first ordering).
  cost — price both candidates with the ARTEMIS cost model
         (`serve.cost.ArtemisCostModel`, hwsim token_PP dataflow) and
         take the cheaper per token. The simulated per-token price is
         U-shaped in tokens-per-pass: falling while token-based
         sharding amortizes the K/V ring broadcast (so short prefills
         are admitted eagerly — here cost coincides with fcfs), then
         rising once the O(N^2) attention terms dominate. The policies
         diverge on LONG prompts: cost keeps the decode lanes running
         rather than stalling them behind a multi-thousand-token
         prefill whose per-token price exceeds the decode batch's
         (pinned by tests/test_serve.py::test_cost_policy_defers_long_
         prefill_while_decoding).

The scheduler is a pure function of its inputs — determinism under a
fixed trace is a test invariant, and eviction (cache pressure during
decode) lives in the engine, not here.
"""
from __future__ import annotations

import dataclasses

from repro.serve.cost import ArtemisCostModel
from repro.serve.paged_cache import pad_to_page
from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str                  # "prefill" | "decode" | "advance" | "idle"
    rid: int | None = None
    next_time: float | None = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "cost"       # "cost" | "fcfs"

    def __post_init__(self):
        if self.policy not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler policy {self.policy!r}")


class Scheduler:
    def __init__(self, sched_cfg: SchedulerConfig,
                 cost: ArtemisCostModel | None, page_size: int):
        if sched_cfg.policy == "cost" and cost is None:
            raise ValueError("cost policy needs a cost model")
        self.cfg = sched_cfg
        self.cost = cost
        self.page_size = page_size

    def admissible(self, req: Request, free_lanes: int,
                   free_pages: int) -> bool:
        n_pages = pad_to_page(len(req.effective_prompt()),
                              self.page_size) // self.page_size
        return free_lanes > 0 and n_pages <= free_pages

    def decide(self, queued: list[Request], next_arrival: float | None,
               n_decoding: int, free_lanes: int,
               free_pages: int) -> Action:
        """queued: arrived, FCFS-ordered QUEUED requests."""
        head = queued[0] if queued else None
        can_prefill = head is not None and self.admissible(
            head, free_lanes, free_pages)
        can_decode = n_decoding > 0

        if can_prefill and can_decode and self.cfg.policy == "cost":
            prefill_tokens = pad_to_page(len(head.effective_prompt()),
                                         self.page_size)
            if (self.cost.price_per_token(n_decoding)
                    < self.cost.price_per_token(prefill_tokens)):
                return Action("decode")
            return Action("prefill", rid=head.rid)
        if can_prefill:
            return Action("prefill", rid=head.rid)
        if can_decode:
            return Action("decode")
        if next_arrival is not None:
            return Action("advance", next_time=next_arrival)
        return Action("idle")
