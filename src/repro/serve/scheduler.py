"""Continuous-batching scheduler — admission control + step composition.

Each engine step the scheduler composes ONE action:

  prefill — run one fixed-size chunk (<= prefill_chunk tokens) for a
            BATCH of requests: every request already mid-prefill
            continues its next chunk, and head-of-line queued requests
            (strict FCFS) are admitted into free lanes while the
            memory budget lasts. A prompt longer than the chunk size
            spans multiple steps instead of stalling the decode lanes.
  decode  — one token for every active decode lane.
  mixed   — prefill chunks AND decode composed into a single step,
            priced as one pass over the combined token count — the
            ARTEMIS token-parallel dataflow spreads all concurrent
            tokens over the banks, so heterogeneous compositions are
            exactly what the hardware model rewards.
  advance — nothing runnable now; jump the virtual clock to the next
            arrival.

Two policies:

  fcfs — prefill chunks whenever any exist, else decode (vLLM's
         default prompt-first ordering, never mixing).
  cost — price every candidate composition (decode-only, prefill-only,
         mixed) with the ARTEMIS cost model over its TOTAL token count
         and take the cheapest per token; exact latency ties (the
         simulator's round-based latency plateaus make them real) break
         toward lower simulated energy per token, then toward the
         composition that makes more progress. The simulated per-token
         price is U-shaped in tokens-per-pass, so small chunks ride the
         falling edge and mixing usually wins — while an UNCHUNKED
         giant prompt (prefill_chunk >= prompt) still prices worse per
         token than a busy decode batch and is deferred, preserving
         the original head-of-line guarantee when chunking is off.

AUDIT TRAIL: when the engine runs at `observability="trace"` the
scheduler emits one `DecisionEvent` (repro.serve.obs) per decide() —
the candidate compositions it priced with their per-token cost/energy,
what it chose and the reason code, the chunk plan, and every
admit/defer outcome with the budget-probe numbers that drove it — so
"why was this request deferred" is answerable from the event log
alone. At the default metrics level no audit objects are built.

The scheduler is a pure function of its inputs — determinism under a
fixed trace is a test invariant. It knows NOTHING about how sequence
memory is organized: each decide() receives a fresh `BudgetProbe` from
the engine's `SequenceBackend` (see repro.serve.backend) and charges
candidate chunks and admissions against it — page math, state-slot
counting, and the prefix-share discount (an admission is billed only
for memory its shared prefix doesn't already cover) all live behind
the probe. Eviction under memory pressure lives in the engine. One
exception to the budget: the OLDEST mid-prefill request is always
planned (`forced=True`), because the engine funds it by evicting newer
requests (mirroring decode-growth eviction order), so a tight pool can
never deadlock a half-prefilled request. When even that fails — the
missing memory is held by requests OLDER than the prefiller, which
eviction never touches — the engine executes a decode round in the
chunk batch's place so the holders keep progressing.
"""
from __future__ import annotations

import dataclasses

from repro.serve.cost import ArtemisCostModel
from repro.serve.obs import DecisionEvent, Tracer
from repro.serve.request import Request


@dataclasses.dataclass(frozen=True)
class Action:
    kind: str            # "prefill" | "decode" | "mixed" | "advance" | "idle"
    # (rid, n_tokens) chunk plan, in execution order: continuing
    # mid-prefill requests first (oldest admission first), then new
    # FCFS admissions
    prefill: tuple[tuple[int, int], ...] = ()
    decode: bool = False
    next_time: float | None = None


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    policy: str = "cost"       # "cost" | "fcfs"

    def __post_init__(self):
        if self.policy not in ("cost", "fcfs"):
            raise ValueError(f"unknown scheduler policy {self.policy!r}")


class Scheduler:
    def __init__(self, sched_cfg: SchedulerConfig,
                 cost: ArtemisCostModel | None, prefill_chunk: int = 32,
                 obs: Tracer | None = None, clock=None):
        """`obs`/`clock` (the engine's Tracer and virtual-clock read)
        enable the per-decide() audit trail; without them — or at the
        default metrics level — decide() builds no audit objects."""
        if sched_cfg.policy == "cost" and cost is None:
            raise ValueError("cost policy needs a cost model")
        if prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        self.cfg = sched_cfg
        self.cost = cost
        self.prefill_chunk = prefill_chunk
        self.obs = obs
        self.clock = clock or (lambda: 0.0)

    @property
    def _auditing(self) -> bool:
        return self.obs is not None and self.obs.tracing

    def _plan_chunks(self, queued: list[Request],
                     prefilling: list[Request], free_lanes: int,
                     budget, audit: dict | None = None
                     ) -> tuple[tuple[int, int], ...]:
        """Compose this step's prefill chunk batch within the lane
        budget and the backend's memory budget. Continuing requests
        already own a lane; queued admissions consume one free lane
        each. When `audit` is given, record each admit/defer outcome
        into it (keys "admitted"/"deferred") with a reason code."""
        chunk = self.prefill_chunk
        plan: list[tuple[int, int]] = []
        for i, r in enumerate(prefilling):
            remaining = len(r.effective_prompt()) - r.prefill_pos
            n = budget.grant_continue(r, min(chunk, remaining),
                                      forced=(i == 0))
            if n <= 0:
                if audit is not None:
                    audit["deferred"].append((r.rid, "budget_exhausted"))
                continue
            plan.append((r.rid, n))
        lanes_left = free_lanes
        blocked = None               # FCFS head that failed admission
        for r in queued:
            if lanes_left <= 0:
                if audit is not None:
                    audit["deferred"].append((r.rid, "no_free_lane"))
                    continue         # keep auditing the rest
                break
            if blocked is not None:
                # strict FCFS: the head is stuck, so is everyone behind
                audit["deferred"].append((r.rid, "fcfs_head_blocked"))
                continue
            n = budget.grant_admit(r, chunk)
            if n <= 0:
                if audit is None:
                    break   # never skip the head to admit later
                audit["deferred"].append((r.rid, "budget_exhausted"))
                blocked = r.rid
                continue
            lanes_left -= 1
            plan.append((r.rid, n))
            if audit is not None:
                audit["admitted"].append((r.rid, n))
        return tuple(plan)

    def decide(self, queued: list[Request], next_arrival: float | None,
               prefilling: list[Request], decoding: list[Request],
               free_lanes: int, budget) -> Action:
        """queued: arrived, FCFS-ordered QUEUED requests; prefilling:
        mid-prefill requests in admission order; decoding: active
        decode-lane requests; budget: a fresh BudgetProbe from the
        engine's backend (consumed by this decide())."""
        audit = ({"admitted": [], "deferred": []}
                 if self._auditing else None)
        budget_free = getattr(budget, "free", None) if audit else None
        plan = self._plan_chunks(queued, prefilling, free_lanes, budget,
                                 audit)
        n_chunk = sum(n for _, n in plan)
        n_dec = len(decoding)

        def _record(chosen: str, reason: str,
                    scored: tuple = ()) -> None:
            if audit is None:
                return
            self.obs.emit(DecisionEvent(
                ts=self.clock(), chosen=chosen, reason=reason,
                candidates=scored, plan=plan, n_decode=n_dec,
                admitted=tuple(audit["admitted"]),
                deferred=tuple(audit["deferred"]),
                budget_free=budget_free))

        if not n_chunk and not n_dec:
            if next_arrival is not None:
                _record("advance", "nothing_runnable_before_arrival")
                return Action("advance", next_time=next_arrival)
            _record("idle", "no_work")
            return Action("idle")

        if self.cfg.policy == "fcfs":
            if n_chunk:
                _record("prefill", "fcfs_prompt_first")
                return Action("prefill", prefill=plan)
            _record("decode", "fcfs_no_prefill_work")
            return Action("decode", decode=True)

        # cost: rank candidate compositions by simulated price per
        # token, tie-broken by energy per token, then by progress
        candidates = []
        if n_chunk and n_dec:
            candidates.append((0, "mixed", n_chunk + n_dec))
        if n_chunk:
            candidates.append((1, "prefill", n_chunk))
        if n_dec:
            candidates.append((2, "decode", n_dec))
        kind = min(
            candidates,
            key=lambda c: (self.cost.price_per_token(c[2]),
                           self.cost.energy_per_token(c[2]), c[0]))[1]
        if audit is not None:
            scored = tuple(
                (name, n, self.cost.price_per_token(n),
                 self.cost.energy_per_token(n))
                for _, name, n in candidates)
            _record(kind, "only_candidate" if len(candidates) == 1
                    else "cheapest_per_token", scored)
        if kind == "mixed":
            return Action("mixed", prefill=plan, decode=True)
        if kind == "prefill":
            return Action("prefill", prefill=plan)
        return Action("decode", decode=True)
