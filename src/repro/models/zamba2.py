"""Zamba2-style hybrid: Mamba2 (SSD) backbone + one SHARED transformer
block applied every `shared_attn_period` layers (weights reused across
invocations — the Zamba2 parameter-sharing trick, arXiv:2411.15242).

Simplifications vs. the released checkpoints (recorded in DESIGN.md):
per-invocation LoRA deltas on the shared block are omitted; the shared
block consumes the hidden state directly (no concat-with-embedding
projector). The layer count, widths, SSM state size, and the
share-every-k structure match the assigned config.

Decode cache:
  mamba  — per-layer SSD + conv states, stacked (L, ...)
  attn   — per-invocation KV ring buffers (n_inv, B, Sc, KV, Dh) with a
           stored absolute-position array (ring => sliding window for the
           long_500k cell; Sc = attn_window when set, else max_len)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.config import ModelConfig
from repro.models.transformer import _embed_tokens, _logits
from repro.parallel.context import activation_constraint

INT32_MAX = jnp.iinfo(jnp.int32).max


def _dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    v, d = cfg.padded_vocab, cfg.d_model
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    layers = jax.vmap(lambda k: M2.mamba2_init(k, cfg, dtype))(layer_keys)
    kk = jax.random.split(ks[2], 2)
    shared = {
        "ln1": L.rmsnorm_init(d, dtype),
        "attn": L.attn_init(kk[0], d, _dims(cfg), cfg.qk_norm, dtype),
        "ln2": L.rmsnorm_init(d, dtype),
        "ffn": L.ffn_init(kk[1], d, cfg.d_ff, cfg.glu, dtype),
    }
    params = {"embed": L.embed_init(ks[0], v, d, dtype),
              "layers": layers, "shared": shared,
              "final_norm": L.rmsnorm_init(d, dtype)}
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(ks[3], d, v, dtype)
    return params


def n_invocations(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_period


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    ninv = n_invocations(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    sc = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    st = M2.init_state(cfg, batch)
    mamba = jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)
    return {
        "mamba": mamba,
        "attn_k": jnp.zeros((ninv, batch, sc, kv, hd), dtype),
        "attn_v": jnp.zeros((ninv, batch, sc, kv, hd), dtype),
        "attn_pos": jnp.full((batch, sc), INT32_MAX, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


def _shared_block(sp, x, cfg, policy, positions, kv_positions, cache_kv,
                  slot):
    h, new_kv = L.attention(
        sp["attn"], L.rmsnorm(sp["ln1"], x, cfg.norm_eps), _dims(cfg),
        positions=positions, kv_positions=kv_positions, policy=policy,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
        window=cfg.attn_window, norm_eps=cfg.norm_eps,
        cache=cache_kv, cache_index=slot)
    x = x + h
    f = L.ffn(sp["ffn"], L.rmsnorm(sp["ln2"], x, cfg.norm_eps),
              cfg.act, cfg.glu, policy)
    return x + f, new_kv


def apply(params, cfg: ModelConfig, inputs: dict, *,
          policy: ArithmeticPolicy = ArithmeticPolicy(),
          cache: dict | None = None, remat: bool = True,
          unroll: int | bool = 1):
    """Returns (logits, aux(=0), new_cache)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(params, cfg, inputs["tokens"], dtype)
    b, s, d = x.shape
    period = cfg.shared_attn_period
    ninv = n_invocations(cfg)
    tail = cfg.n_layers - ninv * period

    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = inputs.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(
            index + jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))

    # -- attention cache bookkeeping (ring) ---------------------------------
    kv_positions = None
    slot = jnp.zeros((), jnp.int32)
    new_pos = None
    if cache is not None:
        sc = cache["attn_k"].shape[2]
        if s >= sc:
            # prefill longer than the window ring: attention runs
            # in-sequence (L.attention's s >= smax path); the ring ends
            # up holding the last sc tokens
            new_pos = positions[:, -sc:]
            kv_positions = None
        else:
            slot = jnp.remainder(index, sc)
            new_pos = jax.lax.dynamic_update_slice(
                cache["attn_pos"], positions, (0, slot))
            kv_positions = new_pos

    # -- scan over invocation groups ---------------------------------------
    def mamba_body(carry, xs):
        x = carry["x"]
        st = None
        if cache is not None:
            st = xs["state"]
        out, new_st = M2.mamba2_layer(xs["lp"], x, cfg, policy, st)
        ys = {"state": new_st} if cache is not None else None
        return {"x": x + out}, ys

    mamba_scan = jax.checkpoint(mamba_body) if remat else mamba_body

    def run_layers(x, lps, states):
        xs = {"lp": lps}
        if cache is not None:
            xs["state"] = states
        carry, ys = jax.lax.scan(mamba_scan, {"x": x}, xs, unroll=unroll)
        return carry["x"], (ys["state"] if cache is not None else None)

    def take(tree, lo, hi, reshape=None):
        def f(a):
            a = a[lo:hi]
            if reshape is not None:
                a = a.reshape(reshape + a.shape[1:])
            return a
        return jax.tree.map(f, tree)

    grp_lps = take(params["layers"], 0, ninv * period, (ninv, period))
    grp_states = None
    if cache is not None:
        grp_states = take(cache["mamba"], 0, ninv * period, (ninv, period))

    def group_body(carry, xs):
        x = carry["x"]
        x, new_states = run_layers(x, xs["lps"],
                                   xs.get("states"))
        ckv = None
        if cache is not None:
            ckv = {"k": xs["ck"], "v": xs["cv"]}
        x, new_kv = _shared_block(params["shared"], x, cfg, policy,
                                  positions, kv_positions, ckv, slot)
        x = activation_constraint(x, "resid")
        ys = {}
        if cache is not None:
            ys = {"states": new_states,
                  "ck": new_kv["k"], "cv": new_kv["v"]}
        return {"x": x}, ys

    xs = {"lps": grp_lps}
    if cache is not None:
        xs["states"] = grp_states
        xs["ck"], xs["cv"] = cache["attn_k"], cache["attn_v"]
    carry, ys = jax.lax.scan(group_body, {"x": x}, xs, unroll=unroll)
    x = carry["x"]

    new_tail_states = None
    if tail:
        x, new_tail_states = run_layers(
            x, take(params["layers"], ninv * period, cfg.n_layers),
            take(cache["mamba"], ninv * period, cfg.n_layers)
            if cache is not None else None)

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    logits = activation_constraint(logits, "logits")

    new_cache = None
    if cache is not None:
        grp = jax.tree.map(
            lambda a: a.reshape((ninv * period,) + a.shape[2:]),
            ys["states"])
        if tail:
            mamba = jax.tree.map(
                lambda a, t: jnp.concatenate([a, t], axis=0),
                grp, new_tail_states)
        else:
            mamba = grp
        new_cache = {"mamba": mamba, "attn_k": ys["ck"], "attn_v": ys["cv"],
                     "attn_pos": new_pos, "index": index + s}
    return logits, jnp.zeros((), jnp.float32), new_cache
