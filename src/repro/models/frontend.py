"""Modality frontends — STUBS per the brief.

The assigned [vlm]/[audio] architectures specify the transformer BACKBONE
only; the modality frontend supplies *precomputed* patch/frame embeddings
(`input_specs()` hands the model `prefix_embeds` ShapeDtypeStructs, and the
data pipeline synthesizes deterministic stand-ins).

  vlm   (internvl2-1b): an InternViT-300M vision tower would emit
        (n_patches, d_vit) features -> pixel-shuffle -> MLP projector to the
        LM width. We stub the tower+projector output: (B, n_patches, d_model).
  audio (musicgen-large): EnCodec tokenizes audio into `n_codebooks`
        parallel streams; the backbone consumes the token streams directly
        (codebook embeddings are summed *inside* the model — that part is
        real, in transformer._embed_tokens). Nothing to stub beyond the
        token layout (B, S, n_codebooks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# InternVL2-1B: 448x448 image, 14x14 ViT patches -> 1024 tokens,
# pixel-shuffle x0.5 -> 256 visual tokens entering the LM.
VLM_PREFIX_TOKENS = 256


def n_prefix_tokens(cfg: ModelConfig) -> int:
    return VLM_PREFIX_TOKENS if cfg.modality == "vlm" else 0


def prefix_embed_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    """ShapeDtypeStruct for the precomputed visual prefix (dry-run input)."""
    assert cfg.modality == "vlm"
    return jax.ShapeDtypeStruct(
        (batch, VLM_PREFIX_TOKENS, cfg.d_model), jnp.dtype(cfg.compute_dtype)
    )


def synth_prefix_embeds(key, cfg: ModelConfig, batch: int) -> jax.Array:
    """Deterministic stand-in for ViT features (unit-RMS, like post-LN)."""
    x = jax.random.normal(
        key, (batch, VLM_PREFIX_TOKENS, cfg.d_model), jnp.float32
    )
    return x.astype(jnp.dtype(cfg.compute_dtype))


def token_shape(cfg: ModelConfig, batch: int, seq: int) -> tuple[int, ...]:
    """Token-input shape for a given modality."""
    if cfg.modality == "audio":
        return (batch, seq, cfg.n_codebooks)
    return (batch, seq)
