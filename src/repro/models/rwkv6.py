"""RWKV-6 "Finch" — attention-free, data-dependent per-channel decay.

Chunked-parallel wkv evaluation (exact, not an approximation):

  S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: (H, N, N))
  o_t = r_t . S_{t-1} + (r_t . (u * k_t)) v_t   (bonus on the current token)

Within a chunk of length L the pairwise per-channel decay
exp(lc_{t-1} - lc_m) (<= 1, so fp32-stable) is contracted directly:
A[t,m] = sum_i r_{t,i} k_{m,i} exp(lc_{t-1,i} - lc_{m,i}) for m < t.
A lax.scan over chunks carries S. This is the paper's token-dataflow
degenerate case: sequence sharding needs only a chunk-boundary state
pass, no ring (DESIGN.md §Arch-applicability).

Time-mix uses the RWKV6 ddlerp (low-rank data-dependent token-shift
mixing); channel-mix is the relu^2 MLP. Norms are LayerNorm (as in the
reference implementation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.context import activation_constraint

LORA_MIX = 32     # ddlerp rank
LORA_DECAY = 64   # decay lora rank


# ---------------------------------------------------------------------------
# layernorm (RWKV uses LN, not RMSNorm)
# ---------------------------------------------------------------------------


def ln_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def rwkv6_layer_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, dff = cfg.d_model, cfg.d_ff
    h = cfg.d_model // cfg.ssm_head_dim
    n = cfg.ssm_head_dim
    ks = jax.random.split(key, 12)
    u = 0.5 * jnp.ones((h, n), jnp.float32)
    return {
        "ln1": ln_init(d, dtype), "ln2": ln_init(d, dtype),
        # time-mix ddlerp
        "maa_x": jnp.full((d,), 0.5, dtype),
        "maa_wkvrg": jnp.full((5, d), 0.5, dtype),
        "maa_w1": (jax.random.normal(ks[0], (d, 5 * LORA_MIX), jnp.float32)
                   * 1e-2).astype(dtype),
        "maa_w2": (jax.random.normal(ks[1], (5, LORA_MIX, d), jnp.float32)
                   * 1e-2).astype(dtype),
        # data-dependent decay
        "td_base": jnp.full((d,), -1.0, dtype),   # w ~ exp(-exp(-1)) ~ .69
        "td_w1": (jax.random.normal(ks[2], (d, LORA_DECAY), jnp.float32)
                  * 1e-2).astype(dtype),
        "td_w2": (jax.random.normal(ks[3], (LORA_DECAY, d), jnp.float32)
                  * 1e-2).astype(dtype),
        "u": u.astype(dtype),
        "wr": L.dense_init(ks[4], d, d, dtype),
        "wk": L.dense_init(ks[5], d, d, dtype),
        "wv": L.dense_init(ks[6], d, d, dtype),
        "wg": L.dense_init(ks[7], d, d, dtype),
        "wo": L.dense_init(ks[8], d, d, dtype),
        "ln_x": ln_init(d, dtype),
        # channel-mix
        "cm_maa_k": jnp.full((d,), 0.5, dtype),
        "cm_maa_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": L.dense_init(ks[9], d, dff, dtype),
        "cm_wv": L.dense_init(ks[10], dff, d, dtype),
        "cm_wr": L.dense_init(ks[11], d, d, dtype),
    }


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Decode carry for ONE layer."""
    h = cfg.d_model // cfg.ssm_head_dim
    n = cfg.ssm_head_dim
    return {
        "x_tm": jnp.zeros((batch, cfg.d_model), dtype),
        "x_cm": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, h, n, n), dtype),
    }


# ---------------------------------------------------------------------------
# chunked wkv
# ---------------------------------------------------------------------------


def _wkv_chunked(r, k, v, log_w, u, s0, chunk: int):
    """r,k,v: (B,S,H,N); log_w: (B,S,H,N) <= 0; u: (H,N); s0: (B,H,N,N).

    Returns (o: (B,S,H,N), s_final)."""
    b, s, h, n = r.shape
    pad = (-s) % chunk
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        log_w = jnp.pad(log_w, z)
    nc = r.shape[1] // chunk
    shp = (b, nc, chunk, h, n)
    r, k, v, log_w = (a.reshape(shp) for a in (r, k, v, log_w))
    lc = jnp.cumsum(log_w, axis=2)                    # inclusive
    # exclusive cumsum for the output side (S_{t-1} uses lc_{t-1})
    lx = lc - log_w
    strict = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)

    def body(state, xs):
        rc, kc, vc, lcc, lxc = xs                     # (B,L,H,N)
        # intra: A[t,m] = sum_i r_t k_m exp(lx_t - lc_m), m < t
        dec = jnp.exp(jnp.clip(
            lxc[:, :, None, :, :] - lcc[:, None, :, :, :], -60.0, 0.0))
        amat = jnp.einsum("bthn,bmhn,btmhn->bhtm", rc, kc, dec)
        amat = jnp.where(strict[None, None], amat, 0.0)
        o = jnp.einsum("bhtm,bmhn->bthn", amat, vc)
        # bonus (current token)
        o = o + jnp.einsum("bthn,hn,bthn->bth", rc, u, kc)[..., None] * vc
        # inter: o_t += (r_t * exp(lx_t)) . S0
        o = o + jnp.einsum("bthn,bhnj->bthj", rc * jnp.exp(lxc), state)
        # state: S' = diag(exp(lc_L)) S0 + sum_m exp(lc_L - lc_m) k_m v_m^T
        dlast = jnp.exp(lcc[:, -1, None, :, :] - lcc)  # (B,L,H,N)
        snew = state * jnp.exp(lcc[:, -1])[:, :, :, None] \
            + jnp.einsum("bmhn,bmhj->bhnj", kc * dlast, vc)
        return snew, o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, lc, lx))
    s_final, os = jax.lax.scan(body, s0, xs)
    o = jnp.moveaxis(os, 0, 1).reshape(b, nc * chunk, h, n)
    return o[:, :s], s_final


# ---------------------------------------------------------------------------
# layer forward
# ---------------------------------------------------------------------------


def _shift(x, prev):
    """Token shift: prev token's activation. x: (B,S,d), prev: (B,d)|None."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def rwkv6_layer(p, x, cfg: ModelConfig, policy=ArithmeticPolicy(),
                state=None):
    """x: (B, S, d) -> (out, new_state or None)."""
    b, s, d = x.shape
    h = d // cfg.ssm_head_dim
    n = cfg.ssm_head_dim

    # ---- time mix ---------------------------------------------------------
    xt = layernorm(p["ln1"], x)
    prev = state["x_tm"].astype(xt.dtype) if state is not None else None
    xprev = _shift(xt, prev)
    dx = xprev - xt
    xxx = xt + dx * p["maa_x"].astype(xt.dtype)
    delta = jnp.tanh(jnp.einsum("bsd,dr->bsr", xxx, p["maa_w1"].astype(
        xt.dtype))).reshape(b, s, 5, LORA_MIX)
    dyn = jnp.einsum("bsfr,frd->bsfd", delta, p["maa_w2"].astype(xt.dtype))
    mixes = xt[:, :, None] + dx[:, :, None] * (
        p["maa_wkvrg"].astype(xt.dtype)[None, None] + dyn)   # (B,S,5,d)
    mw, mk, mv, mr, mg = (mixes[:, :, i] for i in range(5))

    dd = jnp.tanh(L.mm(mw, p["td_w1"], policy)).astype(jnp.float32)
    log_w = -jnp.exp(jnp.clip(
        p["td_base"].astype(jnp.float32)[None, None]
        + jnp.matmul(dd, p["td_w2"].astype(jnp.float32)), -8.0, 6.0))

    # projections go through the policy ladder; the wkv recurrence itself
    # stays exact fp32 (DESIGN.md §Arch-applicability)
    r = L.mm(mr, p["wr"], policy).reshape(b, s, h, n).astype(jnp.float32)
    k = L.mm(mk, p["wk"], policy).reshape(b, s, h, n).astype(jnp.float32)
    v = L.mm(mv, p["wv"], policy).reshape(b, s, h, n).astype(jnp.float32)
    g = jax.nn.silu(L.mm(mg, p["wg"], policy))
    log_w = log_w.reshape(b, s, h, n)

    s0 = (state["wkv"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, h, n, n), jnp.float32))
    o, s_final = _wkv_chunked(r, k, v, log_w, p["u"].astype(jnp.float32),
                              s0, min(cfg.chunk_size, max(s, 1)))
    o = o.reshape(b, s, d).astype(x.dtype)
    o = layernorm(p["ln_x"], o) * g
    x = x + L.mm(o, p["wo"], policy)

    # ---- channel mix ------------------------------------------------------
    xc = layernorm(p["ln2"], x)
    prevc = state["x_cm"].astype(xc.dtype) if state is not None else None
    xprevc = _shift(xc, prevc)
    dxc = xprevc - xc
    xk = xc + dxc * p["cm_maa_k"].astype(xc.dtype)
    xr = xc + dxc * p["cm_maa_r"].astype(xc.dtype)
    kk = jnp.square(jax.nn.relu(L.mm(xk, p["cm_wk"], policy)))
    cm = jax.nn.sigmoid(L.mm(xr, p["cm_wr"], policy)) \
        * L.mm(kk, p["cm_wv"], policy)
    x = x + cm

    new_state = None
    if state is not None:
        new_state = {
            "x_tm": xt[:, -1].astype(state["x_tm"].dtype),
            "x_cm": xc[:, -1].astype(state["x_cm"].dtype),
            "wkv": s_final.astype(state["wkv"].dtype),
        }
    return x, new_state


# ---------------------------------------------------------------------------
# model level (embed -> scan over layers -> head)
# ---------------------------------------------------------------------------


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    v, d = cfg.padded_vocab, cfg.d_model
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    layers = jax.vmap(lambda k: rwkv6_layer_init(k, cfg, dtype))(layer_keys)
    params = {"embed": L.embed_init(ks[0], v, d, dtype),
              "ln0": ln_init(d, dtype),          # RWKV's post-embed LN
              "layers": layers,
              "final_norm": ln_init(d, dtype)}
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(ks[2], d, v, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0,
               dtype=jnp.float32):
    """Decode carry, stacked (L, ...). max_len unused (O(1) state)."""
    st = init_state(cfg, batch, dtype)
    return {
        "layers": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st),
        "index": jnp.zeros((), jnp.int32),
    }


def apply(params, cfg: ModelConfig, inputs: dict, *,
          policy: ArithmeticPolicy = ArithmeticPolicy(),
          cache: dict | None = None, remat: bool = True,
          unroll: int | bool = 1):
    """Returns (logits, aux(=0), new_cache)."""
    from repro.models.transformer import _embed_tokens, _logits
    dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_tokens(params, cfg, inputs["tokens"], dtype)
    x = layernorm(params["ln0"], x)

    def body(carry, xs):
        x = carry
        st = xs.get("state")
        out, new_st = rwkv6_layer(xs["lp"], x, cfg, policy, st)
        out = activation_constraint(out, "resid")
        ys = {"state": new_st} if cache is not None else None
        return out, ys

    scan_body = jax.checkpoint(body) if remat else body
    xs = {"lp": params["layers"]}
    if cache is not None:
        xs["state"] = cache["layers"]
    x, ys = jax.lax.scan(scan_body, x, xs, unroll=unroll)
    x = layernorm(params["final_norm"], x)
    logits = _logits(params, cfg, x)
    logits = activation_constraint(logits, "logits")
    new_cache = None
    if cache is not None:
        new_cache = {"layers": ys["state"],
                     "index": cache["index"] + inputs["tokens"].shape[1]}
    return logits, jnp.zeros((), jnp.float32), new_cache
