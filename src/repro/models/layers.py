"""Shared neural building blocks (pure JAX, no module framework).

Every parameterized op is a pair of functions:
  init_*(key, cfg, ...) -> pytree of arrays
  apply / named forward fn (params, x, ...) -> array

All dense matmuls route through `mm(...)`, the ArithmeticPolicy switch:
exact mode keeps the compute dtype (bf16 on TPU); quantized modes call
repro.core.artemis_matmul. Attention score/value contractions go through
`qmm_nt` / `qmm_nn`, batched int8 variants of the same ladder (the paper
applies SC to *all* MHA and FFN MatMuls; embeddings and the LM head stay
exact, as does the MoE router — see ArithmeticPolicy docstring).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import quantization as q
from repro.core.artemis_matmul import artemis_matmul
from repro.core.policy import ArithmeticPolicy
from repro.core.quantization import SC_LEVELS
from repro.parallel.context import attention_heads_constraint

# ---------------------------------------------------------------------------
# policy-routed matmuls
# ---------------------------------------------------------------------------


def mm(x: jax.Array, w: jax.Array, policy: ArithmeticPolicy) -> jax.Array:
    """x: (..., K) activations, w: (K, N) weights -> (..., N), x.dtype."""
    if policy.mode == "exact":
        return jnp.matmul(x, w.astype(x.dtype))
    out = artemis_matmul(x, w, policy)
    return out.astype(x.dtype)


def _quant_einsum(spec, a, b, policy):
    """Batched einsum through the int8 / artemis_mxu ladder."""
    sa = q.quant_scale(a, 8, policy.act_quant_axis)
    sb = q.quant_scale(b, 8, policy.act_quant_axis)
    aq, bq = q.quantize(a, sa), q.quantize(b, sb)
    dot = jnp.einsum(spec, aq.astype(jnp.int32), bq.astype(jnp.int32),
                     preferred_element_type=jnp.int32).astype(jnp.float32)
    if policy.mode == "artemis_mxu":
        sgn = jnp.einsum(spec, jnp.sign(aq).astype(jnp.int32),
                         jnp.sign(bq).astype(jnp.int32),
                         preferred_element_type=jnp.int32)
        dot = dot - policy.rbar / SC_LEVELS * sgn.astype(jnp.float32)
    out = dot * sa * sb
    if policy.ste:
        exact = jnp.einsum(spec, a.astype(jnp.float32), b.astype(jnp.float32))
        out = exact + jax.lax.stop_gradient(out - exact)
    return out


def qeinsum(spec: str, a: jax.Array, b: jax.Array,
            policy: ArithmeticPolicy) -> jax.Array:
    """Attention-style batched contraction under the policy ladder.

    `artemis` (bit-level) mode is deliberately mapped onto `artemis_mxu`
    here: per-element stream emulation of a batched attention einsum is a
    test-bench tool, not a model-scale path (DESIGN.md §4).
    """
    if policy.mode == "exact":
        return jnp.einsum(spec, a, b)
    return _quant_einsum(spec, a, b, policy).astype(a.dtype)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def headwise_rmsnorm(scale, x, eps: float = 1e-6):
    """qk-norm: normalize over head_dim. x: (..., H, Dh), scale: (Dh,)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def groupnorm(x, n_groups: int, eps: float = 1e-5):
    """GroupNorm over the channel dim (no affine). x: (..., C)."""
    dt = x.dtype
    *lead, c = x.shape
    x = x.astype(jnp.float32).reshape(*lead, n_groups, c // n_groups)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return x.reshape(*lead, c).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    dt = x.dtype
    freqs = rope_frequencies(x.shape[-1], theta)          # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# attention (GQA/MQA, optional qk-norm, KV cache, sliding window)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int


def attn_init(key, d_model: int, dims: AttnDims, qk_norm: bool,
              dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    p = {
        "wq": dense_init(ks[0], d_model, h * hd, dtype),
        "wk": dense_init(ks[1], d_model, kv * hd, dtype),
        "wv": dense_init(ks[2], d_model, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _causal_mask(q_pos, k_pos, window: int):
    """q_pos: (B, Sq), k_pos: (B, Sk) -> (B, 1, Sq, Sk) bool (True=keep)."""
    dq = q_pos[:, None, :, None]
    dk = k_pos[:, None, None, :]
    keep = dk <= dq
    if window:
        keep = keep & (dk > dq - window)
    return keep


def attention(p, x, dims: AttnDims, *, positions, kv_positions=None,
              policy=ArithmeticPolicy(), qk_norm=False, rope_theta=1e4,
              window=0, norm_eps=1e-6, cache=None, cache_index=None):
    """GQA attention. x: (B, S, D).

    cache: optional dict {"k","v"}: (B, Smax, KV, Dh); cache_index: scalar
    write offset (decode). Returns (out, new_cache_kv or None).
    """
    b, s, _ = x.shape
    h, kv, hd = dims.n_heads, dims.n_kv_heads, dims.head_dim
    qh = mm(x, p["wq"], policy).reshape(b, s, h, hd)
    kh = mm(x, p["wk"], policy).reshape(b, s, kv, hd)
    vh = mm(x, p["wv"], policy).reshape(b, s, kv, hd)
    if qk_norm:
        qh = headwise_rmsnorm(p["q_norm"], qh, norm_eps)
        kh = headwise_rmsnorm(p["k_norm"], kh, norm_eps)
    qh = apply_rope(qh, positions, rope_theta)
    kh = apply_rope(kh, positions, rope_theta)
    if cache is None:
        # in-sequence attention: when the q-head count doesn't divide the
        # TP degree, pin q/k/v to one seq-sharded layout so the score
        # einsum stays device-local (§Perf H2). Divisible archs keep
        # GSPMD's own (good) placement; cached decode keeps split-KV.
        qh = attention_heads_constraint(qh, h)
        kh = attention_heads_constraint(kh, h)
        vh = attention_heads_constraint(vh, h)

    new_kv = None
    if cache is not None:
        smax = cache["k"].shape[1]
        if s >= smax:
            # prefill longer than the cache ring (zamba2 sliding-window
            # buffers): attend in-sequence — the window mask handles
            # causality — and store only the LAST smax tokens
            new_kv = {"k": kh[:, -smax:].astype(cache["k"].dtype),
                      "v": vh[:, -smax:].astype(cache["v"].dtype)}
            kv_positions = None
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], kh.astype(cache["k"].dtype),
                (0, cache_index, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], vh.astype(cache["v"].dtype),
                (0, cache_index, 0, 0))
            kh, vh = ck.astype(x.dtype), cv.astype(x.dtype)
            new_kv = {"k": ck, "v": cv}
            if kv_positions is None:
                kv_positions = jnp.broadcast_to(
                    jnp.arange(ck.shape[1], dtype=jnp.int32)[None, :],
                    (b, ck.shape[1]))
    if kv_positions is None:
        kv_positions = positions

    g = h // kv
    qg = qh.reshape(b, s, kv, g, hd)
    scores = qeinsum("bskgd,btkd->bkgst", qg, kh, policy)
    scores = scores.astype(jnp.float32) * (hd ** -0.5)
    mask = _causal_mask(positions, kv_positions, window)      # (B,1,Sq,Sk)
    scores = jnp.where(mask[:, :, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = qeinsum("bkgst,btkd->bskgd", probs, vh, policy)
    ctx = ctx.reshape(b, s, h * hd)
    return mm(ctx, p["wo"], policy), new_kv


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
         "relu2": lambda x: jnp.square(jax.nn.relu(x))}


def ffn_init(key, d_model: int, d_ff: int, glu: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d_model, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d_model, dtype)}
    if glu:
        p["w_gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def ffn(p, x, act: str, glu: bool, policy=ArithmeticPolicy()):
    up = mm(x, p["w_up"], policy)
    if glu:
        up = _ACTS[act](mm(x, p["w_gate"], policy)) * up
    else:
        up = _ACTS[act](up)
    return mm(up, p["w_down"], policy)
