"""Mamba2 (SSD — state-space duality) layer, chunked-parallel form.

Used as the backbone of the zamba2 hybrid. The chunked algorithm (Dao &
Gu 2024, Alg. 1) maps onto TPU as dense per-chunk einsums plus a
lax.scan over chunks carrying the (H, N, P) state — sub-quadratic in
sequence length and MXU-friendly (the per-chunk (L, L) score matrices are
plain matmuls).

Per layer:
  in_proj   d -> [z (di), x (di), B (N), C (N), dt (H)]
  conv1d    causal depthwise width-4 over (x | B | C)
  SSD       y_t = C_t . S_t,  S_t = exp(dt_t A) S_{t-1} + B_t (dt_t x_t)^T
  gate      RMSNorm(y * silu(z)) -> out_proj

`policy.apply_to_state` gates SC arithmetic inside the recurrence; by
default only in_proj/out_proj go through the ARTEMIS ladder (recurrent
error accumulation violates the 20-acc independence premise — DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import layers as L
from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                     jnp.float32)
                   * (1.0 / cfg.conv_width) ** 0.5).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "dt_bias": jnp.full((h,), -3.0, dtype),   # softplus^-1(~0.05)
        "D": jnp.ones((h,), dtype),
        "norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.dense_init(ks[2], di, d, dtype),
    }


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Decode carry for ONE layer: SSD state + conv tail."""
    h, n, p = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_ch = cfg.d_inner + 2 * n
    return {
        "ssd": jnp.zeros((batch, h, n, p), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
    }


# ---------------------------------------------------------------------------
# chunked SSD scan
# ---------------------------------------------------------------------------


def _ssd_chunked(xbar, bmat, cmat, log_a, s0, chunk: int):
    """xbar: (B,S,H,P) = dt*x;  bmat/cmat: (B,S,N);  log_a: (B,S,H) <= 0.

    Returns (y: (B,S,H,P), s_final: (B,H,N,P)). Exact chunked evaluation
    of  S_t = a_t S_{t-1} + B_t xbar_t^T,  y_t = C_t . S_t.
    """
    b, s, h, p = xbar.shape
    n = bmat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    nc = xbar.shape[1] // chunk
    xbar = xbar.reshape(b, nc, chunk, h, p)
    bmat = bmat.reshape(b, nc, chunk, n)
    cmat = cmat.reshape(b, nc, chunk, n)
    log_a = log_a.reshape(b, nc, chunk, h)

    cum = jnp.cumsum(log_a, axis=2)                       # inclusive
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))        # s<=t keep

    def body(state, xs):
        xb, bm, cm, cu = xs                               # per-chunk
        # intra-chunk: W[t,m,h] = (C_t.B_m) exp(cu_t - cu_m), m<=t
        scores = jnp.einsum("bln,bmn->blm", cm, bm)
        # clamp the exponent to <= 0: upper-triangle (masked) entries would
        # overflow exp and poison the backward pass with 0 * inf = NaN
        decay = jnp.exp(jnp.minimum(cu[:, :, None, :] - cu[:, None, :, :],
                                    0.0))
        w = scores[..., None] * jnp.where(tri[None, :, :, None], decay, 0.0)
        y = jnp.einsum("blmh,bmhp->blhp", w, xb)
        # inter-chunk: y_t += C_t . (exp(cu_t) S0)
        y = y + jnp.einsum("bln,bhnp,blh->blhp", cm, state, jnp.exp(cu))
        # state update: S' = exp(cu_L) S0 + sum_m exp(cu_L - cu_m) B_m xb_m
        dlast = jnp.exp(cu[:, -1, None, :] - cu)          # (B,L,H)
        snew = state * jnp.exp(cu[:, -1, :])[:, :, None, None] \
            + jnp.einsum("bmn,bmhp,bmh->bhnp", bm, xb, dlast)
        return snew, y

    xs = (jnp.moveaxis(xbar, 1, 0), jnp.moveaxis(bmat, 1, 0),
          jnp.moveaxis(cmat, 1, 0), jnp.moveaxis(cum, 1, 0))
    s_final, ys = jax.lax.scan(body, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, h, p)
    return y[:, :s], s_final


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv. x: (B,S,C); w: (W,C); tail: (B,W-1,C)."""
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(width))
    new_tail = xp[:, -(width - 1):] if width > 1 else tail
    return jax.nn.silu(out + b[None, None, :]), new_tail


# ---------------------------------------------------------------------------
# layer forward
# ---------------------------------------------------------------------------


def mamba2_layer(p, x, cfg: ModelConfig, policy=ArithmeticPolicy(),
                 state=None):
    """x: (B, S, d). state: init_state(...) pytree or None.

    Returns (out (B, S, d), new_state or None). With S == 1 and a state
    this is the O(1) decode step (chunked path degenerates correctly).
    """
    b, s, d = x.shape
    di, n, h, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    proj = L.mm(x, p["in_proj"], policy)
    z, xi, bm, cm, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n], axis=-1)

    conv_in = jnp.concatenate([xi, bm, cm], axis=-1)
    tail = state["conv"] if state is not None else None
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], tail)
    xi, bm, cm = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    log_decay = dt * a[None, None, :]                          # <= 0
    xh = xi.reshape(b, s, h, hp).astype(jnp.float32)
    xbar = xh * dt[..., None]
    bm32, cm32 = bm.astype(jnp.float32), cm.astype(jnp.float32)

    s0 = (state["ssd"].astype(jnp.float32) if state is not None
          else jnp.zeros((b, h, n, hp), jnp.float32))
    y, s_final = _ssd_chunked(xbar, bm32, cm32, log_decay, s0,
                              min(cfg.chunk_size, max(s, 1)))
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.mm(y, p["out_proj"], policy)

    new_state = None
    if state is not None:
        new_state = {"ssd": s_final.astype(state["ssd"].dtype),
                     "conv": new_tail.astype(state["conv"].dtype)}
    return out, new_state
