"""ModelConfig — one dataclass covering every assigned architecture family.

Families:
  dense   — decoder-only transformer (GQA/MQA, GLU or plain FFN)
  moe     — dense backbone with the FFN replaced by a routed MoE layer
  rwkv6   — attention-free RWKV-6 "Finch" (data-dependent decay)
  zamba2  — Mamba2 (SSD) backbone + a shared transformer block applied
            every `shared_attn_period` layers

Modalities ("text" | "vlm" | "audio") only change the input plumbing:
vlm prepends precomputed patch embeddings (frontend stub per the brief),
audio consumes `n_codebooks` parallel EnCodec token streams.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | rwkv6 | zamba2
    n_layers: int
    d_model: int
    vocab_size: int
    modality: str = "text"         # text | vlm | audio
    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    attn_window: int = 0           # 0 = full causal; >0 = sliding window
    # --- FFN ---
    d_ff: int = 0
    act: str = "silu"              # silu | gelu | relu
    glu: bool = True               # gated (SwiGLU/GeGLU) vs plain 2-layer MLP
    # --- norm / embed ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)
    vocab_round_to: int = 128      # pad vocab so the TP axis divides it
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    expert_round_to: int = 0       # pad expert count to a TP multiple
    router_aux_weight: float = 0.01
    # --- SSM (mamba2 within zamba2; rwkv6) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    chunk_size: int = 128          # chunked-scan block length
    # --- zamba2 hybrid ---
    shared_attn_period: int = 0    # shared block every k mamba layers
    # --- audio ---
    n_codebooks: int = 0
    # --- numerics ---
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"

    def __post_init__(self):
        if self.family not in ("dense", "moe", "rwkv6", "zamba2"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family in ("dense", "moe") and self.n_heads == 0:
            raise ValueError(f"{self.name}: attention family needs n_heads")
        if self.family == "moe" and not (self.n_experts and self.top_k):
            raise ValueError(f"{self.name}: moe family needs experts/top_k")

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        r = self.vocab_round_to or 1
        return -(-self.vocab_size // r) * r

    @property
    def padded_experts(self) -> int:
        r = self.expert_round_to or 1
        return -(-self.n_experts // r) * r

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, v = self.d_model, self.padded_vocab
        n = v * d if self.tie_embeddings else 2 * v * d
        if self.family in ("dense", "moe"):
            hd = self.resolved_head_dim
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
            if self.family == "dense":
                ffn = d * self.d_ff * (3 if self.glu else 2)
            else:
                e = d * self.d_ff_expert * (3 if self.glu else 2)
                ffn = (self.n_experts + self.n_shared_experts) * e + \
                    d * self.n_experts
            n += self.n_layers * (attn + ffn + 2 * d)
        elif self.family == "rwkv6":
            per = 4 * d * d + 2 * d * self.d_ff + 13 * d  # approx
            n += self.n_layers * per
        elif self.family == "zamba2":
            di = self.d_inner
            g = 1  # B/C groups
            per = d * (2 * di + 2 * g * self.ssm_state + self.ssm_heads) \
                + di * d + 2 * d
            n += self.n_layers * per
            if self.shared_attn_period:
                hd = self.resolved_head_dim
                n += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d + 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Per-token active params (= total except for MoE routed experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        e = d * self.d_ff_expert * (3 if self.glu else 2)
        inactive = (self.n_experts - self.top_k) * e * self.n_layers
        return self.param_count() - inactive
