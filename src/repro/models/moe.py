"""Mixture-of-Experts layer: top-k routing, grouped two-level dispatch.

Design (DESIGN.md §8 + EXPERIMENTS.md §Perf H4): dispatch must lower to
static shapes AND emit an all-to-all (not a replicated scatter) under
pjit at dbrx scale. The GShard one-hot (T, E, C) einsum is out (C·E·T
blow-up); a single global argsort over (T·k,) serializes and made GSPMD
reshard token buffers with ~150 GB/step of collective-permute at the
qwen2-moe train cell. Instead, dispatch is HIERARCHICAL:

  1. tokens are viewed as (G, T/G, d), G = data-parallel group count
     (from the sharding context; 1 outside any mesh) — each group's
     tokens already live on its devices;
  2. router logits (fp32, exact — routing is the most truncation-
     sensitive op; policy.apply_to_router gates SC here) -> top_k;
  3. PER-GROUP stable sort by expert id + capacity C_g = C/G slots;
     drops are per-group (GShard-style local capacity — the standard
     large-scale behavior);
  4. scatter into the group's (E, C_g, d) buffer — all indices are
     group-local so the scatter itself never crosses devices;
  5. one sharding constraint flips (G, E, C_g, d): P(dp,...) ->
     (E, G, C_g, d): P(ep,...) — THE all-to-all, sized exactly
     T·k·d (the information-theoretic minimum);
  6. batched expert FFN over (E, G·C_g, d), E sharded on the expert
     axis; 7. inverse all-to-all; 8. combine with gate weights
     (+ shared experts, always-on).

Aux load-balance loss (Switch-style) is returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.context import sharding_ctx
from repro.parallel.sharding import batch_axes, moe_dispatch_specs, named


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    e = cfg.padded_experts
    ks = jax.random.split(key, 3)
    expert_keys = jax.random.split(ks[0], e)
    experts = jax.vmap(
        lambda k: L.ffn_init(k, cfg.d_model, cfg.d_ff_expert, cfg.glu, dtype)
    )(expert_keys)
    p = {"router": L.dense_init(ks[1], cfg.d_model, e, dtype),
         "experts": experts}
    if cfg.n_shared_experts:
        shared_keys = jax.random.split(ks[2], cfg.n_shared_experts)
        p["shared"] = jax.vmap(
            lambda k: L.ffn_init(k, cfg.d_model, cfg.d_ff_expert, cfg.glu,
                                 dtype)
        )(shared_keys)
    return p


def _expert_ffn(expert_params, xs, cfg: ModelConfig, policy):
    """xs: (E, C, d); expert_params leaves lead with E."""
    def one(p, x):
        return L.ffn(p, x, cfg.act, cfg.glu, policy)
    return jax.vmap(one)(expert_params, xs)


def _mesh_groups():
    """(n_groups, mesh, dp_axes, ep_axis) from the sharding context."""
    ctx = sharding_ctx()
    if ctx is None:
        return 1, None, None, None
    mesh, rules = ctx
    bax = batch_axes(mesh)
    g = 1
    axes = bax if isinstance(bax, tuple) else ((bax,) if bax else ())
    for a in axes:
        g *= mesh.shape[a]
    return g, mesh, axes, rules.expert_axis


def _constrain(x, mesh, spec):
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, named(mesh, spec))


def moe_ffn(p, x, cfg: ModelConfig, policy=ArithmeticPolicy()):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.padded_experts, cfg.top_k
    g_mesh, mesh, dp_axes, ep_axis = _mesh_groups()
    # groups must divide tokens; degenerate cells (tiny batches) fall back
    g = g_mesh if (g_mesh and t % g_mesh == 0 and b % g_mesh == 0) else 1
    tg = t // g
    dp_spec = dp_axes if (dp_axes and len(dp_axes) > 1) else (
        dp_axes[0] if dp_axes else None)
    specs = moe_dispatch_specs(dp_spec, ep_axis)

    xt = x.reshape(g, tg, d)
    xt = _constrain(xt, mesh, specs["tokens"])

    # --- routing (exact fp32 unless the policy opts the router in) -------
    rpol = policy if policy.apply_to_router else ArithmeticPolicy(mode="exact")
    logits = L.mm(xt.astype(jnp.float32), p["router"].astype(jnp.float32),
                  rpol)                                   # (G, Tg, E)
    if cfg.padded_experts != cfg.n_experts:               # mask pad experts
        pad_mask = jnp.arange(e) < cfg.n_experts
        logits = jnp.where(pad_mask[None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                   # (G, Tg, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- aux load-balance loss (Switch eq. 4) ----------------------------
    density = jnp.mean(jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32),
                       axis=(0, 1))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_weight * e * jnp.sum(density * mean_probs)

    # --- per-group sort-based dispatch (device-local) ---------------------
    cap = max(int(cfg.capacity_factor * tg * k / e), 1)
    flat_ids = ids.reshape(g, tg * k)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)    # (G, Tg*k)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    seg_start = jax.vmap(
        lambda sid: jnp.searchsorted(sid, jnp.arange(e), side="left")
    )(sorted_ids)                                          # (G, E)
    slot = jnp.arange(tg * k)[None, :] \
        - jnp.take_along_axis(seg_start, sorted_ids, axis=-1)
    keep = slot < cap
    dest = jnp.where(keep, sorted_ids * cap + slot, e * cap)

    src_token = order // k                                 # (G, Tg*k)
    buf = jnp.zeros((g, e * cap, d), x.dtype)
    buf = jax.vmap(lambda bb, dd, ss, xx: bb.at[dd].set(xx[ss],
                                                        mode="drop"))(
        buf, dest, src_token, xt)
    buf = buf.reshape(g, e, cap, d)
    buf = _constrain(buf, mesh, specs["buffers"])

    # --- THE all-to-all: (G, E, C, d) dp-sharded -> (E, G, C, d) EP ------
    # E flips dp->ep while G KEEPS its dp sharding: each device then holds
    # (E/ep, G/dp, C, d) — its own experts x its own token groups
    bufT = jnp.swapaxes(buf, 0, 1)                        # (E, G, C, d)
    bufT = _constrain(bufT, mesh, specs["expert"])

    out_e = _expert_ffn(p["experts"], bufT.reshape(e, g * cap, d), cfg,
                        policy)
    out_e = _constrain(out_e.reshape(e, g, cap, d), mesh,
                       specs["expert"])

    # --- inverse all-to-all + combine --------------------------------------
    out_g = jnp.swapaxes(out_e, 0, 1).reshape(g, e * cap, d)
    out_g = _constrain(out_g, mesh, specs["tokens"])
    copy_out = jax.vmap(lambda oo, dd: oo.at[dd, :].get(
        mode="fill", fill_value=0))(out_g, dest)
    copy_out = jnp.where(keep[..., None], copy_out, 0)
    w = jnp.take_along_axis(gate.reshape(g, tg * k), order, axis=-1)
    combined = jax.vmap(lambda st, co, ww: jnp.zeros(
        (tg, d), x.dtype).at[st].add(co * ww[:, None].astype(x.dtype)))(
        src_token, copy_out, w)
    combined = _constrain(combined, mesh, specs["tokens"])

    # --- shared experts (always active) ------------------------------------
    if cfg.n_shared_experts:
        def one(sp):
            return L.ffn(sp, xt.reshape(t, d), cfg.act, cfg.glu, policy)
        shared = jax.vmap(one)(p["shared"])               # (Ns, T, d)
        combined = combined.reshape(t, d) + jnp.sum(shared, axis=0)

    return combined.reshape(b, s, d), aux
