"""Decoder-only transformer (dense + MoE families), scan-over-layers.

Params layout (all leaves `cfg.param_dtype`):
  embed       (V, d)            audio: (n_codebooks, V, d)
  layers      per-layer pytree stacked on a leading L axis (lax.scan)
  final_norm  rmsnorm
  head        (d, V)            audio: (n_codebooks, d, V); absent if tied

KV cache layout (decode): {"k"/"v": (L, B, Smax, KV, Dh), "index": i32[]}.
`apply` is the single forward entry point — training (no cache), prefill
(cache, index 0) and decode (cache, S==1) all route through it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import layers as L
from repro.models import moe as M
from repro.models.config import ModelConfig
from repro.parallel.context import activation_constraint


def _dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)


def _layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.attn_init(ks[0], cfg.d_model, _dims(cfg), cfg.qk_norm,
                            dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = M.moe_init(ks[1], cfg, dtype)
    else:
        p["ffn"] = L.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.glu, dtype)
    return p


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    v, d = cfg.padded_vocab, cfg.d_model
    if cfg.modality == "audio":
        embed = jax.vmap(lambda k: L.embed_init(k, v, d, dtype))(
            jax.random.split(ks[0], cfg.n_codebooks))
    else:
        embed = L.embed_init(ks[0], v, d, dtype)
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg, dtype))(layer_keys)
    params = {"embed": embed, "layers": layers,
              "final_norm": L.rmsnorm_init(d, dtype)}
    if not cfg.tie_embeddings:
        if cfg.modality == "audio":
            params["head"] = jax.vmap(
                lambda k: L.dense_init(k, d, v, dtype))(
                jax.random.split(ks[2], cfg.n_codebooks))
        else:
            params["head"] = L.dense_init(ks[2], d, v, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.zeros((), jnp.int32)}


def _embed_tokens(params, cfg: ModelConfig, tokens, dtype):
    if cfg.modality == "audio":
        # tokens: (B, S, n_codebooks); sum codebook embeddings
        x = jnp.sum(jax.vmap(
            lambda e, t: e[t], in_axes=(0, 2), out_axes=0
        )(params["embed"], tokens), axis=0)
    else:
        x = params["embed"][tokens]
    x = x.astype(dtype)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return x


def _logits(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        w = params["embed"]
        if cfg.modality == "audio":
            return jnp.einsum("bsd,cvd->bscv", x, w.astype(x.dtype))
        return jnp.matmul(x, w.astype(x.dtype).T)
    if cfg.modality == "audio":
        return jnp.einsum("bsd,cdv->bscv", x, params["head"].astype(x.dtype))
    return jnp.matmul(x, params["head"].astype(x.dtype))


def _block(lp, x, cfg: ModelConfig, policy, positions, kv_positions,
           cache_kv, cache_index, window):
    h, new_kv = L.attention(
        lp["attn"], L.rmsnorm(lp["ln1"], x, cfg.norm_eps), _dims(cfg),
        positions=positions, kv_positions=kv_positions, policy=policy,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta, window=window,
        norm_eps=cfg.norm_eps, cache=cache_kv, cache_index=cache_index)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        f, aux = M.moe_ffn(lp["moe"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps),
                           cfg, policy)
    else:
        f = L.ffn(lp["ffn"], L.rmsnorm(lp["ln2"], x, cfg.norm_eps),
                  cfg.act, cfg.glu, policy)
    return x + f, aux, new_kv


def apply(params, cfg: ModelConfig, inputs: dict, *,
          policy: ArithmeticPolicy = ArithmeticPolicy(),
          cache: dict | None = None, remat: bool = True,
          unroll: int | bool = 1):
    """Forward pass.

    inputs: {"tokens": (B,S) i32 [audio: (B,S,C)],
             optional "prefix_embeds": (B,P,d) (vlm frontend stub),
             optional "positions": (B,S)}
    unroll: layer-scan unroll factor (True = full). The dry-run lowers
    with full unroll so `cost_analysis()` counts every layer (XLA counts
    a while-loop body ONCE regardless of trip count — verified; see
    EXPERIMENTS.md §Dry-run methodology).
    Returns (logits, aux_loss, new_cache).
    """
    dtype = jnp.dtype(cfg.compute_dtype)
    tokens = inputs["tokens"]
    x = _embed_tokens(params, cfg, tokens, dtype)
    if "prefix_embeds" in inputs and inputs["prefix_embeds"] is not None:
        x = jnp.concatenate(
            [inputs["prefix_embeds"].astype(dtype), x], axis=1)
    b, s, _ = x.shape

    index = cache["index"] if cache is not None else jnp.zeros((), jnp.int32)
    positions = inputs.get("positions")
    if positions is None:
        positions = index + jnp.arange(s, dtype=jnp.int32)[None, :]
        positions = jnp.broadcast_to(positions, (b, s))
    kv_positions = None
    if cache is not None:
        smax = cache["k"].shape[2]
        kv_positions = jnp.broadcast_to(
            jnp.arange(smax, dtype=jnp.int32)[None, :], (b, smax))
        # mask out cache slots not yet written
        kv_positions = jnp.where(kv_positions <= jnp.max(positions),
                                 kv_positions, jnp.iinfo(jnp.int32).max)

    window = cfg.attn_window

    def body(carry, lp):
        # the FULL stacked KV cache travels in the carry and is updated
        # in place per layer (dynamic_update_index) — with donated inputs
        # XLA aliases the buffer end-to-end, vs ys-stacking which
        # re-materializes the whole cache every step (§Perf H5)
        x, aux, ck, cv, li = carry
        ckv = None
        if cache is not None:
            ckv = {"k": jax.lax.dynamic_index_in_dim(ck, li, 0, False),
                   "v": jax.lax.dynamic_index_in_dim(cv, li, 0, False)}
        x, a, new_kv = _block(lp, x, cfg, policy, positions, kv_positions,
                              ckv, index, window)
        x = activation_constraint(x, "resid")
        if cache is not None:
            ck = jax.lax.dynamic_update_index_in_dim(ck, new_kv["k"], li, 0)
            cv = jax.lax.dynamic_update_index_in_dim(cv, new_kv["v"], li, 0)
        return (x, aux + a, ck, cv, li + 1), None

    scan_body = jax.checkpoint(body) if remat else body
    x = activation_constraint(x, "resid")
    if cache is not None:
        ck0, cv0 = cache["k"], cache["v"]
    else:
        ck0 = cv0 = jnp.zeros((), jnp.bfloat16)  # unused placeholder
    (x, aux, ck, cv, _), _ = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32), ck0, cv0,
                    jnp.zeros((), jnp.int32)),
        params["layers"], unroll=unroll)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    logits = activation_constraint(logits, "logits")

    new_cache = None
    if cache is not None:
        new_cache = {"k": ck, "v": cv, "index": index + s}
    return logits, aux, new_cache
