"""Model factory — one `init`/`apply`/`init_cache` surface over all families.

  init(key, cfg)                      -> params pytree
  apply(params, cfg, inputs, ...)     -> (logits, aux_loss, new_cache)
  init_cache(cfg, batch, max_len)     -> decode carry (KV / SSM state)
  lm_loss(logits, labels, mask)       -> mean token cross-entropy

inputs: {"tokens": (B,S) i32 [audio: (B,S,C)],
         optional "prefix_embeds" (vlm), optional "positions"}.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.policy import ArithmeticPolicy
from repro.models import rwkv6, transformer, zamba2
from repro.models.config import ModelConfig

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "rwkv6": rwkv6,
    "zamba2": zamba2,
}


def _mod(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init(key, cfg: ModelConfig):
    return _mod(cfg).init(key, cfg)


def apply(params, cfg: ModelConfig, inputs: dict, *,
          policy: ArithmeticPolicy = ArithmeticPolicy(),
          cache: dict | None = None, remat: bool = True,
          unroll: int | bool = 1):
    return _mod(cfg).apply(params, cfg, inputs, policy=policy, cache=cache,
                           remat=remat, unroll=unroll)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    if cfg.family == "rwkv6":
        return rwkv6.init_cache(cfg, batch, max_len, jnp.float32)
    if cfg.family == "zamba2":
        return zamba2.init_cache(cfg, batch, max_len, dtype)
    return transformer.init_cache(cfg, batch, max_len, dtype)


def lm_loss(logits: jax.Array, labels: jax.Array,
            mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy, tensor-parallel-safe.

    logits: (B, S, V) [audio: (B, S, C, V)]; labels: same minus V, i32.
    mask: optional (B, S) weights.

    Written as logsumexp - <logits, one_hot> rather than
    log_softmax + take_along_axis: reductions and the one-hot contraction
    both shard cleanly over a vocab-TP'd logits dim, whereas the gather
    forces GSPMD to replicate the full fp32 (B, S, V) tensor — measured
    at ~650 GB/device of all-reduce per step on the 151k-vocab archs
    (EXPERIMENTS.md §Perf H1).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if nll.ndim == 3:  # audio: mean over codebooks
        nll = jnp.mean(nll, axis=-1)
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree.leaves(params))
