"""Published baseline anchors for Figs 9-11 (paper §IV.D).

The paper compares against CPU / GPU / TPU / FPGA_ACC [40] / TransPIM [9]
/ ReBERT [11] / HAIMA [10], using "power, latency, and energy values
reported for the selected accelerators" — i.e. published numbers, not
re-simulations. We anchor the same way: each platform is stored as its
paper-reported average factor vs ARTEMIS (speedup = ARTEMIS_speedup /
platform_speedup, both CPU-relative).

ARTEMIS-relative averages from §IV.D (speedup / energy / efficiency):
  CPU      1230x   1443.3x   1269.0x
  GPU       157x    700.4x    673.6x
  TPU       212x   1000.4x    950.2x
  FPGA_ACC 29.6x      8.8x      8.5x
  TransPIM  4.8x      3.5x      3.3x
  ReBERT   11.9x      1.8x      1.9x   (BERT-family models only)
  HAIMA     3.6x      6.2x      5.9x
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Baseline:
    name: str
    speedup_vs: float    # ARTEMIS speedup over this platform (avg)
    energy_vs: float     # ARTEMIS energy advantage (avg)
    efficiency_vs: float  # ARTEMIS GOPS/W advantage (avg)
    bert_only: bool = False


BASELINES = {
    "CPU": Baseline("CPU", 1230.0, 1443.3, 1269.0),
    "GPU": Baseline("GPU", 157.0, 700.4, 673.6),
    "TPU": Baseline("TPU", 212.0, 1000.4, 950.2),
    "FPGA_ACC": Baseline("FPGA_ACC", 29.6, 8.8, 8.5),
    "TransPIM": Baseline("TransPIM", 4.8, 3.5, 3.3),
    "ReBERT": Baseline("ReBERT", 11.9, 1.8, 1.9, bert_only=True),
    "HAIMA": Baseline("HAIMA", 3.6, 6.2, 5.9),
}

# the paper's headline claim (abstract): vs the best competitor in each
# metric, ARTEMIS achieves AT LEAST these factors
HEADLINE = {"speedup": 3.0, "energy": 1.8, "efficiency": 1.9}
