"""Paper Table II workloads in hwsim form."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    params: float
    n_layers: int
    n_tokens: int
    n_heads: int
    d_model: int
    d_ff: int
    decoder: bool = False   # encoder-decoder (transformer-base) vs enc-only


# paper Table II
_MODELS = {
    "transformer_base": Workload("transformer-base", 52e6, 2, 128, 8, 512,
                                 2048, decoder=True),
    "bert_base": Workload("bert-base", 108e6, 12, 128, 12, 768, 3072),
    "albert_base": Workload("albert-base", 12e6, 12, 128, 12, 768, 3072),
    "vit_base": Workload("vit-base", 86e6, 12, 256, 12, 768, 3072),
    "opt_350": Workload("opt-350", 350e6, 12, 2048, 12, 768, 3072),
}


def paper_models() -> dict:
    return dict(_MODELS)
