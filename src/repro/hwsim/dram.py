"""DRAM geometry helpers — what fits where, and how fast it moves.

Maps matrix work onto the ARTEMIS hierarchy:
  stack > channel > bank > subarray (128/bank, half active) > tile (32).

Throughput primitives (all per the paper's §III):
  * A tile holds two 128-bit operand rows + computational rows; processes
    2 multiplies at a time; 40 MACs per readout round via 2 MOMCAPs.
  * A subarray = 32 tiles -> 64 concurrent MACs; the paper's headline
    "64 MACs in 48 ns per subarray".
  * A bank = 64 active subarrays -> 4096 concurrent MACs.
  * Banks run independently (token parallelism); the shared intra-channel
    bus serializes inter-bank transfers (ring + broadcast, §III.D.1).
"""
from __future__ import annotations

import dataclasses

from repro.hwsim.constants import ArtemisConfig


@dataclasses.dataclass(frozen=True)
class DramGeometry:
    cfg: ArtemisConfig

    @property
    def macs_per_subarray(self) -> int:
        """Concurrent MACs per subarray (2 per tile x 32 tiles)."""
        return 2 * self.cfg.tiles_per_subarray

    @property
    def macs_per_bank(self) -> int:
        return self.macs_per_subarray * self.cfg.active_subarrays_per_bank

    @property
    def total_concurrent_macs(self) -> int:
        return self.macs_per_bank * self.cfg.n_banks

    def mac_round_latency_ns(self) -> float:
        """One 40-MAC accumulation round in a tile: 40 sequential SC
        multiplies (2 MOCs each, tiles pipelined two-at-a-time) + the
        A_to_B readout. Matches the paper's 64 MACs / 48 ns per-subarray
        number when amortized across the 32 tiles' parallel operation."""
        c = self.cfg
        t_mults = c.momcap_depth * c.t_mul_ns / c.caps_per_tile
        return t_mults + c.t_s_to_b_ns

    def dot_product_latency_ns(self, k: int) -> float:
        """Latency of one length-k dot product mapped across tiles
        (paper Fig 5(a)): ceil(k / 40) rounds + the NSC reduction tree."""
        c = self.cfg
        rounds = -(-k // self.cfg.momcap_depth) / c.caps_per_tile
        t_reduce = (c.t_latch_ps + c.t_addsub_ps) / 1000.0 * 2
        return rounds * self.mac_round_latency_ns() + t_reduce

    def matmul_macs(self, m: int, k: int, n: int) -> int:
        return m * k * n

    def matmul_latency_ns(self, m: int, k: int, n: int,
                          banks: int | None = None) -> float:
        """Blocked matmul latency on `banks` banks (default: all)."""
        banks = banks or self.cfg.n_banks
        total = self.matmul_macs(m, k, n)
        per_round = banks * self.macs_per_bank * self.cfg.momcap_depth \
            * self.cfg.caps_per_tile
        rounds = -(-total // per_round)
        return rounds * self.mac_round_latency_ns()

    # -- energy -------------------------------------------------------------
    def mac_energy_pj(self, n_macs: int) -> float:
        """SC MAC energy: 2 MOCs (operand copies) per multiply, amortized
        over the bank-wide activation. As in Ambit/DRISA-style in-DRAM
        compute, one ACTIVATE command drives one row in EVERY active
        subarray of the bank simultaneously (e_act is per bank-level
        ACTIVATE, Table I), so an activate pair feeds
        active_subarrays x tiles x 2 concurrent products
        (= 64 x 32 x 2 = 4096). This is what keeps ARTEMIS inside its
        60 W budget (sanity check in tests/test_hwsim.py)."""
        c = self.cfg
        macs_per_act_pair = (c.active_subarrays_per_bank
                             * c.tiles_per_subarray * 2)
        return 2.0 * c.e_act_pj * n_macs / macs_per_act_pair

    def transfer_energy_pj(self, bits: int, hops: int = 1) -> float:
        """Inter-bank transfer over the shared bus (binary format)."""
        c = self.cfg
        return bits * (c.e_pre_gsa_pj_b + c.e_post_gsa_pj_b) * hops

    def transfer_latency_ns(self, bits: int) -> float:
        return bits * self.cfg.t_link_ns_per_bit
