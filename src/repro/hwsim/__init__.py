"""repro.hwsim — the paper's evaluation methodology, reimplemented.

ARTEMIS §IV: "We developed a comprehensive simulator in Python to estimate
the performance and energy costs of our proposed accelerator by accurately
modeling all hardware components and in-DRAM operations." This package IS
that simulator: device constants from Tables I/III, the DRAM geometry,
per-operation latency/energy models, the layer/token dataflow × pipelining
execution model, and published baseline anchors for Figs 9-11.
"""
from repro.hwsim.constants import (
    ArtemisConfig,
    DEFAULT,
    DRISA_CONFIG,
)
from repro.hwsim.dram import DramGeometry
from repro.hwsim.dataflow import (
    DataflowConfig,
    simulate_model,
    simulate_breakdown,
)
from repro.hwsim.workloads import paper_models
from repro.hwsim.baselines import BASELINES

__all__ = ["ArtemisConfig", "DEFAULT", "DRISA_CONFIG", "DramGeometry",
           "DataflowConfig", "simulate_model", "simulate_breakdown",
           "paper_models", "BASELINES"]
