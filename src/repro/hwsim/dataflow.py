"""Dataflow × pipelining execution model (paper §III.D, Figs 2/6/8/12).

Simulates one transformer inference on the ARTEMIS HBM under the four
schemes of Fig 8:

  layer_NP  layer-based dataflow, no pipelining (conventional PIM mapping)
  layer_PP  layer-based + execution pipelining
  token_NP  token-based sharding, no pipelining
  token_PP  token-based + pipelining (= ARTEMIS)

Structural differences (paper §III.D.1):
  * layer-based: each layer's weights are RESIDENT in a fixed group of
    banks_per_layer = max(1, K/L) banks; only those banks compute while a
    layer executes (bank under-utilization), and every intermediate
    (activations AND the O(N^2) attention matrices) crosses the single
    shared bus into/out of that group, with operands STAGED into compute
    rows (ACTIVATE-heavy "loading, reorganization" — the >60%-of-time
    data handling the paper cites from [9]).
  * token-based: every bank owns N_b = N/K tokens end-to-end; all banks
    compute concurrently; only K_i/V_i shards travel the ring+broadcast
    network on concurrent neighbor links; attention intermediates stay
    bank-local.
  * pipelining (Fig 6): intra-bank latch/NSC movement hides behind MAC
    rounds; inter-bank transfers overlap the score/SV MatMuls; received
    data feeds B_to_TCU directly (DRAM write-skip, §III.D.3).

Calibrated constants (documented, single source): C_STAGE — ACTIVATE
cycles per staged row for layer-based operand loading/reorganization
(paper reports aggregates only; its own SPICE/CACTI-derived simulator
constants are not all published). Everything else derives from Tables
I/III and §III timing.
"""
from __future__ import annotations

import dataclasses

from repro.hwsim.constants import DRISA_CONFIG, ArtemisConfig, DEFAULT
from repro.hwsim.dram import DramGeometry
from repro.hwsim.workloads import Workload

# ACTIVATE-equivalents per staged row in layer-based operand loading
# (write + restore + reorganization passes). Calibrated once against the
# paper's six Fig-8 aggregates (11.0x/3.5x token-vs-layer, 1.50/1.43
# pipelining speedup, 1.42/1.43 pipelining energy); with these two values
# our aggregates are 13.7x/3.2x and 1.48/1.30, 1.62/1.73 — all within
# ~25% (benchmarks/fig8_dataflow.py records both sides).
C_STAGE = 10.0
# fraction of a layer's MatMul window available to hide inter-bank
# transfers behind (Fig 6: scores + SV + B_to_TCU overlap region)
PP_OVERLAP_FRAC = 0.8


@dataclasses.dataclass(frozen=True)
class DataflowConfig:
    scheme: str = "token_PP"       # layer_NP | layer_PP | token_NP | token_PP
    hw: ArtemisConfig = DEFAULT

    @property
    def token_based(self) -> bool:
        return self.scheme.startswith("token")

    @property
    def pipelined(self) -> bool:
        return self.scheme.endswith("PP")


@dataclasses.dataclass
class SimResult:
    latency_ns: float
    energy_pj: float
    t_matmul: float
    t_softmax: float
    t_nonlinear: float
    t_move: float
    t_other: float
    macs: int = 0

    @property
    def breakdown(self) -> dict:
        tot = max(self.latency_ns, 1e-9)
        return {"matmul": self.t_matmul / tot,
                "softmax": self.t_softmax / tot,
                "nonlinear": self.t_nonlinear / tot,
                "data_movement": self.t_move / tot,
                "other": self.t_other / tot}

    @property
    def gops(self) -> float:
        """Useful GOPS (2 ops per MAC) over the run."""
        return 2.0 * self.macs / max(self.latency_ns, 1e-9)


def _layer_matmul_macs(w: Workload) -> dict:
    n, d, f = w.n_tokens, w.d_model, w.d_ff
    return {
        "qkv": 3 * n * d * d,
        "scores": n * n * d,
        "sv": n * n * d,
        "proj": n * d * d,
        "ffn": n * d * f + n * f * d,
    }


def _matmul_time_ns(geo: DramGeometry, hw: ArtemisConfig, macs: int,
                    banks: int) -> float:
    per_round = (banks * geo.macs_per_bank * hw.momcap_depth
                 * hw.caps_per_tile)
    rounds = -(-macs // per_round)
    return rounds * geo.mac_round_latency_ns()


def simulate_model(w: Workload, df: DataflowConfig = DataflowConfig(),
                   n_stacks: int | None = None) -> SimResult:
    """Full-model inference latency/energy under one dataflow scheme."""
    hw = df.hw if n_stacks is None else dataclasses.replace(
        df.hw, n_stacks=n_stacks)
    geo = DramGeometry(hw)
    k_banks = hw.n_banks
    n, d = w.n_tokens, w.d_model
    bits8 = 8
    layers_eff = int(w.n_layers * (1.5 if w.decoder else 1.0))

    macs = _layer_matmul_macs(w)
    total_macs_layer = sum(macs.values())

    # ---- compute ----------------------------------------------------------
    if df.token_based:
        active_banks = k_banks
    else:
        active_banks = max(1, k_banks // layers_eff)
    t_matmul = _matmul_time_ns(geo, hw, total_macs_layer, active_banks)

    # ---- NSC work ---------------------------------------------------------
    nsc_units = active_banks * hw.active_subarrays_per_bank
    n_softmax_vals = w.n_heads * n * n
    t_softmax = n_softmax_vals * (hw.t_comparator_ps + 2 * hw.t_addsub_ps
                                  + 2 * hw.t_lut_ps) / 1000.0 / nsc_units
    t_nonlinear = (n * w.d_ff) * hw.t_lut_ps / 1000.0 / nsc_units
    t_conv = (n * d) * hw.t_b_to_tcu_ps / 1000.0 / nsc_units

    # ---- data movement ----------------------------------------------------
    e_bus_pj_b = hw.e_pre_gsa_pj_b + hw.e_post_gsa_pj_b + hw.e_io_pj_b
    e_ring_pj_b = hw.e_pre_gsa_pj_b   # short neighbor links, no I/O hop
    if df.token_based:
        n_b = max(n // k_banks, 1)
        shard_bits = n_b * d * bits8
        # K_i then V_i ring broadcast: (K-1) steps, links concurrent
        t_move = 2 * (k_banks - 1) * geo.transfer_latency_ns(shard_bits)
        bit_hops = 2 * (k_banks - 1) * k_banks * shard_bits
        e_move = bit_hops * e_ring_pj_b
        staged_rows = bit_hops / hw.bits_per_row
    else:
        # single shared bus: the layer's PARAMETERS stream into the
        # small compute-bank group ("the large number of model parameters
        # ... leads to significantly high congestion", §III.D.1), plus
        # activations in/out and the O(N^2) attention intermediates.
        # Per-layer weights are the layer shapes (4d^2 attn + 2df FFN),
        # NOT params/L — embeddings never cross per layer.
        weight_bits_layer = (4 * d * d + 2 * d * w.d_ff) * bits8
        bus_bits = (2 * 5 * n * d + 2 * w.n_heads * n * n) * bits8 \
            + weight_bits_layer
        t_move = geo.transfer_latency_ns(bus_bits)   # fully serialized
        e_move = bus_bits * e_bus_pj_b
        staged_rows = bus_bits / hw.bits_per_row

    # operand staging: received/streamed data must reach computation rows.
    # PP feeds B_to_TCU directly -> one computation-row write (already the
    # MAC's copy MOCs for token; C_STAGE/2 reorganization for layer).
    # NP first writes DRAM arrays, later re-activates to read = 2x row ops
    # on top (the "avoided unnecessary write operations" of §III.D.3).
    t_stage = staged_rows * hw.t_moc_ns / max(nsc_units, 1)
    if df.token_based:
        e_stage = 0.0 if df.pipelined else staged_rows * hw.e_act_pj * 2.0
    else:
        c = C_STAGE / 2.0 if df.pipelined else C_STAGE
        e_stage = staged_rows * hw.e_act_pj * c

    # ---- weight capacity / remapping (Fig 12 lever) -----------------------
    capacity_bytes = hw.n_stacks * 8 * 2**30 * 0.5
    weight_bytes = w.params
    remaps = max(1.0, weight_bytes * (k_banks if df.token_based else 1)
                 / max(capacity_bytes, 1))
    t_remap = 0.0
    if remaps > 1.0:
        extra_bits = (remaps - 1.0) * weight_bytes * bits8 / layers_eff
        t_remap = geo.transfer_latency_ns(extra_bits)

    # ---- per-layer roll-up -------------------------------------------------
    # per-MAC-round overhead that pipelining hides (Fig 6): the A_to_B
    # readout, the tile->NSC latch pipeline, the NSC reduction adds and
    # the next round's B_to_TCU operand prep — serialized when NP
    n_rounds = -(-total_macs_layer // (active_banks * geo.macs_per_bank
                                       * hw.momcap_depth
                                       * hw.caps_per_tile))
    per_round_overhead_ns = (
        hw.t_s_to_b_ns
        + hw.tiles_per_subarray * (hw.t_latch_ps + hw.t_addsub_ps
                                   + hw.t_b_to_tcu_ps) / 1000.0)
    t_intra = n_rounds * per_round_overhead_ns
    if df.pipelined:
        overlap = t_matmul * PP_OVERLAP_FRAC
        t_move_exposed = max(0.0, t_move + t_stage - overlap)
        t_softmax_exposed = t_softmax * 0.15  # only the ln+exp tail shows
        t_intra_exposed = 0.0                 # fully hidden behind MACs
        t_conv_exposed = 0.0
    else:
        t_move_exposed = t_move + t_stage
        t_softmax_exposed = t_softmax
        t_intra_exposed = t_intra
        t_conv_exposed = t_conv

    t_layer = (t_matmul + t_softmax_exposed + t_nonlinear
               + t_move_exposed + t_intra_exposed + t_conv_exposed
               + t_remap)
    latency = t_layer * layers_eff

    # ---- energy ------------------------------------------------------------
    e_mac = geo.mac_energy_pj(total_macs_layer)
    e_nsc = (t_softmax + t_nonlinear) * nsc_units \
        * (hw.p_lut_mw + hw.p_comparator_mw) * 1e-3
    energy = (e_mac + e_move + e_stage + e_nsc) * layers_eff

    return SimResult(latency, energy, t_matmul * layers_eff,
                     t_softmax_exposed * layers_eff,
                     t_nonlinear * layers_eff,
                     (t_move_exposed + t_intra_exposed) * layers_eff,
                     (t_conv_exposed + t_remap) * layers_eff,
                     macs=total_macs_layer * layers_eff)


def simulate_breakdown(w: Workload) -> dict:
    """Fig 2: component-wise time on a CONVENTIONAL digital PIM (DRISA):
    1600 ns per MUL, bit-serial adds — >90% of time in MatMuls."""
    dr = DRISA_CONFIG
    hw = DEFAULT
    geo = DramGeometry(hw)
    k_banks = hw.n_banks
    macs = _layer_matmul_macs(w)
    total_macs = sum(macs.values())
    lanes = k_banks * hw.active_subarrays_per_bank \
        * hw.tiles_per_subarray * 2
    t_matmul = total_macs * (dr.t_mul_ns + dr.t_add_ns) / lanes
    nsc_units = k_banks * hw.active_subarrays_per_bank
    n = w.n_tokens
    t_softmax = (w.n_heads * n * n) * 40 * dr.t_moc_ns / nsc_units
    t_nonlinear = (n * w.d_ff) * 8 * dr.t_moc_ns / nsc_units
    bus_bits = (2 * 5 * n * w.d_model + 2 * w.n_heads * n * n) * 8
    t_move = geo.transfer_latency_ns(bus_bits)
    total = t_matmul + t_softmax + t_nonlinear + t_move
    return {"matmul": t_matmul / total, "softmax": t_softmax / total,
            "nonlinear": t_nonlinear / total,
            "data_movement": t_move / total}
