"""ARTEMIS device/circuit constants — paper Tables I & III + §III/§IV text.

Every number is traceable to the paper:
  * Table I: HBM configuration (1 stack, 8 channels, 4 banks/channel,
    128 subarrays/bank, 32 tiles/subarray, 256 rows, 256 bits/row) and
    energies (e_act = 909 pJ, e_pre_gsa = 1.51 pJ/b, e_post_gsa = 1.17
    pJ/b, e_io = 0.80 pJ/b).
  * Table III: per-subarray NSC component latency/power/area.
  * §III/§IV text: 17 ns per MOC; SC multiply = 2 MOCs = 34 ns; S_to_B
    (A_to_B ladder) = 31 ns (vs AGNI's 56 ns); 64 MACs / 48 ns per
    subarray; MOMCAP depth 20 (2 caps -> 40 MACs per operational tile);
    128-bit streams + sign; 60 W power budget; 256-bit inter-bank links;
    256 GB/s per-stack bandwidth; DRISA MUL = 1600 ns (Fig 2 baseline).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArtemisConfig:
    # ---- DRAM geometry (Table I) ----
    n_stacks: int = 1
    channels_per_stack: int = 8
    banks_per_channel: int = 4
    subarrays_per_bank: int = 128
    tiles_per_subarray: int = 32
    rows_per_tile: int = 256
    bits_per_row: int = 256

    # ---- timing (§III / §IV) ----
    t_moc_ns: float = 17.0          # one memory operation cycle
    mul_mocs: int = 2               # SC multiply = 2 MOCs (copy operands)
    t_mul_ns: float = 34.0          # = mul_mocs * t_moc_ns
    t_s_to_b_ns: float = 31.0       # A_to_B ladder (refined from AGNI 56)
    t_macs_64_ns: float = 48.0      # 64 MACs per subarray (§II.E, §IV.D)
    momcap_depth: int = 20          # accumulations per MOMCAP
    caps_per_tile: int = 2          # own + idle neighbour -> 40 MACs
    open_bitline_frac: float = 0.5  # half the subarrays active at a time

    # ---- stochastic representation ----
    sc_bits: int = 128              # 8-bit magnitude -> 128-bit stream
    value_bits: int = 8

    # ---- NSC per-subarray circuits (Table III) ----
    t_s_to_b_circ_ps: float = 20000.0
    t_comparator_ps: float = 623.7
    t_addsub_ps: float = 719.95
    t_lut_ps: float = 222.5
    t_b_to_tcu_ps: float = 530.2
    t_latch_ps: float = 77.7
    p_s_to_b_mw: float = 0.053
    p_comparator_mw: float = 0.055
    p_addsub_mw: float = 0.0028
    p_lut_mw: float = 4.21
    p_b_to_tcu_mw: float = 0.021
    p_latch_mw: float = 0.028

    # ---- energies (Table I) ----
    e_act_pj: float = 909.0         # one row ACTIVATE in one bank
    e_pre_gsa_pj_b: float = 1.51    # row buffer -> global S/As, per bit
    e_post_gsa_pj_b: float = 1.17   # GSAs -> DRAM I/O, per bit
    e_io_pj_b: float = 0.80         # I/O channel, per bit

    # ---- interconnect / system ----
    link_bits: int = 256            # inter-bank link width (§III.D.3)
    stack_bw_gbps: float = 256.0    # HBM per-stack bandwidth (§IV.C)
    power_budget_w: float = 60.0    # §IV

    # -- derived -----------------------------------------------------------
    @property
    def n_banks(self) -> int:
        return self.n_stacks * self.channels_per_stack \
            * self.banks_per_channel

    @property
    def active_subarrays_per_bank(self) -> int:
        return int(self.subarrays_per_bank * self.open_bitline_frac)

    @property
    def macs_per_tile_round(self) -> int:
        """MACs accumulated per operational tile before an A_to_B readout
        (2 multiplies at a time x 20-deep MOMCAPs x 2 caps)."""
        return self.momcap_depth * self.caps_per_tile

    @property
    def t_link_ns_per_bit(self) -> float:
        """Inter-bank link: 256 bits/cycle at the DRAM I/O clock; the
        paper's 256 GB/s stack bandwidth over 8 channels gives the
        effective per-bank-link rate."""
        bytes_per_ns = self.stack_bw_gbps / self.channels_per_stack
        return 1.0 / (bytes_per_ns * 8.0)


DEFAULT = ArtemisConfig()


# DRISA-style conventional PIM (Fig 2 comparison): digital bit-serial MAC,
# a single MUL takes 1600 ns (§II.E), additions ~8 MOCs per bit-serial add.
@dataclasses.dataclass(frozen=True)
class DrisaConfig:
    t_mul_ns: float = 1600.0
    t_add_ns: float = 8 * 17.0
    t_moc_ns: float = 17.0


DRISA_CONFIG = DrisaConfig()
