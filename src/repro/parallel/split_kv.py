"""Split-KV decode attention — the token dataflow's decode-side analogue.

Paper Eq. 5's log-sum-exp decomposition is associative across token
shards, so a decode step against a sequence-sharded KV cache can compute
per-shard partial attention and merge exactly with one psum pair:

  m   = pmax_i(m_i)
  out = psum_i(o_i * l_i * exp(m_i - m)) / psum_i(l_i * exp(m_i - m))

where (o_i, m_i, l_i) are each shard's normalized output / running max /
sum-exp. This is what ARTEMIS' NSC comparator network does across banks
(§III.C.2 pipelined y_max + §III.D softmax overlap), expressed on the TPU
ICI. Used by serve_step when the KV cache's S axis is sharded over
`model` (parallel.sharding.cache_specs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.ring_attention import NEG_INF, _chunk_attn, _repeat_kv

__all__ = ["NEG_INF", "split_kv_attention"]


def split_kv_attention(q, k_local, v_local, *, axis_name: str,
                       q_positions, kv_positions_local,
                       scale: float | None = None, causal: bool = True):
    """q: (B, Sq, H, D) REPLICATED across `axis_name` (Sq = 1 for decode);
    k_local/v_local: (B, S_shard, H|KV, D) — this device's token shard
    (KV-head counts that divide H are repeated internally: GQA).
    kv_positions_local: (B, S_shard) global positions (INT32_MAX = empty).

    Returns (B, Sq, H, D) replicated (identical on every shard).
    """
    b, sq, h, d = q.shape
    k_local, v_local = _repeat_kv(h, k_local, v_local)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    o, m, l = _chunk_attn(q.astype(jnp.float32),
                          k_local.astype(jnp.float32),
                          v_local.astype(jnp.float32),
                          q_positions, kv_positions_local, scale,
                          causal=causal)
    # cross-shard LSE merge (one pmax + two psums on (B,Sq,H)-sized terms —
    # the 'transfer in binary, compressed' insight: only statistics cross
    # the link, never the S-sized score matrix)
    m_glob = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - m_glob)
    num = jax.lax.psum(o * w[..., None], axis_name)
    den = jax.lax.psum(l * w, axis_name)
    den = jnp.maximum(den, 1e-30)
    return (num / den[..., None]).astype(q.dtype)
