"""Ring attention — the ARTEMIS token-based dataflow as a shard_map module.

Paper §III.D.1/Fig 5(b): tokens are sharded across banks; each bank
computes Q_i/K_i/V_i locally, then the K_i (and V_i) shards travel a
ring+broadcast network while each bank accumulates partial attention
scores, overlapped with softmax max-tracking and the next MatMul.

TPU-native translation (DESIGN.md §2): banks -> devices along a mesh axis,
ring network -> `jax.lax.ppermute` on ICI, "keep updating y_max as scores
stream out" -> the online-softmax merge carried across ring steps. The
compute of step t overlaps the permute of step t+1 by construction
(ppermute is async on TPU; XLA schedules the DMA alongside the matmuls).

Exactness: per-chunk partial (o, m, l) statistics merge associatively
(paper Eq. 5's log-sum-exp decomposition), so the sharded result is
bit-comparable to full attention up to fp reassociation — pinned in
tests/test_parallel.py.

Layout: q, k, v are (B, S_local, H, Dh) on each device, S sharded along
`axis_name`; causal masking uses global positions derived from
axis_index. Zig-zag (striped) sharding for causal load balance is the
dataflow's `stripe` option (beyond-paper optimization, §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk_attn(q, k, v, q_pos, k_pos, scale, causal):
    """Single-chunk attention partials.

    q: (B,Sq,H,D), k/v: (B,Sk,H,D) -> (o_unnorm (B,Sq,H,D), m, l (B,Sq,H)).
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        keep = q_pos[:, None, :, None] >= k_pos[:, None, None, :]
        s = jnp.where(keep, s, NEG_INF)
    m = jnp.max(s, axis=-1)                       # (B,H,Sq)
    # guard fully-masked rows (m == NEG_INF): exp(s - m) would be exp(0)=1
    m_safe = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1)                       # (B,H,Sq)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o, jnp.moveaxis(m_safe, 1, 2), jnp.moveaxis(l, 1, 2)


def _merge(o1, m1, l1, o2, m2, l2):
    """Associative online-softmax merge of two partials ((B,Sq,H,D) etc.)."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    o = o1 * a1[..., None] + o2 * a2[..., None]
    l = l1 * a1 + l2 * a2
    return o, m, l


def ring_attention(q, k, v, *, axis_name: str, causal: bool = True,
                   scale: float | None = None,
                   q_positions=None, kv_positions=None):
    """Sequence-sharded attention over `axis_name` (call inside shard_map).

    q, k, v: (B, S_local, H|KV, Dh). GQA is handled by the caller repeating
    KV heads (or by equal H). Returns (B, S_local, H, Dh) in q.dtype.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_local, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_positions is None:
        q_positions = idx * s_local + jnp.arange(s_local, dtype=jnp.int32)
        q_positions = jnp.broadcast_to(q_positions[None], (b, s_local))

    qf = q.astype(jnp.float32)

    def body(carry, step):
        o, m, l, kc, vc = carry
        # the K/V chunk currently held arrived from device (idx - step) % n
        src = jnp.remainder(idx - step, n)
        if kv_positions is None:
            k_pos = src * kc.shape[1] + jnp.arange(kc.shape[1],
                                                   dtype=jnp.int32)
            k_pos = jnp.broadcast_to(k_pos[None], (b, kc.shape[1]))
        else:
            k_pos = kv_positions  # caller-supplied (striped layouts)
        oc, mc, lc = _chunk_attn(qf, kc.astype(jnp.float32),
                                 vc.astype(jnp.float32),
                                 q_positions, k_pos, scale, causal)
        o, m, l = _merge(o, m, l, oc, mc, lc)
        # ring step: pass the chunk to the next device (paper Fig 5(b)
        # Rounds 3-4); ppermute overlaps with the next step's compute
        perm = [(i, (i + 1) % n) for i in range(n)]
        kc = jax.lax.ppermute(kc, axis_name, perm)
        vc = jax.lax.ppermute(vc, axis_name, perm)
        return (o, m, l, kc, vc), None

    o0 = jnp.zeros((b, s_local, h, d), jnp.float32)
    m0 = jnp.full((b, s_local, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s_local, h), jnp.float32)
    # mark the constant inits as device-varying over the ring axis (the body
    # outputs are varying; scan carries must type-match under shard_map vma).
    # jax.lax.pvary only exists once shard_map enforces varying-manual-axes
    # typing (jax >= 0.5); on older releases the carries already type-match
    # and the annotation is a no-op.
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        o0, m0, l0 = (pvary(a, axis_name) for a in (o0, m0, l0))
    (o, m, l, _, _), _ = jax.lax.scan(
        body, (o0, m0, l0, k, v), jnp.arange(n))
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None]).astype(q.dtype)


def layer_dataflow_attention(q, k, v, *, axis_name: str,
                             causal: bool = True,
                             scale: float | None = None):
    """The LAYER-BASED dataflow baseline (paper Fig 8 'layer_*'): all-gather
    the full K/V onto every device, then attend locally. Same math, strictly
    more ICI bytes — the comparison benchmarks/collective_bytes.py measures.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_local, h, d = q.shape
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kg = jax.lax.all_gather(k, axis_name, axis=1, tiled=True)
    vg = jax.lax.all_gather(v, axis_name, axis=1, tiled=True)
    q_pos = idx * s_local + jnp.arange(s_local, dtype=jnp.int32)
    q_pos = jnp.broadcast_to(q_pos[None], (b, s_local))
    k_pos = jnp.broadcast_to(
        jnp.arange(kg.shape[1], dtype=jnp.int32)[None], (b, kg.shape[1]))
    o, m, l = _chunk_attn(q.astype(jnp.float32), kg.astype(jnp.float32),
                          vg.astype(jnp.float32), q_pos, k_pos, scale,
                          causal)
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None]).astype(q.dtype)
