"""Ring attention — the ARTEMIS token-based dataflow as a shard_map module.

Paper §III.D.1/Fig 5(b): tokens are sharded across banks; each bank
computes Q_i/K_i/V_i locally, then the K_i (and V_i) shards travel a
ring+broadcast network while each bank accumulates partial attention
scores, overlapped with softmax max-tracking and the next MatMul.

TPU-native translation (DESIGN.md §2): banks -> devices along a mesh axis,
ring network -> `jax.lax.ppermute` on ICI, "keep updating y_max as scores
stream out" -> the online-softmax merge carried across ring steps. The
compute of step t overlaps the permute of step t+1 by construction
(ppermute is async on TPU; XLA schedules the DMA alongside the matmuls).

Exactness: per-chunk partial (o, m, l) statistics merge associatively
(paper Eq. 5's log-sum-exp decomposition), so the sharded result is
bit-comparable to full attention up to fp reassociation — pinned in
tests/test_parallel.py.

Layout: q, k, v are (B, S_local, H, Dh) on each device, S sharded along
`axis_name`; causal masking uses global positions derived from
axis_index. Zig-zag (striped) sharding for causal load balance is the
dataflow's `stripe` option (beyond-paper optimization, §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
_EMPTY = 2**31 - 1   # INT32_MAX position = unwritten cache slot


def _repeat_kv(h: int, k, v):
    """Native GQA: repeat (B, S, KV, D) K/V heads up to the H query
    heads (KV must divide H). Head order matches the serve layer's
    grouped-query reshape (q head i -> kv head i // g)."""
    kvh = k.shape[2]
    if kvh == h:
        return k, v
    if h % kvh:
        raise ValueError(
            f"query heads ({h}) must be a multiple of KV heads ({kvh})")
    g = h // kvh
    return jnp.repeat(k, g, axis=2), jnp.repeat(v, g, axis=2)


def _chunk_attn(q, k, v, q_pos, k_pos, scale, causal):
    """Single-chunk attention partials.

    q: (B,Sq,H,D), k/v: (B,Sk,H,D) -> (o_unnorm (B,Sq,H,D), m, l (B,Sq,H)).
    """
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    # INT32_MAX-position slots mark unwritten cache entries: masked under
    # BOTH modes (the causal comparison used to be the only thing hiding
    # them, so non-causal attention read garbage K/V — surfaced when the
    # sharded serve path started calling these with padded paged views)
    keep = (k_pos < _EMPTY)[:, None, None, :]
    if causal:
        keep = keep & (q_pos[:, None, :, None] >= k_pos[:, None, None, :])
    s = jnp.where(keep, s, NEG_INF)
    m = jnp.max(s, axis=-1)                       # (B,H,Sq)
    # guard fully-masked rows (m == NEG_INF): exp(s - m) would be exp(0)=1
    m_safe = jnp.maximum(m, -1e29)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    l = jnp.sum(p, axis=-1)                       # (B,H,Sq)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o, jnp.moveaxis(m_safe, 1, 2), jnp.moveaxis(l, 1, 2)


def _merge(o1, m1, l1, o2, m2, l2):
    """Associative online-softmax merge of two partials ((B,Sq,H,D) etc.)."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    o = o1 * a1[..., None] + o2 * a2[..., None]
    l = l1 * a1 + l2 * a2
    return o, m, l


def ring_attention(q, k, v, *, axis_name: str, causal: bool = True,
                   scale: float | None = None,
                   q_positions=None, kv_positions=None):
    """Sequence-sharded attention over `axis_name` (call inside shard_map).

    q: (B, Sq_local, H, Dh); k, v: (B, Sk_local, H|KV, Dh) — KV-head
    counts that divide H are repeated internally (GQA), and the K/V
    chunk length may differ from the query chunk length (the sharded
    paged-serve path rings a gathered cache view past short prompt
    chunks). `q_positions`/`kv_positions` are per-device GLOBAL
    positions of the local chunks (defaults assume contiguous layout);
    a device's kv positions travel the ring WITH its K/V chunk, so
    striped / paged layouts mask correctly on every hop. Returns
    (B, Sq_local, H, Dh) in q.dtype.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_local, h, d = q.shape
    k, v = _repeat_kv(h, k, v)
    s_k = k.shape[1]
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    if q_positions is None:
        q_positions = idx * s_local + jnp.arange(s_local, dtype=jnp.int32)
        q_positions = jnp.broadcast_to(q_positions[None], (b, s_local))
    if kv_positions is None:
        kv_positions = idx * s_k + jnp.arange(s_k, dtype=jnp.int32)
        kv_positions = jnp.broadcast_to(kv_positions[None], (b, s_k))

    qf = q.astype(jnp.float32)

    def body(carry, _):
        o, m, l, kc, vc, pc = carry
        oc, mc, lc = _chunk_attn(qf, kc.astype(jnp.float32),
                                 vc.astype(jnp.float32),
                                 q_positions, pc, scale, causal)
        o, m, l = _merge(o, m, l, oc, mc, lc)
        # ring step: pass the chunk (and its positions — they describe
        # the chunk, not the device) to the next device (paper Fig 5(b)
        # Rounds 3-4); ppermute overlaps with the next step's compute
        perm = [(i, (i + 1) % n) for i in range(n)]
        kc = jax.lax.ppermute(kc, axis_name, perm)
        vc = jax.lax.ppermute(vc, axis_name, perm)
        pc = jax.lax.ppermute(pc, axis_name, perm)
        return (o, m, l, kc, vc, pc), None

    o0 = jnp.zeros((b, s_local, h, d), jnp.float32)
    m0 = jnp.full((b, s_local, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s_local, h), jnp.float32)
    # mark the constant inits as device-varying over the ring axis (the body
    # outputs are varying; scan carries must type-match under shard_map vma).
    # jax.lax.pvary only exists once shard_map enforces varying-manual-axes
    # typing (jax >= 0.5); on older releases the carries already type-match
    # and the annotation is a no-op.
    pvary = getattr(jax.lax, "pvary", None)
    if pvary is not None:
        o0, m0, l0 = (pvary(a, axis_name) for a in (o0, m0, l0))
    (o, m, l, _, _, _), _ = jax.lax.scan(
        body, (o0, m0, l0, k, v, kv_positions), None, length=n)
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None]).astype(q.dtype)


def layer_dataflow_attention(q, k, v, *, axis_name: str,
                             causal: bool = True,
                             scale: float | None = None):
    """The LAYER-BASED dataflow baseline (paper Fig 8 'layer_*'): all-gather
    the full K/V onto every device, then attend locally. Same math, strictly
    more ICI bytes — the comparison benchmarks/collective_bytes.py measures.
    """
    n = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    b, s_local, h, d = q.shape
    k, v = _repeat_kv(h, k, v)
    if scale is None:
        scale = 1.0 / (d ** 0.5)
    kg = jax.lax.all_gather(k, axis_name, axis=1, tiled=True)
    vg = jax.lax.all_gather(v, axis_name, axis=1, tiled=True)
    q_pos = idx * s_local + jnp.arange(s_local, dtype=jnp.int32)
    q_pos = jnp.broadcast_to(q_pos[None], (b, s_local))
    k_pos = jnp.broadcast_to(
        jnp.arange(kg.shape[1], dtype=jnp.int32)[None], (b, kg.shape[1]))
    o, m, l = _chunk_attn(q.astype(jnp.float32), kg.astype(jnp.float32),
                          vg.astype(jnp.float32), q_pos, k_pos, scale,
                          causal)
    l = jnp.maximum(l, 1e-30)
    return (o / l[..., None]).astype(q.dtype)
