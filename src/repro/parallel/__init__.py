from repro.parallel.sharding import (
    ShardingRules,
    batch_axes,
    batch_specs,
    cache_specs,
    paged_pool_spec,
    param_specs,
)
from repro.parallel.context import (
    activation_constraint,
    sharding_ctx,
    use_sharding,
)
from repro.parallel.ring_attention import ring_attention
from repro.parallel.split_kv import split_kv_attention
from repro.parallel.compress import (
    CompressionState,
    compressed_psum,
    init_compression,
)

__all__ = [
    "ShardingRules", "param_specs", "batch_specs", "cache_specs",
    "batch_axes", "paged_pool_spec", "activation_constraint", "use_sharding", "sharding_ctx",
    "ring_attention", "split_kv_attention",
    "CompressionState", "compressed_psum", "init_compression",
]
