"""Collective compression with error feedback — the inter-bank 'transfer in
binary' insight (paper §III.D.1) applied to gradient all-reduce.

ARTEMIS converts stochastic streams to dense binary before crossing the
shared HBM bus (128 bits -> 8 bits per value). The DP-gradient analogue:
cast grads to a narrow dtype before the all-reduce, keep the residual in
an error-feedback buffer so compression noise is unbiased over steps
(Karimireddy et al. 2019).

Modes: "none" | "bf16" | "int8" (per-tensor symmetric, like the ARTEMIS
quantizer). int8 halves DP all-reduce bytes vs bf16 and quarters fp32.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionState:
    mode: str                 # none | bf16 | int8
    error: dict | None       # error-feedback buffers (same tree as grads)


def init_compression(grads_like, mode: str = "none") -> CompressionState:
    if mode == "none":
        return CompressionState("none", None)
    err = jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    return CompressionState(mode, err)


def _compress(g: jax.Array, mode: str, axis_name=None):
    """Returns (compressed, dequantize_fn)."""
    if mode == "bf16":
        c = g.astype(jnp.bfloat16)
        return c, lambda x: x.astype(jnp.float32)
    if mode == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
        if axis_name is not None:
            # all ranks must quantize with the SAME scale or the int32 sum
            # of their int8 lanes is meaningless — one tiny pmax fixes it
            scale = jax.lax.pmax(scale, axis_name)
        c = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return c, lambda x: x.astype(jnp.float32) * scale
    raise ValueError(mode)


def compressed_psum(grads, state: CompressionState, axis_name):
    """psum(grads) over `axis_name` with compression + error feedback.

    Call inside shard_map/pmap. Returns (mean_grads, new_state).
    NOTE: int8 psum sums int8 lanes in int32 via upcast to avoid overflow.
    """
    n = jax.lax.psum(1, axis_name)
    if state.mode == "none":
        out = jax.tree.map(
            lambda g: jax.lax.psum(g, axis_name) / n, grads)
        return out, state

    new_err = {}
    outs = {}
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_flatten(state.error)[0]
    out_leaves, err_leaves = [], []
    for g, e in zip(flat_g, flat_e):
        g32 = g.astype(jnp.float32) + e          # error feedback
        c, deq = _compress(g32, state.mode, axis_name)
        if state.mode == "int8":
            summed = jax.lax.psum(c.astype(jnp.int32), axis_name)
            red = deq(summed) / n
        else:
            red = deq(jax.lax.psum(c, axis_name)) / n
        err_leaves.append(g32 - deq(c.astype(jnp.int32)
                                    if state.mode == "int8" else c))
        out_leaves.append(red.astype(g.dtype))
    out = jax.tree_util.tree_unflatten(treedef, out_leaves)
    err = jax.tree_util.tree_unflatten(treedef, err_leaves)
    return out, CompressionState(state.mode, err)
