"""PartitionSpec rules — DP / TP / EP / SP over the production mesh.

Mesh axes (launch.mesh.make_production_mesh):
  pod    outermost data parallelism (multi-pod only)
  data   batch DP + FSDP weight sharding (ZeRO-3 style)
  model  tensor parallelism (heads / d_ff / vocab / experts) and — for
         decode — SEQUENCE sharding of the KV cache (the ARTEMIS
         token-based dataflow mapped onto the TP axis: banks -> chips,
         shared HBM bus -> ICI, K_i/V_i ring exchange -> split-KV psum
         merge / ring attention).

Rules are name-matched over flattened param paths (MaxText-style logical
rules), with a divisibility guard: GSPMD pads uneven dims, but we only
*request* sharding where it pays; tiny leaves (norms, biases, scalars)
stay replicated.

Batch specs by shape kind:
  train    tokens (B,S): B over (pod,data); activations constrained
           (B over dp, optional S over model = sequence parallelism)
  prefill  B over (pod,data)
  decode   B over (pod,data); KV cache S over model (split-KV)
"""
from __future__ import annotations

import dataclasses
import re

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Knobs iterated over in §Perf hillclimbing."""
    fsdp: bool = True              # shard the non-TP weight dim over `data`
    seq_parallel: bool = False     # activations S over `model` between blocks
    decode_kv_seq_shard: bool = True   # KV cache S over `model` (split-KV)
    expert_axis: str = "model"     # EP axis for MoE expert leaves


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Data-parallel axes: ('pod', 'data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def _guard(mesh: Mesh, dim: int, axis, min_frac: float = 1.0):
    """Request sharding only when the dim divides evenly: jit
    in_shardings are strict about divisibility (and padded shards waste
    compute even where GSPMD would tolerate them)."""
    size = _axis_size(mesh, axis)
    if size == 1 or dim % size != 0:
        return None
    return axis


# ---------------------------------------------------------------------------
# parameter rules: (regex over leaf path, lambda(shape) -> logical spec)
# logical axes: "tp" (model), "fsdp" (data), "ep" (model), None
# ---------------------------------------------------------------------------

# each entry: (pattern, per-dim logical axes, applied right-aligned to the
# leaf's trailing dims; leading dims — the scan L axis, expert E axis
# handled explicitly — get None)
_RULES: list[tuple[str, tuple]] = [
    # -- MoE (match before generic ffn rules) --
    # expert weights: EP-sharded on E ONLY. FSDP-sharding d over `data`
    # makes the expert einsum contract over a data-sharded dim; XLA then
    # all-reduces (E, G·C, ff)-sized activation partials (~7 GB/op) and
    # gathers the dispatch buffers — §Perf H4b. Per-device expert slices
    # are small (E/tp experts), so EP-only is also the memory-right call.
    (r"experts.*w_(up|gate)", ("ep", None, None)),       # (E, d, d_ff_e)
    (r"experts.*w_down",      ("ep", None, None)),       # (E, d_ff_e, d)
    (r"shared.*w_(up|gate)",  ("ep", "fsdp", "tp")),     # (Ns, d, d_ff_e)
    (r"shared.*w_down",       ("ep", "tp", "fsdp")),
    (r"router",               ("fsdp", None)),           # (d, E) exact fp32
    # -- attention --
    (r"\['wq'\]|\['wk'\]|\['wv'\]", ("fsdp", "tp")),     # (d, H*hd)
    (r"\['wo'\]",             ("tp", "fsdp")),           # (H*hd, d)
    # -- FFN --
    (r"w_(up|gate)",          ("fsdp", "tp")),           # (d, d_ff)
    (r"w_down",               ("tp", "fsdp")),           # (d_ff, d)
    # -- embeddings / head --
    (r"embed",                ("tp", "fsdp")),           # (V, d) vocab-TP
    (r"head",                 ("fsdp", "tp")),           # (d, V)
    # -- mamba2 --
    (r"in_proj",              ("fsdp", "tp")),           # (d, 2di+2n+h)
    (r"out_proj",             ("tp", "fsdp")),           # (di, d)
    (r"conv_w",               (None, "tp")),             # (W, C)
    (r"conv_b",               ("tp",)),
    # -- rwkv6 --
    (r"\['wr'\]|\['wg'\]",    ("fsdp", "tp")),
    (r"cm_wk",                ("fsdp", "tp")),
    (r"cm_wv",                ("tp", "fsdp")),
    (r"cm_wr",                ("fsdp", "tp")),
    (r"td_w1|maa_w1",         ("fsdp", None)),
    (r"td_w2",                (None, "fsdp")),
    (r"maa_w2",               (None, None, "fsdp")),
]


def _logical_to_mesh(logical, mesh: Mesh, rules: ShardingRules):
    if logical == "tp":
        return "model"
    if logical == "ep":
        return rules.expert_axis
    if logical == "fsdp":
        if not rules.fsdp:
            return None
        axes = dp_axes(mesh)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]
    return None


def _spec_for_leaf(path: str, shape: tuple, mesh: Mesh,
                   rules: ShardingRules) -> P:
    for pattern, logical in _RULES:
        if re.search(pattern, path):
            ndim = len(shape)
            spec: list = [None] * ndim
            # right-align the logical template onto trailing dims
            tmpl = logical[-ndim:] if len(logical) > ndim else logical
            off = ndim - len(tmpl)
            for i, ax in enumerate(tmpl):
                mesh_ax = _logical_to_mesh(ax, mesh, rules)
                spec[off + i] = _guard(mesh, shape[off + i], mesh_ax)
            # never shard the same mesh axis twice in one spec
            seen: set = set()
            for i, s in enumerate(spec):
                flat = s if isinstance(s, tuple) else (s,)
                if s is not None and seen & set(flat):
                    spec[i] = None
                else:
                    seen |= set(flat)
            return P(*spec)
    return P()  # replicate (norms, scalars, luts, small loras)


def param_specs(cfg: ModelConfig, shapes, mesh: Mesh,
                rules: ShardingRules = ShardingRules()):
    """shapes: pytree of ShapeDtypeStruct/arrays -> pytree of PartitionSpec."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = []
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        specs.append(_spec_for_leaf(path, tuple(leaf.shape), mesh, rules))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh):
    axes = dp_axes(mesh)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int) -> dict:
    """Specs for {"tokens", "labels", optional "prefix_embeds"}."""
    bax = batch_axes(mesh)
    if _axis_size(mesh, bax) > batch:
        bax = None  # degenerate cells (long_500k B=1): replicate batch
    tok = P(bax, None, None) if cfg.modality == "audio" else P(bax, None)
    out = {"tokens": tok, "labels": tok}
    if cfg.modality == "vlm":
        out["prefix_embeds"] = P(bax, None, None)
    return out


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                rules: ShardingRules = ShardingRules()) -> dict:
    """Decode-cache specs. The KV sequence axis goes over `model` — the
    ARTEMIS token dataflow (each "bank" owns a token shard; attention is
    split-KV with an LSE-exact merge, inserted by GSPMD as psums)."""
    bax = batch_axes(mesh)
    if _axis_size(mesh, bax) > batch:
        bax = None
    seq_ax = "model" if rules.decode_kv_seq_shard else None
    if cfg.family == "rwkv6":
        # O(1) state: (L, B, H, N, N) x_tm/x_cm (L, B, d), no seq axis.
        # H (=40) rarely divides the TP degree; the value dim N does.
        h = cfg.d_model // cfg.ssm_head_dim
        h_ax = _guard(mesh, h, "model")
        n_ax = None if h_ax else _guard(mesh, cfg.ssm_head_dim, "model")
        return {
            "layers": {
                "x_tm": P(None, bax, None),
                "x_cm": P(None, bax, None),
                "wkv": P(None, bax, h_ax, n_ax, None),
            },
            "index": P(),
        }
    if cfg.family == "zamba2":
        h_ax = _guard(mesh, cfg.ssm_heads, "model")
        n_ax = None if h_ax else _guard(mesh, cfg.ssm_state, "model")
        conv_ch = cfg.d_inner + 2 * cfg.ssm_state
        return {
            "mamba": {
                "ssd": P(None, bax, h_ax, n_ax, None),
                "conv": P(None, bax, None, _guard(mesh, conv_ch, "model")),
            },
            "attn_k": P(None, bax, seq_ax, None, None),
            "attn_v": P(None, bax, seq_ax, None, None),
            "attn_pos": P(bax, None),
            "index": P(),
        }
    # dense / moe transformer KV cache: (L, B, S, KV, hd)
    return {
        "k": P(None, bax, seq_ax, None, None),
        "v": P(None, bax, seq_ax, None, None),
        "index": P(),
    }


def paged_pool_spec(cfg: ModelConfig, mesh: Mesh) -> P:
    """Spec for the serve layer's paged KV pool
    (L, n_pages, page, KV, hd): per-shard K/V partitioned along the
    KV-HEAD axis when it divides the TP degree, replicated otherwise.
    The pool's page axis is indexed by host-side block tables (an
    arbitrary permutation, not a sequence), so unlike `cache_specs`
    there is no token axis to shard — the paged analogue of the token
    dataflow lives in the attention core (split-KV / ring over the
    gathered view), not the pool layout."""
    kv_ax = _guard(mesh, cfg.n_kv_heads, "model")
    return P(None, None, None, kv_ax, None)


def named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# seam-exported spec constructors — consumer modules are not allowed to
# build PartitionSpecs themselves (the shard-spec-discipline analysis
# rule), so every layout a consumer needs has a named helper here
# ---------------------------------------------------------------------------


def replicated_spec() -> P:
    """The fully-replicated spec (scalars, metrics, optimizer step)."""
    return P()


def logits_spec(lead: tuple) -> P:
    """Placement for a logits output: the given leading (batch-ish)
    axes as-is, vocab over the TP axis — the launch-layer jit
    out_shardings for prefill/decode steps."""
    return P(*lead, "model")


def moe_dispatch_specs(dp_spec, ep_axis) -> dict:
    """The MoE hierarchical-dispatch placement set (models/moe.py):

    tokens   (G, Tg, d)    token groups over dp
    buffers  (G, E, C, d)  dispatch buffers, still over dp
    expert   (E, G, C, d)  expert-major view, E over ep x G over dp

    The buffers->expert spec flip IS the all-to-all (and its inverse on
    the way back); keeping all three here keeps that contract visible
    in one place."""
    return {
        "tokens": P(dp_spec, None, None),
        "buffers": P(dp_spec, None, None, None),
        "expert": P(ep_axis, dp_spec, None, None),
    }
