"""Activation-sharding context — constraint hooks without threading a mesh
through every model function.

The launcher installs (mesh, rules) in a contextvar; model code calls
`activation_constraint(x, kind)` at block boundaries. Outside any context
(unit tests, single-device runs) the hooks are identity.

kinds:
  "resid"   (B, S, d) residual-stream activations between blocks
            -> P(dp, seq?, None); seq over `model` when rules.seq_parallel
               (Korthikanti-style sequence parallelism: norms/residual work
               is sharded over the TP axis between the matmul regions)
  "logits"  (B, S, V) -> vocab over `model`
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import sharding as sh

_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_ctx", default=None)


def sharding_ctx():
    return _CTX.get()


@contextlib.contextmanager
def use_sharding(mesh: Mesh, rules: sh.ShardingRules):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def activation_constraint(x: jax.Array, kind: str = "resid") -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    bax = sh.batch_axes(mesh)
    if kind == "resid":
        if x.ndim != 3:
            return x
        seq_ax = "model" if rules.seq_parallel else None
        spec = P(bax, seq_ax, None)
    elif kind == "logits":
        spec = P(bax, None, "model")
    else:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def _flat_axes(bax) -> tuple:
    if bax is None:
        return ()
    return tuple(bax) if isinstance(bax, tuple) else (bax,)


def attention_heads_constraint(x: jax.Array, n_q_heads: int) -> jax.Array:
    """Place (B, S, H, Dh) attention tensors so score einsums stay local.

    Only intervenes when the Q-HEAD count does not divide the TP degree —
    the measured pathology (internvl2 14H, qwen3-14b 40H, deepseek 56H on
    tp=16): GSPMD partially shards head_dim and all-reduces the S²-sized
    score tensor (34 GB/layer measured). In that case q/k/v are all
    pinned to the same layout, in priority:
      1. S % tp == 0      -> query-sequence-sharded attention (S over
         model; K/V gathers are S·d-sized, the S² block stays local)
      2. B % (dp*tp) == 0 -> batch-sharded attention
      3. replicate over model (last resort)
    When H % tp == 0, GSPMD's own propagation (Megatron head-TP with GQA
    KV broadcast) is already right — constraining it REGRESSED qwen3-8b
    3x (kv=8 heads got a different layout than q; §Perf H2 iteration 3).
    """
    import os
    if os.environ.get("REPRO_NO_ATTN_HOOK"):   # compile-time bisection
        return x
    ctx = _CTX.get()
    if ctx is None or x.ndim != 4:
        return x
    mesh, rules = ctx
    tp = mesh.shape.get("model", 1)
    if tp == 1 or n_q_heads % tp == 0:
        return x
    bax = sh.batch_axes(mesh)
    dp = 1
    for a in _flat_axes(bax):
        dp *= mesh.shape[a]
    b, s, _, _ = x.shape
    if s % tp == 0:
        spec = P(bax, "model", None, None)
    elif b % (dp * tp) == 0:
        spec = P(_flat_axes(bax) + ("model",), None, None, None)
    else:
        spec = P(bax, None, None, None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
