"""The ARTEMIS matmul emulation ladder — the paper's MAC pipeline end-to-end.

For one output element, ARTEMIS computes (paper §III.A, §III.C.1):

  1. quantize operands to signed 8-bit; magnitudes go to 128-level TCU
     streams, signs to the per-row sign column;
  2. multiply each operand pair with the deterministic TCU AND
     -> floor(m_a * m_b / 128);
  3. accumulate products on MOMCAPs in groups of `acc_depth` (=20),
     positives and negatives in separate passes;
  4. read each group out through the quantizing A_to_B ladder;
  5. reduce group readouts (pos - neg) exactly in the NSC adders;
  6. dequantize: result = signed_sum * 128 * s_a * s_b.

Four modes (ArithmeticPolicy.mode):
  exact        a @ b in float
  int8         quantize, exact int32 dot, dequantize
  artemis      the full pipeline above (scan over K-groups, VPU-style)
  artemis_mxu  beyond-paper MXU fast path (see below)

The MXU fast path.  Writing m_a*m_b = 128*floor(m_a*m_b/128) + r with
r = (m_a*m_b mod 128) in [0,127]:

  sum_k sign_k * floor(...) = ( sum_k qa_k*qb_k - sum_k sign_k * r_k ) / 128

The first term is a plain int8 MXU matmul of the *signed* operands.  The
correction term is approximated by rbar * (sign(a) @ sign(b)) — a second
int8 matmul of the sign matrices with the calibrated constant
rbar = E[(m_a*m_b) mod 128] (~63.5 for weakly-dependent operands).  Two MXU
matmuls replace O(M*K*N) VPU element work; the residual error (zero-mean,
O(sqrt(K)) scale) and the unmodeled readout quantization are measured in
benchmarks/table5_calibration.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quantization as q
from repro.core.analog import MomcapConfig, readout_quantize
from repro.core.policy import ArithmeticPolicy
from repro.core.quantization import SC_LEVELS
from repro.core.stochastic import sc_multiply


def _quantize_pair(a, b, policy: ArithmeticPolicy):
    """Per-tensor (activations) / per-column (weights) symmetric int8."""
    sa = q.quant_scale(a, 8, policy.act_quant_axis)
    sb = q.quant_scale(b, 8, policy.weight_quant_axis)
    return q.quantize(a, sa), q.quantize(b, sb), sa, sb


def _int8_matmul(a, b, policy: ArithmeticPolicy):
    aq, bq, sa, sb = _quantize_pair(a, b, policy)
    acc = jnp.matmul(
        aq.astype(jnp.int32), bq.astype(jnp.int32)
    ).astype(jnp.float32)
    return acc * sa * sb


def _artemis_emulated(a, b, policy: ArithmeticPolicy, key):
    """Bit-faithful pipeline. a: (..., M, K), b: (K, N)."""
    aq, bq, sa, sb = _quantize_pair(a, b, policy)
    ma, sga = q.magnitude_sign(aq)           # (..., M, K)
    mb, sgb = q.magnitude_sign(bq)           # (K, N)

    g = policy.acc_depth
    k = ma.shape[-1]
    pad = (-k) % g
    if pad:
        ma = jnp.pad(ma, [(0, 0)] * (ma.ndim - 1) + [(0, pad)])
        sga = jnp.pad(sga, [(0, 0)] * (sga.ndim - 1) + [(0, pad)])
        mb = jnp.pad(mb, [(0, pad), (0, 0)])
        sgb = jnp.pad(sgb, [(0, pad), (0, 0)])
    kp = ma.shape[-1]
    ngroups = kp // g

    # (..., M, ngroups, g) / (ngroups, g, N)
    ma_g = ma.reshape(ma.shape[:-1] + (ngroups, g))
    sga_g = sga.reshape(sga.shape[:-1] + (ngroups, g))
    mb_g = mb.reshape(ngroups, g, -1)
    sgb_g = sgb.reshape(ngroups, g, -1)

    cfg = MomcapConfig(
        acc_depth=g,
        readout_bits=policy.readout_bits,
        sigma_analog=policy.sigma_analog,
    )
    out_shape = ma.shape[:-1] + (mb.shape[-1],)

    noisy = policy.sigma_analog > 0.0
    if noisy and key is None:
        raise ValueError("artemis mode with sigma_analog > 0 needs a key")
    key0 = key if noisy else jax.random.PRNGKey(0)

    def body(carry, xs):
        acc, kcur = carry
        ma_i, sga_i, mb_i, sgb_i = xs
        # one MOMCAP group: (..., M, g, N) SC products
        p = sc_multiply(ma_i[..., :, :, None], mb_i[None, :, :]).astype(
            jnp.float32
        )
        s = sga_i[..., :, :, None] * sgb_i[None, :, :]
        pos = jnp.sum(jnp.where(s > 0, p, 0.0), axis=-2)
        neg = jnp.sum(jnp.where(s < 0, p, 0.0), axis=-2)
        if noisy:
            kcur, kp_, kn_ = jax.random.split(kcur, 3)
        else:
            kp_ = kn_ = None
        acc = acc + readout_quantize(pos, cfg, kp_) - readout_quantize(
            neg, cfg, kn_
        )
        return (acc, kcur), None

    acc0 = jnp.zeros(out_shape, jnp.float32)
    (acc, _), _ = jax.lax.scan(
        body,
        (acc0, key0),
        (
            jnp.moveaxis(ma_g, -2, 0),
            jnp.moveaxis(sga_g, -2, 0),
            mb_g,
            sgb_g,
        ),
    )
    return acc * SC_LEVELS * sa * sb


def _artemis_mxu(a, b, policy: ArithmeticPolicy):
    aq, bq, sa, sb = _quantize_pair(a, b, policy)
    value_dot = jnp.matmul(aq.astype(jnp.int32), bq.astype(jnp.int32))
    sign_dot = jnp.matmul(
        jnp.sign(aq).astype(jnp.int32), jnp.sign(bq).astype(jnp.int32)
    )
    acc = (value_dot.astype(jnp.float32)
           - policy.rbar * sign_dot.astype(jnp.float32)) / SC_LEVELS
    return acc * SC_LEVELS * sa * sb


def artemis_matmul(
    a: jax.Array,
    b: jax.Array,
    policy: ArithmeticPolicy = ArithmeticPolicy(),
    key: jax.Array | None = None,
) -> jax.Array:
    """Matmul through the ARTEMIS arithmetic ladder.

    a: (..., M, K) float; b: (K, N) float.  Returns float32 (..., M, N).
    With policy.ste the backward pass uses the exact matmul gradient
    (straight-through), making every mode trainable.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if policy.mode == "exact":
        return jnp.matmul(a, b)
    if policy.mode == "int8":
        out = _int8_matmul(a, b, policy)
    elif policy.mode == "artemis":
        out = _artemis_emulated(a, b, policy, key)
    elif policy.mode == "artemis_mxu":
        out = _artemis_mxu(a, b, policy)
    else:  # pragma: no cover
        raise ValueError(policy.mode)
    if policy.ste:
        exact = jnp.matmul(a, b)
        out = exact + jax.lax.stop_gradient(out - exact)
    return out


def calibrate_rbar(a: jax.Array, b: jax.Array, policy: ArithmeticPolicy) -> float:
    """Exact E[(m_a*m_b) mod 128] over the operands' actual distribution —
    refines the MXU correction constant per layer (benchmark utility)."""
    aq, bq, _, _ = _quantize_pair(a, b, policy)
    ma, _ = q.magnitude_sign(aq)
    mb, _ = q.magnitude_sign(bq)
    r = (ma[..., :, :, None] * mb[None, :, :]) % SC_LEVELS
    return float(jnp.mean(r.astype(jnp.float32)))
