"""repro.core — the ARTEMIS mixed analog-stochastic arithmetic, in JAX.

Public surface:
  ArithmeticPolicy, EXACT/INT8/ARTEMIS/ARTEMIS_MXU presets
  artemis_matmul          the MAC pipeline (all modes)
  sc_multiply             deterministic TCU multiply, closed form
  grouped_signed_accumulate / MomcapConfig   analog accumulation model
  lse_softmax / artemis_softmax              Eq. 5 softmax (exact / LUT)
  lut_activation                             NSC LUT nonlinearities
"""
from repro.core.analog import (
    MomcapConfig,
    grouped_signed_accumulate,
    max_linear_accumulations,
    momcap_voltage_trace,
    readout_quantize,
)
from repro.core.artemis_matmul import artemis_matmul, calibrate_rbar
from repro.core.lut import binned_apply, lut_activation
from repro.core.policy import (
    ARTEMIS,
    ARTEMIS_MXU,
    EXACT,
    INT8,
    ArithmeticPolicy,
)
from repro.core.quantization import (
    SC_LEVELS,
    dequantize,
    fake_quant,
    magnitude_sign,
    quant_scale,
    quantize,
)
from repro.core.softmax import artemis_softmax, lse_softmax, online_max_sum
from repro.core.stochastic import (
    SC_BITS,
    sc_multiply,
    sc_multiply_bitstream,
    sc_multiply_float,
    sc_truncation_error,
    spread_encode,
    tcu_encode,
)

__all__ = [
    "ArithmeticPolicy", "EXACT", "INT8", "ARTEMIS", "ARTEMIS_MXU",
    "artemis_matmul", "calibrate_rbar",
    "MomcapConfig", "grouped_signed_accumulate", "readout_quantize",
    "momcap_voltage_trace", "max_linear_accumulations",
    "lse_softmax", "artemis_softmax", "online_max_sum",
    "binned_apply", "lut_activation",
    "SC_LEVELS", "SC_BITS", "quantize", "dequantize", "quant_scale",
    "fake_quant", "magnitude_sign",
    "sc_multiply", "sc_multiply_bitstream", "sc_multiply_float",
    "sc_truncation_error", "tcu_encode", "spread_encode",
]
