"""NSC reprogrammable-LUT nonlinearities — paper §III.C.2.

Each NSC unit evaluates nonlinear functions (exp, ln for softmax; ReLU,
GELU, SiLU for FFNs) through 8-bit look-up tables.  We emulate a real
n-entry table:

  * the table's input grid covers [lo, hi] (linear bins) or is log-spaced
    (`log_bins=True` — hardware-realizable with the priority encoder the
    NSC already has for U_to_B conversion, i.e. an MSB/exponent index);
  * stored outputs are optionally quantized to `out_bits` levels over the
    table's own output range (min/max over stored entries);
  * a lookup snaps the input to the nearest grid point and returns the
    stored (quantized) output.

Under jit the input range may be traced (per-tensor calibration); the table
is then *constructed* on the traced grid, which is bit-identical to
indexing a materialized LUT.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def binned_apply(
    fn,
    x: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    n_in: int = 256,
    out_bits: int | None = 8,
    log_bins: bool = False,
) -> jax.Array:
    """Emulate an n_in-entry LUT of `fn` over [lo, hi] applied to x."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    grid = jnp.arange(n_in, dtype=jnp.float32) / (n_in - 1)
    if log_bins:
        # log-spaced grid over [lo, hi], lo > 0 (exponent-indexed table)
        ratio = jnp.maximum(hi / jnp.maximum(lo, 1e-8), 1.0 + 1e-6)
        xs_table = lo * ratio**grid
        xq = jnp.clip(x, lo, hi)
        idx = jnp.clip(
            jnp.round(jnp.log(xq / lo) / jnp.log(ratio) * (n_in - 1)),
            0, n_in - 1,
        ).astype(jnp.int32)
    else:
        span = jnp.maximum(hi - lo, 1e-8)
        xs_table = lo + grid * span
        idx = jnp.clip(
            jnp.round((x - lo) / span * (n_in - 1)), 0, n_in - 1
        ).astype(jnp.int32)

    ys_table = fn(xs_table)
    if out_bits is not None:
        # stored-output quantization over the table's own output range
        y_lo = jnp.min(ys_table)
        y_hi = jnp.max(ys_table)
        y_span = jnp.maximum(y_hi - y_lo, 1e-8)
        levels = 2**out_bits - 1
        yq = jnp.round((ys_table - y_lo) / y_span * levels)
        ys_table = y_lo + yq / levels * y_span
    return jnp.take(ys_table, idx, axis=0)


def _dynamic_range(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    m = jax.lax.stop_gradient(jnp.max(jnp.abs(x)))
    m = jnp.maximum(m, 1e-6)
    return -m, m


def lut_activation(
    x: jax.Array,
    kind: str,
    n_in: int = 256,
    out_bits: int | None = 8,
) -> jax.Array:
    """LUT-emulated activation with per-tensor dynamic range calibration."""
    fns = {
        "relu": jax.nn.relu,
        "gelu": jax.nn.gelu,
        "silu": jax.nn.silu,
        "gelu_tanh": lambda v: jax.nn.gelu(v, approximate=True),
    }
    fn = fns[kind]
    lo, hi = _dynamic_range(x)
    return binned_apply(fn, x, lo, hi, n_in=n_in, out_bits=out_bits)


# exp inputs below this contribute < 6e-6 to a softmax — clamping the LUT
# range here keeps the bins fine where exp actually resolves.
EXP_LUT_FLOOR = -12.0


def lut_exp(x: jax.Array, lo: jax.Array, n_in: int = 256,
            out_bits: int | None = 8) -> jax.Array:
    """exp LUT over [max(lo, FLOOR), 0] — softmax inputs are <= 0 after the
    y_max shift; anything below the floor quantizes to ~0 anyway."""
    lo = jnp.maximum(jnp.asarray(lo, jnp.float32), EXP_LUT_FLOOR)
    return binned_apply(jnp.exp, x, lo, 0.0, n_in=n_in, out_bits=out_bits)


def lut_ln(x: jax.Array, hi: jax.Array, n_in: int = 256,
           out_bits: int | None = 8) -> jax.Array:
    """ln LUT over [1, hi] with log-spaced (exponent-indexed) bins.

    Log spacing bounds the ln error by ln(hi)/(2*(n_in-1)) uniformly —
    linear bins would be catastrophically coarse near x=1.
    """
    return binned_apply(jnp.log, x, 1.0, hi, n_in=n_in, out_bits=out_bits,
                        log_bins=True)
