"""Symmetric integer quantization — the front door of the ARTEMIS ladder.

ARTEMIS (paper §IV.A) quantizes transformer weights/activations to signed
8-bit and represents each magnitude as a 128-level unary (TCU) stream plus a
sign bit.  Everything downstream (stochastic multiply, MOMCAP accumulation)
operates on the integer magnitudes produced here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# 8-bit signed -> 128-bit unary magnitude + 1 sign bit  (paper §III.A.1)
SC_LEVELS = 128


def _absmax(x: jax.Array, axis, keepdims: bool = True) -> jax.Array:
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    return jnp.maximum(m, 1e-8)


def quant_scale(x: jax.Array, bits: int = 8, axis=None) -> jax.Array:
    """Symmetric scale so that round(x/scale) fits in `bits` signed bits.

    axis=None -> per-tensor; axis=int/tuple -> per-channel over that axis.
    """
    qmax = 2 ** (bits - 1) - 1
    return _absmax(x, axis) / qmax


def quantize(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    return q.astype(jnp.int8 if bits <= 8 else jnp.int32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def fake_quant(x: jax.Array, bits: int = 8, axis=None) -> jax.Array:
    """Quantize-dequantize (the Q(8-bit) column of paper Table IV)."""
    s = quant_scale(x, bits, axis)
    return dequantize(quantize(x, s, bits), s)


def magnitude_sign(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split a signed int8 tensor into (magnitude in [0,127], sign in {-1,0,+1}).

    ARTEMIS stores the sign in a dedicated bit-line column and keeps all-
    positive / all-negative rows (paper §III.A.1); computationally the split
    is per-element.
    """
    q32 = q.astype(jnp.int32)
    return jnp.abs(q32), jnp.sign(q32)
