"""MOMCAP analog temporal accumulation — paper §III.A.2, §III.B, Fig 7.

Each DRAM tile dumps the popcount of a stochastic product row as charge on a
metal-on-metal capacitor.  Up to `acc_depth = 20` consecutive products
accumulate per MOMCAP (an operational tile borrows its idle neighbour's cap,
so a tile covers 40 MACs) before the analog value must be read out through
the A_to_U comparator ladder + U_to_B priority encoder (31 ns).

Numerically this is:
  * exact integer sums of floor-products inside a group of `acc_depth`,
  * a quantizing readout (`readout_bits` levels over the group full scale)
    with optional zero-mean Gaussian analog noise (`sigma_analog`, expressed
    as a fraction of full scale; paper Table V measures MAE 0.0085),
  * signs handled by accumulating all-positive and all-negative products in
    separate passes and subtracting in the NSC adder/subtractor (§III.C.1).

The module also carries the device-level RC charge model used to reproduce
Fig 7 (voltage staircase vs capacitance, max linear accumulations).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core.quantization import SC_LEVELS


@dataclasses.dataclass(frozen=True)
class MomcapConfig:
    acc_depth: int = 20          # consecutive accumulations per MOMCAP
    readout_bits: int | None = 8  # None -> ideal (no readout quantization)
    sigma_analog: float = 0.0    # noise stddev, fraction of group full scale

    @property
    def full_scale(self) -> int:
        """Group full scale in product units (each product <= 127)."""
        return self.acc_depth * (SC_LEVELS - 1)


def readout_quantize(
    x: jax.Array, cfg: MomcapConfig, key: jax.Array | None = None
) -> jax.Array:
    """A_to_B conversion of an accumulated analog value (paper §III.B).

    x: non-negative accumulated product sums, in product units (<= full_scale).
    """
    x = x.astype(jnp.float32)
    if cfg.sigma_analog > 0.0:
        if key is None:
            raise ValueError("sigma_analog > 0 requires a PRNG key")
        x = x + cfg.sigma_analog * cfg.full_scale * jax.random.normal(
            key, x.shape, dtype=jnp.float32
        )
    if cfg.readout_bits is None:
        return x
    levels = 2**cfg.readout_bits - 1
    delta = cfg.full_scale / levels
    return jnp.clip(jnp.round(x / delta), 0, levels) * delta


def grouped_signed_accumulate(
    products: jax.Array,
    signs: jax.Array,
    cfg: MomcapConfig,
    key: jax.Array | None = None,
) -> jax.Array:
    """Accumulate signed floor-products along the LAST axis, ARTEMIS-style.

    products: int32/float magnitudes of SC products, shape (..., K).
    signs:    {-1, 0, +1}, same shape.
    Returns float32 (...,) — the NSC-reduced signed sum after per-group
    MOMCAP readouts.
    """
    g = cfg.acc_depth
    k = products.shape[-1]
    pad = (-k) % g
    if pad:
        products = jnp.pad(products, [(0, 0)] * (products.ndim - 1) + [(0, pad)])
        signs = jnp.pad(signs, [(0, 0)] * (signs.ndim - 1) + [(0, pad)])
    ngroups = products.shape[-1] // g
    p = products.reshape(products.shape[:-1] + (ngroups, g)).astype(jnp.float32)
    s = signs.reshape(signs.shape[:-1] + (ngroups, g))

    pos = jnp.sum(jnp.where(s > 0, p, 0.0), axis=-1)
    neg = jnp.sum(jnp.where(s < 0, p, 0.0), axis=-1)
    if cfg.sigma_analog > 0.0:
        kp, kn = jax.random.split(key)
    else:
        kp = kn = None
    pos_r = readout_quantize(pos, cfg, kp)
    neg_r = readout_quantize(neg, cfg, kn)
    # NSC binary reduction of per-group readouts (exact digital adds).
    return jnp.sum(pos_r - neg_r, axis=-1)


# ---------------------------------------------------------------------------
# Device-level RC model (Fig 7 reproduction).
# ---------------------------------------------------------------------------

V_SAT = 1.1          # volts — bit-line/core supply rail
# Charge per accumulation event, calibrated so the paper's chosen 8 pF
# MOMCAP (tile-area-matched, 338 um^2) supports exactly 20 linear
# accumulations (paper §IV.B).
Q_STEP_FC = 22.0     # femto-coulombs per full 128-bit accumulation event
LINEARITY = 0.95     # a step counts as "linear" while dv >= 95% of dv0


def momcap_voltage_trace(c_pf: float, n_events: int) -> jnp.ndarray:
    """Voltage staircase for n accumulation events on a c_pf MOMCAP.

    Each event nominally adds dv0 = Q/C; as the cap charges toward the rail
    the increment compresses by (1 - v/V_SAT) — the saturation visible in
    paper Fig 7.
    """
    dv0 = (Q_STEP_FC * 1e-15) / (c_pf * 1e-12)

    def step(v, _):
        v_next = v + dv0 * (1.0 - v / V_SAT)
        return v_next, v_next

    _, trace = jax.lax.scan(step, 0.0, None, length=n_events)
    return trace


def max_linear_accumulations(c_pf: float) -> int:
    """Number of accumulation steps before the increment falls below
    LINEARITY * dv0 (closed form of the geometric compression)."""
    dv0 = (Q_STEP_FC * 1e-15) / (c_pf * 1e-12)
    x = dv0 / V_SAT
    return int(math.floor(math.log(LINEARITY) / math.log(1.0 - x)))
