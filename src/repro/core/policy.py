"""ArithmeticPolicy — the per-layer switchboard for the ARTEMIS ladder.

modes (paper Table IV columns):
  exact       fp32/bf16 reference                      (FP32)
  int8        int8 quant, exact int32 accumulation     (Q(8-bit))
  artemis     int8 + TCU floor-multiply + MOMCAP group (Q(8-bit) + SC)
              accumulation + readout quantization/noise + LUT nonlinearities
  artemis_mxu beyond-paper fast path: the ARTEMIS semantics approximated by
              two MXU int8 matmuls (value dot + sign dot bias correction)
              instead of per-product VPU emulation — see artemis_matmul.py.
"""
from __future__ import annotations

import dataclasses

MODES = ("exact", "int8", "artemis", "artemis_mxu")


@dataclasses.dataclass(frozen=True)
class ArithmeticPolicy:
    mode: str = "exact"
    # --- MOMCAP / readout (paper §III.A.2, §III.B) ---
    acc_depth: int = 20
    readout_bits: int | None = 8
    sigma_analog: float = 0.0
    # --- NSC LUTs (paper §III.C.2) ---
    lut_entries: int = 256
    lut_out_bits: int | None = 8
    # --- quantization ---
    act_quant_axis: tuple | None = None   # None -> per-tensor
    weight_quant_axis: tuple | None = None
    # --- training / integration ---
    ste: bool = True            # straight-through estimator for backprop
    apply_to_router: bool = False  # MoE router stays exact (Table-V-style
    # calibration shows routing logits are the most truncation-sensitive op)
    apply_to_state: bool = False   # SSM/RWKV recurrences stay >= bf16:
    # recurrent error accumulation violates the 20-acc independence premise
    # (DESIGN.md §Arch-applicability)
    rbar: float = 63.5          # E[(a*b) mod 128] for the MXU correction

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode must be one of {MODES}, got {self.mode!r}")

    def is_quantized(self) -> bool:
        return self.mode != "exact"


EXACT = ArithmeticPolicy(mode="exact")
INT8 = ArithmeticPolicy(mode="int8")
ARTEMIS = ArithmeticPolicy(mode="artemis")
ARTEMIS_MXU = ArithmeticPolicy(mode="artemis_mxu")
