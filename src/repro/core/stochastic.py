"""Deterministic stochastic (TCU) multiplication — paper §III.A.1.

ARTEMIS multiplies 8-bit magnitudes by AND-ing two 128-bit streams in the
DRAM bit-line logic:

  * operand 1 goes through a B_to_TCU decoder: transition-coded unary, all
    1s grouped at the trailing end -> bit i is set iff i < a;
  * operand 2 goes through B_to_TCU + a *bit-position correlation encoder*
    that spreads its 1s evenly across the 128 positions (so the conditional
    probability of operand-1 bits given operand-2 bits matches the marginal
    — the deterministic low-discrepancy construction of [15], [31]);
  * the product popcount is then popcount(tcu(a) & spread(b)).

The even spreading is the Bresenham construction: bit i of spread(b) is set
iff floor((i+1)*b/128) > floor(i*b/128).  AND-ing with the first `a`
positions counts exactly floor(a*b/128) set bits, which gives the closed
form used throughout the framework:

  sc_multiply(a, b) == floor(a * b / 128)   for a, b in [0, 127].

`tests/test_core_arithmetic.py` pins the bitstream emulation against the
closed form exhaustively over the full 128x128 operand square.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import SC_LEVELS

SC_BITS = SC_LEVELS  # 128-bit streams


def tcu_encode(m: jax.Array) -> jax.Array:
    """B_to_TCU decoder: magnitude m in [0,128] -> (..., 128) bool stream."""
    positions = jnp.arange(SC_BITS, dtype=jnp.int32)
    return positions < m[..., None]


def spread_encode(m: jax.Array) -> jax.Array:
    """Bit-position correlation encoder: evenly spread m ones over 128 bits."""
    i = jnp.arange(SC_BITS, dtype=jnp.int32)
    m = m[..., None].astype(jnp.int32)
    return ((i + 1) * m) // SC_BITS - (i * m) // SC_BITS > 0


def sc_multiply_bitstream(a: jax.Array, b: jax.Array) -> jax.Array:
    """Bit-level emulation: popcount(tcu(a) & spread(b)). For validation."""
    anded = jnp.logical_and(tcu_encode(a), spread_encode(b))
    return jnp.sum(anded.astype(jnp.int32), axis=-1)


def sc_multiply(a: jax.Array, b: jax.Array) -> jax.Array:
    """Closed form of the deterministic TCU multiply: floor(a*b/128).

    a, b: integer magnitudes in [0, 127] (any broadcastable shapes).
    """
    return (a.astype(jnp.int32) * b.astype(jnp.int32)) // SC_BITS


def sc_multiply_float(a: jax.Array, b: jax.Array) -> jax.Array:
    """float32 variant of the closed form (used inside Pallas kernel bodies,
    where float VPU math is preferred)."""
    return jnp.floor(a * b * (1.0 / SC_BITS))


def sc_truncation_error(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact truncation error of one SC multiply, in product units (1/128):
    (a*b mod 128)/128 in [0, 1). Used by the Table V calibration bench."""
    prod = a.astype(jnp.int32) * b.astype(jnp.int32)
    return (prod % SC_BITS).astype(jnp.float32) / SC_BITS
