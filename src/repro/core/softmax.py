"""Log-sum-exp softmax — paper Eq. (5) and §III.C.2 / §III.D.3.

ARTEMIS avoids in-DRAM division and numerical overflow by computing

  softmax(y)_i = exp(y_i - y_max - ln(sum_j exp(y_j - y_max)))

with three hardware tricks we mirror exactly:
  1. y_max is tracked *online* by a comparator as the QK^T MatMul streams
     out (the flash-attention online-max — see kernels/flash_attention);
  2. exp and ln are 8-bit NSC LUTs;
  3. the form is division-free.

The LSE decomposition is associative across shards, which is what makes the
token dataflow's distributed softmax (split-KV decode, ring attention)
exact — see repro.parallel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut


def lse_softmax(y: jax.Array, axis: int = -1) -> jax.Array:
    """Exact division-free log-sum-exp softmax (Eq. 5)."""
    y_max = jnp.max(y, axis=axis, keepdims=True)
    shifted = y - y_max
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=axis, keepdims=True))
    return jnp.exp(shifted - lse)


def artemis_softmax(
    y: jax.Array,
    axis: int = -1,
    n_in: int = 256,
    out_bits: int | None = 8,
) -> jax.Array:
    """Eq. 5 with the exp/ln steps routed through NSC LUT emulation."""
    y = y.astype(jnp.float32)
    y_max = jnp.max(y, axis=axis, keepdims=True)
    shifted = y - y_max                                   # <= 0
    lo = jax.lax.stop_gradient(jnp.minimum(jnp.min(shifted), -1.0))
    n = y.shape[axis]
    e = lut.lut_exp(shifted, lo, n_in=n_in, out_bits=out_bits)
    s = jnp.sum(e, axis=axis, keepdims=True)
    l = lut.lut_ln(jnp.maximum(s, 1.0), float(n), n_in=n_in, out_bits=out_bits)
    out = lut.lut_exp(shifted - l, lo - jnp.log(float(n)),
                      n_in=n_in, out_bits=out_bits)
    return out


def online_max_sum(y_blocks: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Streaming (max, sum-exp) over blocks along axis 0 — the comparator
    pipeline of §III.D.3, used as the reference for the flash kernel and the
    ring-attention merge rule.

    y_blocks: (n_blocks, ..., block) — returns (max, sumexp) over all blocks.
    """

    def step(carry, blk):
        m, s = carry
        m_new = jnp.maximum(m, jnp.max(blk, axis=-1))
        s_new = s * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(blk - m_new[..., None]), axis=-1
        )
        return (m_new, s_new), None

    first = y_blocks[0]
    m0 = jnp.full(first.shape[:-1], -jnp.inf, dtype=jnp.float32)
    s0 = jnp.zeros(first.shape[:-1], dtype=jnp.float32)
    (m, s), _ = jax.lax.scan(step, (m0, s0), y_blocks)
    return m, s
