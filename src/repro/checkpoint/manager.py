"""Fault-tolerant checkpointing (DESIGN.md §5).

Properties required at 1000-node scale, all implemented here:
  * ATOMIC   — write to `step_N.tmp/`, fsync, then rename; a crash mid-save
               never corrupts the latest valid checkpoint.
  * VERIFIED — per-leaf SHA-256 in a manifest; restore validates hashes, and
               a corrupt checkpoint falls back to the previous valid one.
  * ASYNC    — save runs on a background thread over host-transferred
               arrays; the train loop blocks only for the device->host copy
               (and `wait()` joins before the next save or process exit).
  * KEEP-K   — bounded disk usage; old steps garbage-collected after a new
               save commits.
  * RESHARD-ON-RESTORE — checkpoints store fully-replicated host arrays;
               `restore(..., like=...)` re-shards onto whatever mesh the
               restarted job has (elastic scaling: restart on a different
               topology works).

Storage layout:
  <dir>/step_000123/
    manifest.json   {step, leaf paths, shapes, dtypes, sha256, treedef}
    <leaf-idx>.npy  one file per leaf
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    save_every: int = 100
    async_save: bool = True


def _leaf_paths(tree) -> list[str]:
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save_pytree(tree, path: str) -> None:
    """Atomic, hash-manifested save of one pytree to `path` (a step dir)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    manifest = {"treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        fname = f"{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        with open(fpath, "wb") as f:
            np.save(f, arr)
            f.flush()
            os.fsync(f.fileno())
        with open(fpath, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"].append(
            {"file": fname, "shape": list(arr.shape),
             "dtype": str(arr.dtype), "sha256": digest})
    manifest["paths"] = _leaf_paths(tree)
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # the atomic commit point


def load_pytree(path: str, like=None):
    """Load + verify. `like` re-shards leaves onto its shardings/dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = []
    for entry in manifest["leaves"]:
        fpath = os.path.join(path, entry["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        if hashlib.sha256(raw).hexdigest() != entry["sha256"]:
            raise IOError(f"checkpoint corruption: {fpath}")
        arr = np.load(fpath)
        leaves.append(arr)
    if like is None:
        return leaves, manifest
    like_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(like_leaves) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected "
            f"{len(like_leaves)}")
    out = []
    for arr, ref in zip(leaves, like_leaves):
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf shape mismatch: {arr.shape} vs {ref.shape}")
        a = jnp.asarray(arr, dtype=ref.dtype)
        sharding = getattr(ref, "sharding", None)
        if sharding is not None:
            a = jax.device_put(a, sharding)   # reshard-on-restore
        out.append(a)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """keep-k, async, auto-resuming checkpoint manager."""

    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # -- discovery ----------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.cfg.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def _path(self, step: int) -> str:
        return os.path.join(self.cfg.directory, f"step_{step:09d}")

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        self.wait()
        # device->host transfer happens here (the only sync point)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def _do():
            try:
                save_pytree(host_tree, self._path(step))
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if self.cfg.async_save:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.cfg.keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def restore_latest(self, like):
        """Restore newest valid checkpoint; falls back past corrupt ones.

        Returns (step, tree) or (None, None) when nothing valid exists.
        """
        self.wait()
        for step in reversed(self.steps()):
            try:
                return step, load_pytree(self._path(step), like=like)
            except Exception:
                continue
        return None, None
