"""Pins for the serve-facing fixes in parallel/ring_attention.py and
parallel/split_kv.py (the mesh-serve PR's satellite): native GQA
(KV-head counts below the query-head count), sentinel masking under
non-causal attention, and caller-supplied kv positions travelling the
ring WITH their K/V chunk — the latent bugs the sharded paged backend
flushed out. Same 8-forced-host-devices setup as test_parallel.py."""
import os
import sys

if "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro.parallel import ring_attention, split_kv_attention  # noqa: E402
from repro.parallel.ring_attention import (  # noqa: E402
    _repeat_kv,
    layer_dataflow_attention,
)

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (run standalone or first)")

EMPTY = jnp.iinfo(jnp.int32).max


def _mesh():
    return jax.make_mesh((8,), ("sp",))


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _ref(q, k, v, causal=True):
    """Dense reference with KV heads repeated to the query heads."""
    k, v = _repeat_kv(q.shape[2], k, v)
    d = q.shape[-1]
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) * (1.0 / d**0.5)
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s_ = jnp.where(mask[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


class TestGQA:
    def test_repeat_kv_rejects_indivisible(self):
        k = v = jnp.zeros((1, 4, 3, 8))
        with pytest.raises(ValueError, match="multiple of KV heads"):
            _repeat_kv(4, k, v)

    def test_ring_attention_native_gqa(self):
        """KV heads < query heads go through the ring unrepeated: the
        helper expands them with the serve layer's grouping (q head i
        -> kv head i // g)."""
        b, s, h, kvh, d = 2, 64, 8, 2, 16
        q = _rand(0, (b, s, h, d))
        k = _rand(1, (b, s, kvh, d))
        v = _rand(2, (b, s, kvh, d))
        ref = _ref(q, k, v)
        fn = shard_map(
            lambda q, k, v: ring_attention(q, k, v, axis_name="sp"),
            mesh=_mesh(),
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_split_kv_native_gqa(self):
        b, s_cache, h, kvh, d = 2, 64, 4, 2, 8
        q = _rand(3, (b, 1, h, d))
        k = _rand(4, (b, s_cache, kvh, d))
        v = _rand(5, (b, s_cache, kvh, d))
        ref = _ref(q, k, v, causal=False)   # q at the last position
        q_pos = jnp.full((b, 1), s_cache - 1, jnp.int32)
        kv_pos = jnp.broadcast_to(
            jnp.arange(s_cache, dtype=jnp.int32)[None], (b, s_cache))
        fn = shard_map(
            lambda q, kl, vl, kp: split_kv_attention(
                q, kl, vl, axis_name="sp", q_positions=q_pos,
                kv_positions_local=kp),
            mesh=_mesh(),
            in_specs=(P(), P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P())
        out = jax.jit(fn)(q, k, v, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_layer_dataflow_native_gqa(self):
        b, s, h, kvh, d = 1, 64, 4, 2, 8
        q = _rand(6, (b, s, h, d))
        k = _rand(7, (b, s, kvh, d))
        v = _rand(8, (b, s, kvh, d))
        ref = _ref(q, k, v)
        fn = shard_map(
            lambda q, k, v: layer_dataflow_attention(q, k, v,
                                                     axis_name="sp"),
            mesh=_mesh(),
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSentinelMasking:
    def test_ring_non_causal_masks_empty_slots(self):
        """Regression: with causal=False the causal comparison used to
        be the ONLY masking, so INT32_MAX-position (unwritten) slots
        contributed garbage K/V to non-causal attention."""
        b, s, h, d = 1, 64, 2, 8
        valid = 40
        q = _rand(9, (b, s, h, d))
        k = _rand(10, (b, s, h, d))
        v = _rand(11, (b, s, h, d))
        kv_pos = jnp.where(jnp.arange(s) < valid, jnp.arange(s),
                           EMPTY).astype(jnp.int32)[None]
        kv_pos = jnp.broadcast_to(kv_pos, (b, s))
        ref = _ref(q, k[:, :valid], v[:, :valid], causal=False)
        fn = shard_map(
            lambda q, k, v, kp: ring_attention(
                q, k, v, axis_name="sp", causal=False,
                kv_positions=kp),
            mesh=_mesh(),
            in_specs=(P(None, "sp"),) * 3 + (P(None, "sp"),),
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_split_kv_non_causal_masks_empty_slots(self):
        """split_kv_attention grew the causal flag alongside the fix:
        non-causal decode over a partially-written cache view attends
        every VALID slot and none of the sentinels."""
        b, s_cache, h, d = 1, 32, 2, 8
        valid = 17
        q = _rand(12, (b, 1, h, d))
        k = _rand(13, (b, s_cache, h, d))
        v = _rand(14, (b, s_cache, h, d))
        kv_pos = jnp.where(jnp.arange(s_cache) < valid,
                           jnp.arange(s_cache), EMPTY)[None]
        kv_pos = jnp.broadcast_to(kv_pos, (b, s_cache)).astype(jnp.int32)
        # q "position" BELOW some valid slots: non-causal must still
        # attend all 17 valid slots
        q_pos = jnp.zeros((b, 1), jnp.int32)
        ref = _ref(q, k[:, :valid], v[:, :valid], causal=False)
        fn = shard_map(
            lambda q, kl, vl, kp: split_kv_attention(
                q, kl, vl, axis_name="sp", q_positions=q_pos,
                kv_positions_local=kp, causal=False),
            mesh=_mesh(),
            in_specs=(P(), P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P())
        out = jax.jit(fn)(q, k, v, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestRingPositionsTravel:
    def test_permuted_kv_layout(self):
        """Regression for the single-device assumption: caller-supplied
        kv_positions used to be applied to EVERY arriving ring chunk
        (only correct when all shards share one position vector). Now a
        chunk's positions ppermute around the ring with it, so an
        arbitrary (e.g. paged) position layout masks exactly."""
        b, s, h, d = 1, 64, 2, 8
        q = _rand(15, (b, s, h, d))
        k = _rand(16, (b, s, h, d))
        v = _rand(17, (b, s, h, d))
        ref = _ref(q, k, v, causal=True)
        # scatter the sequence across shards: slot j holds position
        # perm[j], different on every shard — the old code got this
        # wrong for every chunk except the locally-resident one
        perm = np.random.default_rng(0).permutation(s).astype(np.int32)
        k_perm = k[:, perm]
        v_perm = v[:, perm]
        kv_pos = jnp.broadcast_to(jnp.asarray(perm)[None], (b, s))
        q_pos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        fn = shard_map(
            lambda q, k, v, qp, kp: ring_attention(
                q, k, v, axis_name="sp", causal=True,
                q_positions=qp, kv_positions=kp),
            mesh=_mesh(),
            in_specs=(P(None, "sp"),) * 5,
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k_perm, v_perm, q_pos, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_kv_chunk_shorter_than_query_chunk(self):
        """The sharded paged prefill rings a gathered cache view whose
        per-shard length differs from the query chunk length — the ring
        must not assume S_q == S_k."""
        b, sq, sk, h, d = 1, 16, 64, 2, 8
        q = _rand(18, (b, sq, h, d))
        k = _rand(19, (b, sk, h, d))
        v = _rand(20, (b, sk, h, d))
        # queries sit at the LAST sq positions of the sk-long history
        q_pos = jnp.broadcast_to(
            (sk - sq + jnp.arange(sq, dtype=jnp.int32))[None], (b, sq))
        kv_pos = jnp.broadcast_to(
            jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
        d_scale = 1.0 / d**0.5
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) * d_scale
        mask = q_pos[0][:, None] >= jnp.arange(sk)[None, :]
        s_ = jnp.where(mask[None, None], s_, -1e30)
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1), v)
        fn = shard_map(
            lambda q, k, v, qp, kp: ring_attention(
                q, k, v, axis_name="sp", q_positions=qp,
                kv_positions=kp),
            mesh=_mesh(),
            in_specs=(P(None, "sp"),) * 5,
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v, q_pos, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
