"""Launch-layer integration tests on a real (tiny) mesh.

Lower + compile + EXECUTE smoke configs on a (2, 2) in-process mesh —
the same code path the 512-device dry-run exercises, plus actual
numerics: a sharded train step must match the single-device train step.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.data import DataConfig, make_batch
from repro.launch import specs as specslib
from repro.launch import steps as stepslib
from repro.launch.mesh import make_smoke_mesh
from repro.models import model
from repro.optim import OptimizerConfig, adamw_init
from repro.parallel import sharding as sh

pytestmark = pytest.mark.skipif(jax.device_count() < 4,
                                reason="needs 4 host devices")


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


@pytest.mark.parametrize("arch", ["qwen3_8b", "qwen2_moe_a2_7b",
                                  "rwkv6_3b"])
def test_sharded_train_step_matches_single_device(arch):
    cfg = configs.get_config(arch, smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rules = sh.ShardingRules()
    opt_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)

    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    batch = make_batch(cfg, DataConfig(seq_len=16, global_batch=4), 0)

    # single-device reference
    ref_step = jax.jit(stepslib.make_train_step(cfg, opt_cfg))
    p_ref, _, m_ref = ref_step(params, opt, batch)

    # sharded
    pspecs = sh.param_specs(cfg, params, mesh, rules)
    psh = _named(mesh, pspecs)
    osh = _named(mesh, {"m": pspecs, "v": pspecs,
                        "step": jax.sharding.PartitionSpec()})
    bsh = _named(mesh, sh.batch_specs(cfg, mesh, 4))
    step = jax.jit(
        stepslib.make_train_step(cfg, opt_cfg, mesh=mesh, rules=rules),
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh,
                       jax.sharding.NamedSharding(
                           mesh, jax.sharding.PartitionSpec())))
    params_s = jax.device_put(params, psh)
    opt_s = jax.device_put(opt, osh)
    batch_s = jax.device_put(batch, bsh)
    p_out, _, m_out = step(params_s, opt_s, batch_s)

    assert float(m_out["loss"]) == pytest.approx(float(m_ref["loss"]),
                                                 rel=2e-3)
    # parameters after one step agree (sharded == unsharded math)
    ref_leaves = jax.tree.leaves(p_ref)
    out_leaves = jax.tree.leaves(p_out)
    worst = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(ref_leaves, out_leaves))
    assert worst < 5e-3, worst


def test_sharded_decode_matches_single_device():
    cfg = configs.get_config("qwen3_8b", smoke=True)
    mesh = make_smoke_mesh(2, 2)
    rules = dataclasses.replace(sh.ShardingRules(), fsdp=False)
    b, s, max_len = 4, 12, 16

    params = model.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size, dtype=jnp.int32)
    cache = model.init_cache(cfg, b, max_len, dtype=jnp.float32)

    prefill = jax.jit(stepslib.make_prefill_step(cfg))
    decode = jax.jit(stepslib.make_decode_step(cfg))
    logits_ref, cache_ref = prefill(params, {"tokens": tokens[:, :-1]},
                                    cache)
    dec_ref, _ = decode(params, tokens[:, -1:], cache_ref)

    pspecs = sh.param_specs(cfg, params, mesh, rules)
    psh = _named(mesh, pspecs)
    csh = _named(mesh, sh.cache_specs(cfg, mesh, b, rules))
    tok_sh = _named(mesh, sh.batch_specs(cfg, mesh, b)["tokens"])
    logits_sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", "model"))
    prefill_s = jax.jit(
        stepslib.make_prefill_step(cfg, mesh=mesh, rules=rules),
        in_shardings=(psh, {"tokens": tok_sh}, csh),
        out_shardings=(logits_sh, csh))
    decode_s = jax.jit(
        stepslib.make_decode_step(cfg, mesh=mesh, rules=rules),
        in_shardings=(psh, tok_sh, csh),
        out_shardings=(logits_sh, csh))

    params_d = jax.device_put(params, psh)
    cache_d = jax.device_put(model.init_cache(cfg, b, max_len,
                                              dtype=jnp.float32), csh)
    _, cache_d = prefill_s(params_d, {"tokens": jax.device_put(
        tokens[:, :-1], tok_sh)}, cache_d)
    dec_out, _ = decode_s(params_d, jax.device_put(tokens[:, -1:], tok_sh),
                          cache_d)
    # bf16 compute with sharded (reassociated) contractions: ~2e-2 noise
    np.testing.assert_allclose(np.asarray(dec_out, np.float32),
                               np.asarray(dec_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_dryrun_cell_compiles_on_tiny_mesh():
    """The dry-run lowering path end-to-end on 4 devices (smoke config,
    reduced cell) — the in-process analogue of the 512-device sweep."""
    from repro.launch.dryrun import cost_analysis_dict, lower_cell
    cfg = configs.get_config("qwen3_8b", smoke=True)
    cell = configs.ShapeCell("t", 64, 4, "train")
    mesh = make_smoke_mesh(2, 2)
    lowered = lower_cell(cfg, cell, mesh, sh.ShardingRules(),
                         ArithmeticPolicy(), unroll=1)
    compiled = lowered.compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0


def test_input_specs_cover_all_kinds():
    for arch in ("qwen3_8b", "musicgen_large", "internvl2_1b",
                 "zamba2_7b"):
        cfg = configs.get_config(arch)  # FULL config, shapes only
        for shape in configs.runnable_shapes(arch):
            cell = configs.SHAPES[shape]
            ins = specslib.input_specs(cfg, cell)
            assert "params" in ins
            leaves = jax.tree.leaves(ins)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            if cell.kind == "decode":
                tok = ins["tokens"]
                assert tok.shape[1] == 1
