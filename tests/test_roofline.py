"""Roofline package tests: HLO collective parser + 3-term model."""
import pytest

from repro.roofline import HW_V5E, analyze, model_flops, parse_collectives
from repro import configs


HLO_SAMPLE = """
  %ar = f32[64,1024]{1,0} all-reduce(%dot), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
  %ag = f32[128,256]{1,0} all-gather(%x), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %rs = bf16[32,64]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[1,8]<=[8], to_apply=%add
  %cp = f32[16,16]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1},{1,0}}
  %ignored = f32[8,8]{1,0} add(%a, %b)
  %aa = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%p, %q), replica_groups={{0,1,2,3}}
"""


class TestParser:
    def test_counts_and_bytes(self):
        st = parse_collectives(HLO_SAMPLE)
        assert st.ops == {"all-reduce": 1, "all-gather": 1,
                          "reduce-scatter": 1, "collective-permute": 1,
                          "all-to-all": 1}
        # all-reduce operand = output = 64*1024*4
        assert st.bytes_by_kind["all-reduce"] == 64 * 1024 * 4
        # all-gather operand = output / group(4)
        assert st.bytes_by_kind["all-gather"] == 128 * 256 * 4 / 4
        # reduce-scatter operand = output * group(8)
        assert st.bytes_by_kind["reduce-scatter"] == 32 * 64 * 2 * 8
        # all-to-all tuple output: 2 tensors of 4x4 f32, group 4
        assert st.bytes_by_kind["all-to-all"] == 2 * 4 * 4 * 4

    def test_wire_weighting(self):
        st = parse_collectives(
            "%ar = f32[100]{0} all-reduce(%x), replica_groups=[1,4]<=[4],"
            " to_apply=%a")
        # ring AR: 2*(S-1)/S*size = 2*3/4*400
        assert st.wire_bytes == pytest.approx(2 * 0.75 * 400)

    def test_start_done_counted_once(self):
        txt = ("%s = f32[8]{0} all-gather-start(%x), replica_groups=[1,2]<=[2]\n"
               "%d = f32[8]{0} all-gather-done(%s)\n")
        st = parse_collectives(txt)
        assert st.ops.get("all-gather", 0) == 1

    def test_degenerate_group_skipped(self):
        st = parse_collectives(
            "%ar = f32[8]{0} all-reduce(%x), replica_groups=[8,1]<=[8],"
            " to_apply=%a")
        assert st.raw_bytes == 0


class TestModel:
    def test_three_terms(self):
        st = parse_collectives(HLO_SAMPLE)
        rep = analyze("a", "s", "m", 256,
                      {"flops": 1e15, "bytes accessed": 1e12}, st,
                      mflops=2.56e17, peak_bytes=8e9)
        assert rep.t_compute == pytest.approx(1e15 / HW_V5E.peak_flops)
        assert rep.t_memory == pytest.approx(1e12 / HW_V5E.hbm_bw)
        assert rep.dominant in ("compute", "memory", "collective")
        assert 0 < rep.useful_flop_fraction <= 1.01
        assert rep.step_time == max(rep.t_compute, rep.t_memory,
                                    rep.t_collective)

    def test_model_flops_dense_vs_moe(self):
        dense = configs.get_config("qwen3_8b")
        moe = configs.get_config("qwen2_moe_a2_7b")
        fd = model_flops(dense, 1024, "prefill", kv_len=1024)
        fm = model_flops(moe, 1024, "prefill", kv_len=1024)
        assert fd > 0 and fm > 0
        # MoE counts ACTIVE params only: far fewer than total
        assert moe.active_param_count() < moe.param_count() / 2

    def test_train_is_3x_forward(self):
        cfg = configs.get_config("gemma_2b")
        f_train = model_flops(cfg, 1000, "train", kv_len=1024)
        f_pref = model_flops(cfg, 1000, "prefill", kv_len=1024)
        assert f_train == pytest.approx(3.0 * f_pref)
