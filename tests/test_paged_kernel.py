"""Fused paged-attention parity suite.

Three altitudes, matching the seams the fused kernel crosses:

  kernel    `kernels.paged_attention` vs the explicit-gather oracle
            (`paged_attention_ref` — `_attn_core` semantics) across
            page sizes, GQA group counts, window on/off, chunk
            boundaries that straddle pages, and trash-page lanes.
  step      `make_paged_chunked_prefill` / `make_paged_decode` with
            the fused `paged_core` vs the default gather core —
            full-model logits at fp32 tolerance, multi-chunk prompts.
  engine    a full mixed greedy/sampled drain with a forced mid-flight
            preemption at `attn_impl="fused"` is TOKEN-IDENTICAL to
            the gather engine — the tentpole's acceptance pin.

Plus the `attn_impl` knob's validation/rejection surfaces (EngineConfig,
quantized policies, the sharded backend's make_backend-style error).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref)
from repro.models import model
from repro.serve import (EngineConfig, ServeEngine, TrafficConfig,
                         synth_trace)
from repro.serve.paged_model import (make_fused_paged_core,
                                     make_paged_chunked_prefill,
                                     make_paged_decode)
from repro.serve.request import RequestState

TOL = dict(rtol=2e-5, atol=2e-5)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# kernel-level parity vs the gather oracle
# ---------------------------------------------------------------------------


class TestPagedKernelParity:
    def _operands(self, seed, *, b, s, h, kvh, hd, npages, page, pmax,
                  starts):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = _rand(ks[0], (b, s, h, hd))
        kp = _rand(ks[1], (npages, page, kvh, hd))
        vp = _rand(ks[2], (npages, page, kvh, hd))
        bt = jax.random.randint(ks[3], (b, pmax), 0, npages, jnp.int32)
        pos = (jnp.asarray(starts, jnp.int32)[:, None]
               + jnp.arange(s, dtype=jnp.int32)[None])
        return q, kp, vp, bt, pos

    @pytest.mark.parametrize("page", [4, 8])
    @pytest.mark.parametrize("h,kvh", [(4, 4), (8, 2), (4, 1)])
    @pytest.mark.parametrize("window", [None, 3])
    def test_matches_gather_oracle(self, page, h, kvh, window):
        # starts straddle page boundaries (none page-aligned), rows at
        # different depths of their tables
        q, kp, vp, bt, pos = self._operands(
            page * 31 + h, b=3, s=7, h=h, kvh=kvh, hd=16, npages=12,
            page=page, pmax=5, starts=[0, page - 1, 2 * page + 1])
        o = paged_attention(q, kp, vp, bt, pos, window=window)
        r = paged_attention_ref(q, kp, vp, bt, pos, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **TOL)

    def test_decode_shape(self):
        # S == 1 (the decode step) with per-lane depths incl. lane 0
        q, kp, vp, bt, pos = self._operands(
            5, b=3, s=1, h=8, kvh=2, hd=16, npages=10, page=4, pmax=5,
            starts=[5, 0, 19])
        o = paged_attention(q, kp, vp, bt, pos)
        r = paged_attention_ref(q, kp, vp, bt, pos)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r), **TOL)

    def test_chunk_straddles_page_boundary(self):
        # a 6-token chunk crossing from page j to page j+1 mid-chunk
        page = 4
        q, kp, vp, bt, pos = self._operands(
            7, b=2, s=6, h=4, kvh=2, hd=8, npages=8, page=page, pmax=4,
            starts=[page - 2, 2 * page - 3])
        for window in (None, 2):
            o = paged_attention(q, kp, vp, bt, pos, window=window)
            r = paged_attention_ref(q, kp, vp, bt, pos, window=window)
            np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                       **TOL)

    def test_trash_page_lanes_never_contribute(self):
        """Unused table slots hold the trash page (0); whatever sits
        there must not leak into valid queries.  Two pools differing
        ONLY in trash-page contents must agree on every valid row."""
        b, s, h, kvh, hd, page, pmax = 2, 4, 4, 2, 8, 4, 4
        ks = jax.random.split(jax.random.PRNGKey(11), 4)
        q = _rand(ks[0], (b, s, h, hd))
        kp = _rand(ks[1], (6, page, kvh, hd))
        vp = _rand(ks[2], (6, page, kvh, hd))
        # row 0: 2 real pages + 2 trash slots; row 1: idle lane (all
        # trash, positions parked at 0 — the engine's inactive shape)
        bt = jnp.asarray([[1, 2, 0, 0], [0, 0, 0, 0]], jnp.int32)
        pos = jnp.asarray([[4, 5, 6, 7], [0, 0, 0, 0]], jnp.int32)
        poisoned_k = kp.at[0].set(1e3)
        poisoned_v = vp.at[0].set(1e3)
        o = paged_attention(q, kp, vp, bt, pos)
        op = paged_attention(q, poisoned_k, poisoned_v, bt, pos)
        # valid row unaffected by trash contents
        np.testing.assert_allclose(np.asarray(o[0]), np.asarray(op[0]),
                                   rtol=0, atol=0)
        # and it matches the oracle
        r = paged_attention_ref(q, kp, vp, bt, pos)
        np.testing.assert_allclose(np.asarray(o[0]), np.asarray(r[0]),
                                   **TOL)
        # idle lane output is finite garbage, never NaN/inf
        assert np.isfinite(np.asarray(o[1])).all()

    def test_shape_validation(self):
        q, kp, vp, bt, pos = self._operands(
            3, b=2, s=4, h=4, kvh=2, hd=8, npages=6, page=4, pmax=3,
            starts=[0, 1])
        with pytest.raises(ValueError, match="multiple"):
            paged_attention(q[:, :, :3], kp, vp, bt, pos)
        with pytest.raises(ValueError, match="batch mismatch"):
            paged_attention(q, kp, vp, bt[:1], pos)
        with pytest.raises(ValueError, match="window"):
            paged_attention(q, kp, vp, bt, pos, window=0)


# ---------------------------------------------------------------------------
# step-level parity: fused paged_core vs the default gather core
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _smoke(attn_window: int = 0):
    cfg = dataclasses.replace(configs.get_config("qwen3_8b", smoke=True),
                              compute_dtype="float32",
                              attn_window=attn_window)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _fresh_kv(cfg, n_pages, page):
    shape = (cfg.n_layers, n_pages, page, cfg.n_kv_heads,
             cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, jnp.float32),
            "v": jnp.zeros(shape, jnp.float32)}


@pytest.mark.parametrize("attn_window", [0, 6])
def test_fused_steps_match_gather_logits(attn_window):
    """Two prefill chunks + one decode round, fused vs gather, same
    pool/tables — full-model logits agree at fp32 tolerance on every
    valid row (the engine only ever reads valid rows)."""
    cfg, params = _smoke(attn_window)
    policy = ArithmeticPolicy()
    page, n_pages, pmax, b, chunk = 4, 16, 4, 2, 6
    fused = make_fused_paged_core(cfg, policy)
    builders = {
        "gather": (make_paged_chunked_prefill(cfg, policy),
                   make_paged_decode(cfg, policy)),
        "fused": (make_paged_chunked_prefill(cfg, policy,
                                             paged_core=fused),
                  make_paged_decode(cfg, policy, paged_core=fused)),
    }
    rng = np.random.default_rng(0)
    # row 0: 9-token prompt split 6+3 across two chunks (pages 1-3);
    # row 1: 5-token prompt in one chunk (pages 4-5), idle in chunk 2
    toks1 = jnp.asarray(rng.integers(2, cfg.vocab_size, (b, chunk)),
                        jnp.int32)
    toks2 = jnp.asarray(rng.integers(2, cfg.vocab_size, (b, chunk)),
                        jnp.int32)
    dtok = jnp.asarray(rng.integers(2, cfg.vocab_size, (b, 1)), jnp.int32)
    bt = jnp.asarray([[1, 2, 3, 0], [4, 5, 0, 0]], jnp.int32)
    zeros = jnp.zeros((b,), jnp.int32)
    out = {}
    for name, (prefill, decode) in builders.items():
        kv = _fresh_kv(cfg, n_pages, page)
        l1, kv = prefill(params, toks1, kv, bt,
                         zeros, jnp.asarray([6, 5], jnp.int32),
                         jnp.asarray([True, True]), zeros)
        l2, kv = prefill(params, toks2, kv, bt,
                         jnp.asarray([6, 0], jnp.int32),
                         jnp.asarray([3, 0], jnp.int32),
                         jnp.asarray([True, False]), zeros)
        l3, kv = decode(params, dtok, kv, bt,
                        jnp.asarray([9, 5], jnp.int32),
                        jnp.asarray([True, True]))
        out[name] = (np.asarray(l1[0, :6]), np.asarray(l1[1, :5]),
                     np.asarray(l2[0, :3]), np.asarray(l3))
    for got, want in zip(out["fused"], out["gather"]):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_fused_greedy_tokens_match_gather():
    """Argmax over the step logits (what greedy decode consumes) is
    bit-identical fused vs gather on the same inputs."""
    cfg, params = _smoke()
    policy = ArithmeticPolicy()
    fused = make_fused_paged_core(cfg, policy)
    page, n_pages = 4, 16
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(2, cfg.vocab_size, (2, 1)), jnp.int32)
    bt = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)
    lens = jnp.asarray([7, 2], jnp.int32)
    active = jnp.asarray([True, True])
    kv = _fresh_kv(cfg, n_pages, page)
    kv["k"] = kv["k"].at[:, 1:4].set(
        _rand(jax.random.PRNGKey(8),
              kv["k"][:, 1:4].shape))
    kv["v"] = kv["v"].at[:, 1:4].set(
        _rand(jax.random.PRNGKey(9),
              kv["v"][:, 1:4].shape))
    lg, _ = make_paged_decode(cfg, policy)(
        params, toks, {k: v.copy() for k, v in kv.items()}, bt, lens,
        active)
    lf, _ = make_paged_decode(cfg, policy, paged_core=fused)(
        params, toks, {k: v.copy() for k, v in kv.items()}, bt, lens,
        active)
    assert jnp.array_equal(jnp.argmax(lg, -1), jnp.argmax(lf, -1))


# ---------------------------------------------------------------------------
# engine-level conformance: attn_impl="fused" drain token identity
# ---------------------------------------------------------------------------


def _engine(attn_impl, **overrides):
    cfg, params = _smoke()
    kw = dict(page_size=8, n_pages=64, max_batch=3, max_pages_per_seq=8,
              prefill_chunk=8, cache_dtype="float32",
              attn_impl=attn_impl)
    kw.update(overrides)
    return ServeEngine(cfg, params=params, ecfg=EngineConfig(**kw))


def test_fused_drain_matches_gather_token_identically():
    """The tentpole's acceptance pin: draining the SAME mixed
    greedy/sampled trace — with a forced mid-flight preemption — at
    attn_impl="fused" produces byte-identical token streams to the
    gather-path engine."""
    cfg, _ = _smoke()
    trace = synth_trace(TrafficConfig(
        n_requests=5, arrival_rate=1e8, prompt_len_min=3,
        prompt_len_max=18, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=61, sampled_fraction=0.5,
        temperature=0.9, top_k=24, top_p=0.95))

    def drain(attn_impl):
        eng = _engine(attn_impl)
        eng.submit_trace(trace)
        preempted = False
        for _ in range(600):
            if not preempted:
                decoding = [r for r in eng.requests.values()
                            if r.state is RequestState.DECODE]
                if decoding:
                    eng._preempt(decoding[0])
                    preempted = True
            if eng.step() is None:
                break
        eng.drain()
        assert preempted, "trace never reached a preemptable decode"
        eng.backend.check_invariants()
        return {i: eng.results()[i].tolist() for i in range(len(trace))}

    assert drain("fused") == drain("gather"), (
        "fused drain diverged from the gather-path reference")


# ---------------------------------------------------------------------------
# knob validation / rejection surfaces
# ---------------------------------------------------------------------------


def test_engine_config_rejects_unknown_attn_impl():
    with pytest.raises(ValueError, match="attn_impl"):
        EngineConfig(attn_impl="bogus")


def test_fused_core_rejects_quantized_policy():
    cfg, _ = _smoke()
    with pytest.raises(ValueError, match="quantized"):
        make_fused_paged_core(cfg, ArithmeticPolicy(mode="int8"))


def test_sharded_backend_rejects_fused():
    """Fused + TP is rejected with the make_backend-style error, not a
    silent fallback."""
    if jax.device_count() < 8:
        pytest.skip(f"needs 8 devices, have {jax.device_count()}")
    with pytest.raises(ValueError, match="attn_impl='gather' or "
                                         "mesh_shards=1"):
        _engine("fused", mesh_shards=8)
