"""Unit + statistics suite for the pure batched sampler.

`repro.serve.sampler.sample_tokens` is the one compiled sampler every
engine token goes through; this module pins its semantics in
isolation: greedy/argmax convergence, top-k and top-p truncation on
hand-built logits, parameter validation, RNG-lane batch invariance
(lane result is a pure function of (seed, position) — never the batch
around it), and a seeded chi-square check that sampled frequencies
match the softmax distribution. Engine-level conformance (batch
composition, preemption replay, mixed greedy/sampled traffic over
both sequence backends) lives in tests/test_serve_backend.py.
"""
import numpy as np
import pytest

from repro.launch import steps as stepslib
from repro.serve import SamplingParams, sample_tokens

VOCAB = 16


def _sample(logits, temperature=1.0, top_k=0, top_p=1.0, seed=0, pos=None):
    """Row-wise convenience wrapper: scalars broadcast over the batch."""
    logits = np.asarray(logits, np.float32)
    b = logits.shape[0]
    full = np.full
    if pos is None:
        pos = np.arange(b, dtype=np.int32)
    return np.asarray(sample_tokens(
        logits, full(b, temperature, np.float32),
        full(b, top_k, np.int32), full(b, top_p, np.float32),
        full(b, seed, np.uint32), np.asarray(pos, np.int32)))


def _rand_logits(n, vocab=VOCAB, seed=0):
    return np.random.default_rng(seed).normal(
        size=(n, vocab)).astype(np.float32)


# ---------------------------------------------------------------------------
# greedy / argmax convergence
# ---------------------------------------------------------------------------


def test_temperature_zero_is_exactly_greedy_sample():
    """The greedy fast path is bit-identical to the pre-sampling
    `greedy_sample` argmax (the anchor every token-identity suite in
    the repo leans on)."""
    logits = _rand_logits(8, seed=3)
    got = _sample(logits, temperature=0.0, top_k=7, top_p=0.5, seed=99)
    ref = np.asarray(stepslib.greedy_sample(logits))
    np.testing.assert_array_equal(got, ref)


def test_temperature_to_zero_converges_to_argmax():
    """As temperature -> 0 the sampled draw converges to argmax: with
    a >=1-logit gap, t=0.01 scales the gap to 100, far beyond any
    plausible Gumbel perturbation."""
    rng = np.random.default_rng(7)
    logits = rng.normal(size=(16, VOCAB)).astype(np.float32)
    logits[np.arange(16), rng.integers(0, VOCAB, 16)] += 5.0
    ref = np.argmax(logits, axis=-1)
    for t in (0.01, 0.003, 0.0):
        np.testing.assert_array_equal(
            _sample(logits, temperature=t, seed=5), ref)


def test_top_k_one_equals_argmax():
    logits = _rand_logits(16, seed=11)
    for t in (0.5, 1.0, 2.0):
        np.testing.assert_array_equal(
            _sample(logits, temperature=t, top_k=1, seed=21),
            np.argmax(logits, axis=-1))


def test_top_k_restricts_support():
    """With top_k=3 every draw lands in the 3 largest logits."""
    row = np.log(np.linspace(1.0, 9.0, VOCAB)).astype(np.float32)
    logits = np.tile(row, (512, 1))
    toks = _sample(logits, temperature=1.5, top_k=3, seed=2)
    top3 = set(np.argsort(row)[-3:].tolist())
    assert set(toks.tolist()) <= top3
    assert len(set(toks.tolist())) > 1, "top-k support collapsed"


# ---------------------------------------------------------------------------
# top-p (nucleus) mass cutoff on hand-built logits
# ---------------------------------------------------------------------------


def test_top_p_mass_cutoff_hand_built():
    """probs (0.5, 0.25, 0.15, 0.1): top_p=0.6 keeps the minimal
    descending set reaching 0.6 mass = {0, 1} and nothing else;
    top_p=0.8 adds token 2; top_p=0.45 keeps only the top token."""
    row = np.log(np.array([0.5, 0.25, 0.15, 0.1], np.float32))
    logits = np.tile(row, (512, 1))
    for top_p, allowed in ((0.45, {0}), (0.6, {0, 1}), (0.8, {0, 1, 2}),
                           (1.0, {0, 1, 2, 3})):
        toks = _sample(logits, temperature=1.0, top_p=top_p, seed=6)
        got = set(toks.tolist())
        assert got <= allowed, f"top_p={top_p} leaked {got - allowed}"
        if top_p >= 0.6:
            assert len(got) > 1, f"top_p={top_p} support collapsed"


def test_top_p_always_keeps_top_token():
    """Even a top_p below the top token's own probability keeps it
    (its exclusive cumulative mass is 0 < top_p), so sampling never
    lands on an empty support."""
    row = np.log(np.array([0.9, 0.06, 0.04], np.float32))
    toks = _sample(np.tile(row, (64, 1)), temperature=1.0, top_p=0.05,
                   seed=8)
    assert set(toks.tolist()) == {0}


def test_top_k_then_top_p_compose():
    """top_p is applied to the top-k-truncated distribution: with
    top_k=2 over (0.4, 0.3, 0.2, 0.1) the renormalized probs are
    (4/7 ~ 0.57, 3/7), so top_p=0.5 keeps only token 0 — while
    without the top-k (token 1's exclusive mass is 0.4 < 0.5) it
    keeps {0, 1}."""
    row = np.log(np.array([0.4, 0.3, 0.2, 0.1], np.float32))
    logits = np.tile(row, (256, 1))
    both = _sample(logits, temperature=1.0, top_k=2, top_p=0.5, seed=9)
    assert set(both.tolist()) == {0}
    p_only = _sample(logits, temperature=1.0, top_p=0.5, seed=9)
    assert set(p_only.tolist()) == {0, 1}


# ---------------------------------------------------------------------------
# SamplingParams validation
# ---------------------------------------------------------------------------


def test_invalid_params_raise():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    for bad_p in (0.0, -0.2, 1.5):
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=bad_p)
    for bad_seed in (-1, 2 ** 32):
        with pytest.raises(ValueError, match="seed"):
            SamplingParams(seed=bad_seed)
    # the full surface is one valid object
    sp = SamplingParams(temperature=0.7, top_k=40, top_p=0.9, seed=7)
    assert not sp.greedy
    assert SamplingParams().greedy
    # greedy is the temperature=0 fast path regardless of truncation
    assert SamplingParams(temperature=0.0, top_k=5, top_p=0.5).greedy


# ---------------------------------------------------------------------------
# RNG lanes: batch invariance + determinism
# ---------------------------------------------------------------------------


def test_lane_is_batch_invariant():
    """A lane's draw is a pure function of (its logits, its params,
    its seed, its position): sampling a row alone must give exactly
    the token it gets packed in a batch — the property the engine's
    whole sampled-determinism story reduces to."""
    logits = _rand_logits(6, seed=13)
    temp = np.array([0.0, 0.9, 1.3, 0.7, 1.0, 0.5], np.float32)
    top_k = np.array([0, 5, 0, 3, 0, 0], np.int32)
    top_p = np.array([1.0, 0.9, 0.7, 1.0, 0.8, 1.0], np.float32)
    seed = np.array([0, 7, 7, 11, 3, 3], np.uint32)
    pos = np.array([0, 4, 4, 2, 9, 9], np.int32)
    batch = np.asarray(sample_tokens(logits, temp, top_k, top_p, seed, pos))
    for i in range(6):
        alone = np.asarray(sample_tokens(
            logits[i:i + 1], temp[i:i + 1], top_k[i:i + 1],
            top_p[i:i + 1], seed[i:i + 1], pos[i:i + 1]))
        assert alone[0] == batch[i], f"lane {i} depends on its batch"


def test_same_seed_position_replays_same_token():
    """Replay: the draw for (seed, pos) is stable across calls — the
    property recompute-style preemption recovery relies on."""
    logits = _rand_logits(4, seed=17)
    a = _sample(logits, temperature=1.0, seed=42, pos=[3, 3, 5, 5])
    b = _sample(logits, temperature=1.0, seed=42, pos=[3, 3, 5, 5])
    np.testing.assert_array_equal(a, b)
    # identical (logits, params, seed, pos) lanes draw identically
    assert a[0] == a[1] and a[2] == a[3]


def test_distinct_seeds_and_positions_decorrelate():
    """Different seeds (and different positions under one seed) give
    different streams — near-uniform logits, 64 draws each."""
    logits = np.tile(_rand_logits(1, seed=19) * 0.1, (64, 1))
    s1 = _sample(logits, temperature=1.0, seed=1)
    s2 = _sample(logits, temperature=1.0, seed=2)
    assert s1.tolist() != s2.tolist()
    same_pos = _sample(logits, temperature=1.0, seed=1,
                       pos=np.zeros(64, np.int32))
    assert len(set(same_pos.tolist())) == 1, \
        "position did not enter the key"
    assert len(set(s1.tolist())) > 4, "positions did not decorrelate"


# ---------------------------------------------------------------------------
# distribution-level statistics
# ---------------------------------------------------------------------------


def test_chi_square_frequencies_match_softmax():
    """Seeded chi-square: ~2k draws from a fixed 8-token softmax. The
    draw stream is deterministic (seed + positions fixed), so this is
    a regression pin, not a flaky tolerance: chi2 stays under the
    p=0.0005 tail of chi2(df=7) ~ 26.0."""
    rng = np.random.default_rng(23)
    row = rng.normal(size=8).astype(np.float32)
    probs = np.exp(row) / np.exp(row).sum()
    n = 2048
    toks = _sample(np.tile(row, (n, 1)), temperature=1.0, seed=31)
    counts = np.bincount(toks, minlength=8)
    expected = probs * n
    chi2 = float(((counts - expected) ** 2 / expected).sum())
    assert chi2 < 26.0, f"chi2={chi2:.2f}, counts={counts.tolist()}"


def test_temperature_flattens_distribution():
    """Higher temperature spreads mass: the argmax token's frequency
    at t=2.5 is strictly below its frequency at t=0.6."""
    rng = np.random.default_rng(29)
    row = rng.normal(size=8).astype(np.float32) * 2.0
    n = 1024
    top = int(np.argmax(row))
    freq = {}
    for t in (0.6, 2.5):
        toks = _sample(np.tile(row, (n, 1)), temperature=t, seed=37)
        freq[t] = (toks == top).mean()
    assert freq[2.5] < freq[0.6]
