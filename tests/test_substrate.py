"""Substrate tests: optimizer, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointConfig, CheckpointManager, \
    load_pytree, save_pytree
from repro.data import DataConfig, make_batch, synthetic_task_batch
from repro.optim import OptimizerConfig, adamw_init, adamw_update, \
    cosine_schedule, global_norm

jax.config.update("jax_platform_name", "cpu")


class TestOptimizer:
    def test_quadratic_convergence(self):
        """AdamW must optimize a simple quadratic to near zero."""
        params = {"w": jnp.array([5.0, -3.0, 2.0])}
        cfg = OptimizerConfig(lr=0.3, warmup_steps=5, total_steps=200,
                              weight_decay=0.0, clip_norm=100.0)
        state = adamw_init(params)
        for _ in range(200):
            grads = {"w": 2.0 * params["w"]}
            params, state, _ = adamw_update(params, grads, state, cfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2

    def test_schedule_shape(self):
        cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              min_lr_frac=0.1)
        lr0 = float(cosine_schedule(cfg, jnp.int32(0)))
        lr_w = float(cosine_schedule(cfg, jnp.int32(10)))
        lr_end = float(cosine_schedule(cfg, jnp.int32(100)))
        assert lr0 < 0.2
        assert abs(lr_w - 1.0) < 1e-6
        assert abs(lr_end - 0.1) < 1e-2

    def test_clipping(self):
        params = {"w": jnp.zeros(4)}
        cfg = OptimizerConfig(lr=1.0, clip_norm=1.0, warmup_steps=0,
                              weight_decay=0.0)
        state = adamw_init(params)
        _, _, m = adamw_update(params, {"w": jnp.full(4, 100.0)}, state,
                               cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_global_norm(self):
        t = {"a": jnp.ones(4), "b": jnp.ones((2, 6))}
        assert float(global_norm(t)) == pytest.approx(4.0)


class TestData:
    def test_determinism_and_restart(self):
        cfg = configs.get_config("qwen3_8b", smoke=True)
        dcfg = DataConfig(seed=7, seq_len=32, global_batch=4)
        b1 = make_batch(cfg, dcfg, 123)
        b2 = make_batch(cfg, dcfg, 123)   # restart at same step
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
        b3 = make_batch(cfg, dcfg, 124)
        assert not np.array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b3["tokens"]))

    def test_host_sharding_disjoint(self):
        cfg = configs.get_config("qwen3_8b", smoke=True)
        a = make_batch(cfg, DataConfig(seq_len=16, global_batch=8,
                                       host_id=0, n_hosts=2), 5)
        b = make_batch(cfg, DataConfig(seq_len=16, global_batch=8,
                                       host_id=1, n_hosts=2), 5)
        assert a["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))

    def test_labels_shifted(self):
        cfg = configs.get_config("qwen3_8b", smoke=True)
        b = make_batch(cfg, DataConfig(seq_len=16, global_batch=2), 0)
        np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                      np.asarray(b["tokens"][:, 1:]))

    @pytest.mark.parametrize("task", ["copy", "reverse", "sort", "modadd"])
    def test_tasks_well_formed(self, task):
        tokens, mask = synthetic_task_batch(jax.random.PRNGKey(0), task,
                                            4, 8, 32)
        assert tokens.shape == (4, 17)
        assert mask.shape == (4, 17)
        assert float(jnp.sum(mask)) == 4 * 8
        if task == "copy":
            np.testing.assert_array_equal(np.asarray(tokens[:, :8]),
                                          np.asarray(tokens[:, 9:]))
        if task == "sort":
            tgt = np.asarray(tokens[:, 9:])
            assert (np.diff(tgt, axis=1) >= 0).all()


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"w": jax.random.normal(k, (8, 4)),
                "b": jnp.arange(3.0),
                "step": jnp.int32(7)}

    def test_roundtrip(self, tmp_path):
        tree = self._tree()
        path = str(tmp_path / "step_1")
        save_pytree(tree, path)
        like = jax.tree.map(jnp.zeros_like, tree)
        out = load_pytree(path, like=like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_corruption_detected_and_fallback(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                                 async_save=False))
        t1, t2 = self._tree(1), self._tree(2)
        mgr.save(1, t1)
        mgr.save(2, t2)
        # corrupt the newest checkpoint
        victim = os.path.join(str(tmp_path), "step_000000002", "00000.npy")
        with open(victim, "r+b") as f:
            f.seek(10)
            f.write(b"\xde\xad\xbe\xef")
        step, out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t1))
        assert step == 1   # fell back past the corrupt one
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(t1["w"]))

    def test_keep_k_gc(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path), keep=2,
                                                 async_save=False))
        for s in (1, 2, 3, 4):
            mgr.save(s, self._tree(s))
        assert mgr.steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                                 async_save=True))
        t = self._tree()
        mgr.save(5, t)
        mgr.wait()
        step, out = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
        assert step == 5

    def test_atomicity_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(CheckpointConfig(str(tmp_path),
                                                 async_save=False))
        mgr.save(1, self._tree())
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_shape_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "step_9")
        save_pytree({"w": jnp.zeros((4,))}, path)
        with pytest.raises(ValueError):
            load_pytree(path, like={"w": jnp.zeros((5,))})
