"""Test-session config: force 8 host devices BEFORE jax initializes.

The parallel-layer tests (ring attention, split-KV, compression) and the
launch integration tests need a multi-device mesh; 8 CPU devices cover
them while keeping single-device semantics for everything else (jit
without shardings still places on device 0). The production 512-device
count is dry-run-only (never set here — brief requirement).
"""
import os
import sys

if "jax" not in sys.modules:
    flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=8").strip()

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
