"""Serve-mesh seam tests: the strict single-device no-op, device
placement on real multi-device meshes, mesh routing, the mesh-aware
cost model, and the "engine/scheduler never branch on the mesh"
source-level contract.

The conformance behavior of the sharded backend itself (token
identity, preemption, sampling) lives in tests/test_serve_backend.py's
parametrized suite; this module pins the seam's mechanics.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.hwsim import DataflowConfig, simulate_model
from repro.models import model
from repro.serve import (
    EngineConfig,
    ArtemisCostModel,
    ServeEngine,
    ServeMesh,
    ShardedPagedBackend,
    Tracer,
    make_backend,
    make_serve_mesh,
)
from repro.serve.mesh import kv_pool_sharding, param_shardings, replicated

needs8 = pytest.mark.skipif(jax.device_count() < 8,
                            reason="needs 8 (simulated) devices")


def _cfg(arch="qwen3_8b"):
    return dataclasses.replace(configs.get_config(arch, smoke=True),
                               compute_dtype="float32")


def _engine(shards, arch="qwen3_8b", **overrides):
    cfg = _cfg(arch)
    kw = dict(page_size=8, n_pages=64, max_batch=3, max_pages_per_seq=8,
              prefill_chunk=8, cache_dtype="float32", mesh_shards=shards)
    kw.update(overrides)
    return ServeEngine(cfg, ecfg=EngineConfig(**kw), seed=0)


# ---------------------------------------------------------------------------
# the ServeMesh value + single-device no-op
# ---------------------------------------------------------------------------


def test_single_mesh_is_strict_noop():
    mesh = make_serve_mesh(1)
    assert mesh == ServeMesh()
    assert mesh.is_single and mesh.handle is None
    cfg = _cfg()
    params = model.init(jax.random.PRNGKey(0), cfg)
    # every placement helper is None: the single-device path never
    # device_puts, so it is bit-identical to the pre-mesh code
    assert param_shardings(mesh, cfg, params) is None
    assert kv_pool_sharding(mesh, cfg) is None
    assert replicated(mesh) is None


def test_mesh_validation():
    with pytest.raises(ValueError, match="n_shards"):
        make_serve_mesh(0)
    with pytest.raises(ValueError, match="n_shards"):
        ServeMesh(n_shards=0)
    # the handle-iff-multi invariant holds both ways
    with pytest.raises(ValueError, match="handle"):
        ServeMesh(n_shards=2)
    with pytest.raises(ValueError, match="handle"):
        ServeMesh(n_shards=1, handle=object())
    with pytest.raises(ValueError, match="mesh_shards"):
        EngineConfig(mesh_shards=0)


@needs8
def test_multi_mesh_carries_handle():
    mesh = make_serve_mesh(4)
    assert not mesh.is_single
    assert mesh.n_shards == 4 and mesh.axis == "model"
    assert mesh.handle is not None
    assert tuple(mesh.handle.axis_names) == ("model",)


# ---------------------------------------------------------------------------
# device placement
# ---------------------------------------------------------------------------


@needs8
def test_kv_pool_partitioned_on_heads_when_divisible():
    """smoke qwen3 has 2 KV heads: a 2-way mesh partitions the pool's
    KV-head axis (genuine per-shard K/V), an 8-way mesh replicates it
    (8 does not divide 2) and parallelism comes from the dataflow
    attention core instead."""
    eng2 = _engine(2)
    spec2 = eng2.backend.cache.kv["k"].sharding.spec
    assert tuple(spec2) == (None, None, None, "model", None)
    eng8 = _engine(8)
    spec8 = eng8.backend.cache.kv["k"].sharding.spec
    assert all(ax is None for ax in spec8)


@needs8
def test_params_committed_to_mesh():
    eng = _engine(2)
    leaves = jax.tree_util.tree_leaves(eng.backend.params)
    assert any(
        any(ax == "model" for ax in leaf.sharding.spec)
        for leaf in leaves
        if hasattr(leaf.sharding, "spec")), \
        "no parameter carries a model-axis sharding on a 2-way mesh"


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


@needs8
def test_engine_threads_mesh_to_backend():
    eng = _engine(2)
    assert isinstance(eng.backend, ShardedPagedBackend)
    assert eng.mesh.n_shards == 2
    assert eng.backend.mesh is eng.mesh
    assert eng.cost.n_shards == 2


@needs8
def test_sharded_backend_rejects_single_mesh():
    cfg = _cfg()
    params = model.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="multi-shard"):
        ShardedPagedBackend(cfg, EngineConfig(), None, params,
                            Tracer(), lambda: 0.0,
                            mesh=make_serve_mesh(1))


@needs8
def test_slot_family_has_no_multidevice_backend():
    cfg = dataclasses.replace(configs.get_config("rwkv6_3b", smoke=True),
                              compute_dtype="float32")
    with pytest.raises(ValueError, match="no multi-device backend"):
        make_backend(cfg, EngineConfig(mesh_shards=2), None, None,
                     obs=Tracer(), clock=lambda: 0.0)


# ---------------------------------------------------------------------------
# shard observability
# ---------------------------------------------------------------------------


@needs8
def test_sharded_drain_emits_shard_metrics_and_trace_tracks():
    from repro.serve import to_chrome_trace, validate_chrome_trace
    eng = _engine(8, observability="trace")
    rng = np.random.default_rng(0)
    for n, g in ((5, 4), (11, 3)):
        eng.submit(rng.integers(2, eng.cfg.vocab_size, n).astype(np.int32),
                   max_new_tokens=g)
    eng.drain()
    reg = eng.obs.registry
    assert reg.gauge("backend/shard_count") == 8
    assert reg.count("backend/shard_steps") > 0
    assert reg.count("backend/shard_tokens") >= 5 + 11
    m = eng.backend.snapshot_metrics()
    assert m["n_shards"] == 8 and m["shard_steps"] > 0
    trace = to_chrome_trace(eng.events)
    validate_chrome_trace(trace)
    shard_slices = [e for e in trace["traceEvents"]
                    if e.get("cat") == "backend" and e.get("ph") == "X"]
    assert shard_slices, "no per-shard slices in the Chrome trace"
    assert {e["tid"] for e in shard_slices} == set(range(8))
    assert {e["args"]["n_shards"] for e in shard_slices} == {8}


# ---------------------------------------------------------------------------
# mesh-aware cost model
# ---------------------------------------------------------------------------


def test_cost_model_single_shard_bit_identical():
    """n_shards=1 must price EXACTLY like the pre-mesh cost model:
    the full-model workload with a zero collective term."""
    cfg = _cfg()
    base = ArtemisCostModel(cfg)
    assert base.n_shards == 1
    assert base._tp_collective(32) == (0.0, 0.0)
    ref = simulate_model(base._workload(32), DataflowConfig())
    assert base.price(32) == ref.latency_ns
    assert base.energy(32) == ref.energy_pj


def test_cost_model_shards_slice_the_workload():
    cfg = _cfg()   # n_heads=4, d_ff=128: both divide 4
    c4 = ArtemisCostModel(cfg, n_shards=4)
    w1, w4 = ArtemisCostModel(cfg)._workload(16), c4._workload(16)
    assert w4.n_heads == w1.n_heads // 4
    assert w4.d_ff == w1.d_ff // 4
    assert w4.params == pytest.approx(w1.params / 4)
    # indivisible head counts stay whole (replicated on device too)
    w3 = ArtemisCostModel(cfg, n_shards=3)._workload(16)
    assert w3.n_heads == w1.n_heads
    assert w3.params == pytest.approx(w1.params / 3)


def test_cost_model_prices_the_all_reduce():
    """The TP collective term follows the ring all-reduce formula over
    the hwsim link model and grows with tokens and layers."""
    cfg = _cfg()
    c8 = ArtemisCostModel(cfg, n_shards=8)
    lat, energy = c8._tp_collective(32)
    assert lat > 0.0 and energy > 0.0
    from repro.hwsim import DramGeometry
    geom = DramGeometry(DataflowConfig().hw)
    ring_bits = 2.0 * 7 / 8 * (32 * cfg.d_model * 32)
    assert lat == pytest.approx(
        2 * cfg.n_layers * geom.transfer_latency_ns(ring_bits))
    assert energy == pytest.approx(
        2 * cfg.n_layers * geom.transfer_energy_pj(ring_bits) * 8)
    # the term is part of the public price, monotone in tokens
    assert c8.price(32) == pytest.approx(
        c8._simulate(32).latency_ns + lat)
    assert c8._tp_collective(64)[0] > lat
    with pytest.raises(ValueError, match="n_shards"):
        ArtemisCostModel(cfg, n_shards=0)


# ---------------------------------------------------------------------------
# engine/scheduler stay mesh-oblivious (source-level contract)
# ---------------------------------------------------------------------------


def test_engine_and_scheduler_have_no_mesh_branches():
    """The tentpole's design constraint: the mesh is threaded as a
    VALUE (engine builds it once and hands it to make_backend); neither
    engine.py nor scheduler.py may branch on mesh state or name the
    sharded backend."""
    import repro.serve.engine as eng_mod
    import repro.serve.scheduler as sched_mod
    import inspect
    eng_src = inspect.getsource(eng_mod)
    sched_src = inspect.getsource(sched_mod)
    # the engine may PASS mesh values (make_serve_mesh / n_shards=...)
    # but never inspect them; the scheduler never sees the mesh at all
    for banned in ("is_single", "ShardedPagedBackend"):
        assert banned not in eng_src, f"engine.py references {banned}"
        assert banned not in sched_src, f"scheduler.py references {banned}"
    for banned in ("mesh", "n_shards"):
        assert banned not in sched_src, f"scheduler.py references {banned}"
