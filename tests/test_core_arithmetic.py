"""Unit + property tests for the ARTEMIS arithmetic core (repro.core)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    # hypothesis is a dev-only dep (requirements-dev.txt): without it
    # only the @given property tests skip — the deterministic tests in
    # this module still run.
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="property test needs hypothesis (requirements-dev.txt)")

    def settings(*a, **k):
        return lambda f: f

from repro.core import (
    ARTEMIS,
    EXACT,
    INT8,
    ArithmeticPolicy,
    MomcapConfig,
    SC_LEVELS,
    artemis_matmul,
    artemis_softmax,
    fake_quant,
    grouped_signed_accumulate,
    lse_softmax,
    lut_activation,
    max_linear_accumulations,
    momcap_voltage_trace,
    online_max_sum,
    quant_scale,
    quantize,
    readout_quantize,
    sc_multiply,
    sc_multiply_bitstream,
    sc_multiply_float,
    spread_encode,
    tcu_encode,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Stochastic (TCU) multiply
# ---------------------------------------------------------------------------

class TestStochasticMultiply:
    def test_bitstream_equals_closed_form_exhaustive(self):
        """popcount(tcu(a) & spread(b)) == floor(a*b/128) over ALL 128x128."""
        a = jnp.arange(128)[:, None] * jnp.ones((1, 128), jnp.int32)
        b = jnp.ones((128, 1), jnp.int32) * jnp.arange(128)[None, :]
        got = sc_multiply_bitstream(a.reshape(-1), b.reshape(-1))
        want = sc_multiply(a.reshape(-1), b.reshape(-1))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_tcu_encode_counts(self):
        m = jnp.array([0, 1, 64, 127, 128])
        counts = tcu_encode(m).sum(-1)
        np.testing.assert_array_equal(np.asarray(counts), [0, 1, 64, 127, 128])

    def test_spread_encode_counts(self):
        m = jnp.arange(129)
        counts = spread_encode(m).sum(-1)
        np.testing.assert_array_equal(np.asarray(counts), np.arange(129))

    def test_float_variant_matches_int(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 128, (64,))
        b = rng.integers(0, 128, (64,))
        got = sc_multiply_float(jnp.float32(a), jnp.float32(b))
        want = sc_multiply(jnp.int32(a), jnp.int32(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @given(st.integers(0, 127), st.integers(0, 127))
    @settings(max_examples=50, deadline=None)
    def test_truncation_bound(self, a, b):
        """SC multiply under-approximates by < 1 product unit (paper §II.B)."""
        exact = a * b / SC_LEVELS
        got = int(sc_multiply(jnp.int32(a), jnp.int32(b)))
        assert 0 <= exact - got < 1.0

    def test_symmetry(self):
        a = jnp.arange(128)
        np.testing.assert_array_equal(
            np.asarray(sc_multiply(a[:, None], a[None, :])),
            np.asarray(sc_multiply(a[None, :], a[:, None])),
        )


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

class TestQuantization:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_roundtrip_error_bound(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (32, 16))
        err = jnp.abs(fake_quant(x) - x)
        bound = quant_scale(x) / 2 + 1e-6
        assert bool(jnp.all(err <= bound))

    def test_per_channel_tighter_than_per_tensor(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (64, 8)) * jnp.logspace(-2, 1, 8)
        err_t = jnp.mean(jnp.abs(fake_quant(x, axis=None) - x))
        err_c = jnp.mean(jnp.abs(fake_quant(x, axis=0) - x))
        assert float(err_c) < float(err_t)

    def test_quantize_range(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (100,)) * 10
        qv = quantize(x, quant_scale(x))
        assert int(jnp.max(jnp.abs(qv.astype(jnp.int32)))) <= 127


# ---------------------------------------------------------------------------
# MOMCAP analog accumulation
# ---------------------------------------------------------------------------

class TestAnalogAccumulation:
    def test_ideal_readout_is_identity(self):
        cfg = MomcapConfig(readout_bits=None)
        x = jnp.float32([0.0, 5.0, 2539.9])
        np.testing.assert_allclose(np.asarray(readout_quantize(x, cfg)),
                                   np.asarray(x))

    def test_readout_quantization_error_bound(self):
        cfg = MomcapConfig(readout_bits=8)
        x = jnp.linspace(0.0, cfg.full_scale, 1000)
        err = jnp.abs(readout_quantize(x, cfg) - x)
        delta = cfg.full_scale / 255
        assert float(jnp.max(err)) <= delta / 2 + 1e-4

    @given(st.integers(0, 2**31 - 1), st.integers(1, 64))
    @settings(max_examples=25, deadline=None)
    def test_ideal_grouped_accumulate_is_exact_sum(self, seed, k):
        rng = np.random.default_rng(seed)
        p = jnp.int32(rng.integers(0, 127, (4, k)))
        s = jnp.int32(rng.choice([-1, 1], (4, k)))
        cfg = MomcapConfig(readout_bits=None)
        got = grouped_signed_accumulate(p, s, cfg)
        want = jnp.sum(p * s, axis=-1).astype(jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_noise_is_deterministic_given_key(self):
        cfg = MomcapConfig(sigma_analog=0.01)
        p = jnp.full((2, 40), 64, jnp.int32)
        s = jnp.ones((2, 40), jnp.int32)
        k = jax.random.PRNGKey(7)
        a = grouped_signed_accumulate(p, s, cfg, key=k)
        b = grouped_signed_accumulate(p, s, cfg, key=k)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_momcap_paper_calibration_point(self):
        """8 pF (the paper's tile-area-matched choice) -> 20 accumulations."""
        assert max_linear_accumulations(8.0) == 20

    def test_momcap_monotone_in_capacitance(self):
        caps = [4.0, 8.0, 16.0, 24.0, 40.0]
        accs = [max_linear_accumulations(c) for c in caps]
        assert all(a < b for a, b in zip(accs, accs[1:]))

    def test_momcap_trace_saturates(self):
        trace = np.asarray(momcap_voltage_trace(8.0, 1000))
        increments = np.diff(trace)
        assert increments[0] > increments[-1] >= 0  # compresses toward rail
        assert trace[-1] <= 1.1  # never exceeds the rail


# ---------------------------------------------------------------------------
# Softmax / LUTs
# ---------------------------------------------------------------------------

class TestSoftmax:
    def test_lse_softmax_matches_jax(self):
        y = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 5
        np.testing.assert_allclose(
            np.asarray(lse_softmax(y)), np.asarray(jax.nn.softmax(y)),
            rtol=1e-5, atol=1e-6,
        )

    def test_artemis_softmax_close(self):
        """LUT softmax MAE stays within the paper's Table V regime (2e-2)."""
        y = jax.random.normal(jax.random.PRNGKey(1), (8, 128)) * 3
        err = jnp.abs(artemis_softmax(y) - jax.nn.softmax(y))
        assert float(jnp.mean(err)) < 5e-3
        assert float(jnp.max(err)) < 6e-2

    def test_artemis_softmax_normalized_roughly(self):
        y = jax.random.normal(jax.random.PRNGKey(2), (16, 64))
        sums = jnp.sum(artemis_softmax(y), axis=-1)
        assert bool(jnp.all(jnp.abs(sums - 1.0) < 0.25))

    def test_online_max_sum_matches_full(self):
        y = jax.random.normal(jax.random.PRNGKey(3), (8, 4, 32))  # 8 blocks
        m, s = online_max_sum(y)
        flat = jnp.moveaxis(y, 0, -2).reshape(4, -1)
        np.testing.assert_allclose(np.asarray(m), np.asarray(flat.max(-1)),
                                   rtol=1e-6)
        want_s = jnp.sum(jnp.exp(flat - flat.max(-1, keepdims=True)), -1)
        np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                                   rtol=1e-5)

    def test_lut_activation_error_bound(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (256,)) * 4
        for kind in ("relu", "gelu", "silu"):
            err = jnp.abs(lut_activation(x, kind) - {
                "relu": jax.nn.relu, "gelu": jax.nn.gelu, "silu": jax.nn.silu
            }[kind](x))
            # 8-bit input bins + 8-bit output quant over a +-4sigma range
            assert float(jnp.max(err)) < 0.15, kind


# ---------------------------------------------------------------------------
# The matmul ladder
# ---------------------------------------------------------------------------

class TestArtemisMatmul:
    def _operands(self, seed=0, m=8, k=64, n=12):
        ka, kb = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(ka, (m, k))
        b = jax.random.normal(kb, (k, n))
        return a, b

    def test_exact_mode_is_matmul(self):
        a, b = self._operands()
        np.testing.assert_allclose(
            np.asarray(artemis_matmul(a, b, EXACT)), np.asarray(a @ b),
            rtol=1e-6)

    def test_int8_mode_close_to_exact(self):
        a, b = self._operands()
        rel = jnp.linalg.norm(artemis_matmul(a, b, INT8) - a @ b) / \
            jnp.linalg.norm(a @ b)
        assert float(rel) < 0.02

    def test_artemis_mode_close_to_int8(self):
        """SC truncation + 8-bit readout error stays bounded (Table IV/V)."""
        a, b = self._operands(k=100)
        out_art = artemis_matmul(a, b, ARTEMIS)
        rel = jnp.linalg.norm(out_art - a @ b) / jnp.linalg.norm(a @ b)
        assert float(rel) < 0.12
        # a finer A_to_B converter (paper Table V: 11.38-bit calibration
        # accuracy) recovers most of the gap to pure truncation error
        fine = ArithmeticPolicy(mode="artemis", readout_bits=12)
        rel_fine = jnp.linalg.norm(
            artemis_matmul(a, b, fine) - a @ b) / jnp.linalg.norm(a @ b)
        assert float(rel_fine) < float(rel)

    def test_artemis_ideal_readout_matches_manual_floor_sum(self):
        """With ideal readout the pipeline == signed sum of floor products."""
        policy = ArithmeticPolicy(mode="artemis", readout_bits=None,
                                  ste=False)
        a, b = self._operands(seed=3, m=4, k=37, n=5)  # K not divisible by 20
        got = artemis_matmul(a, b, policy)

        # manual oracle
        from repro.core import magnitude_sign
        sa = quant_scale(a)
        sb = quant_scale(b)
        ma, sga = magnitude_sign(quantize(a, sa))
        mb, sgb = magnitude_sign(quantize(b, sb))
        p = sc_multiply(ma[:, :, None], mb[None, :, :]).astype(jnp.float32)
        s = (sga[:, :, None] * sgb[None, :, :]).astype(jnp.float32)
        want = jnp.sum(p * s, axis=1) * SC_LEVELS * sa * sb
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_batched_leading_dims(self):
        a = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 8, 40))
        b = jax.random.normal(jax.random.PRNGKey(6), (40, 16))
        out = artemis_matmul(a, b, ARTEMIS)
        assert out.shape == (2, 3, 8, 16)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_ste_gradient_matches_exact(self):
        a, b = self._operands()
        g_art = jax.grad(lambda x: jnp.sum(artemis_matmul(x, b, ARTEMIS)))(a)
        g_exact = jax.grad(lambda x: jnp.sum(x @ b))(a)
        np.testing.assert_allclose(np.asarray(g_art), np.asarray(g_exact),
                                   rtol=1e-5)

    def test_mxu_fast_path_tracks_artemis(self):
        """artemis_mxu error vs exact stays in the same band as artemis."""
        a, b = self._operands(seed=9, m=16, k=256, n=16)
        exact = a @ b
        pol = ArithmeticPolicy(mode="artemis_mxu", ste=False)
        rel = jnp.linalg.norm(artemis_matmul(a, b, pol) - exact) / \
            jnp.linalg.norm(exact)
        assert float(rel) < 0.08

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_artemis_error_bounded_property(self, seed):
        """Ladder error vs exact is bounded for well-scaled operands."""
        ka, kb = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(ka, (4, 60))
        b = jax.random.normal(kb, (60, 4))
        exact = a @ b
        out = artemis_matmul(a, b, ARTEMIS)
        denom = jnp.maximum(jnp.linalg.norm(exact), 1e-3)
        assert float(jnp.linalg.norm(out - exact) / denom) < 0.25

    def test_noise_mode_runs(self):
        pol = ArithmeticPolicy(mode="artemis", sigma_analog=0.005, ste=False)
        a, b = self._operands()
        out = artemis_matmul(a, b, pol, key=jax.random.PRNGKey(0))
        assert bool(jnp.all(jnp.isfinite(out)))
