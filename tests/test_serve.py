"""Tests for the continuous-batching serving engine (repro.serve).

Covers the ISSUE acceptance points: paged-cache allocator invariants
(no aliasing, full free on completion), paged-attention decode and
chunked-prefill equivalence vs the dense-cache reference, scheduler
determinism under a fixed seed/trace (including mixed prefill+decode
actions), and the headline guarantee — engine-mode serving with mixed
prompt/gen lengths and chunked+batched prefill is token-identical to
sequential single-request dense decoding under greedy sampling,
including through cache-pressure preemptions landing mid-prefill.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    # hypothesis is a dev-only dep (requirements-dev.txt): without it
    # only the @given property tests skip — the deterministic tests in
    # this module still run.
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="property test needs hypothesis (requirements-dev.txt)")

    def settings(*a, **k):
        return lambda f: f

from repro import configs
from repro.launch import steps as stepslib
from repro.models import model
from repro.serve import (
    ArtemisCostModel,
    EngineConfig,
    PageAllocator,
    ServeEngine,
    TrafficConfig,
    init_paged_cache,
    make_paged_chunked_prefill,
    make_paged_decode,
    make_paged_prefill,
    pad_to_page,
    percentile,
    synth_trace,
)
from repro.serve.paged_cache import TRASH_PAGE
from repro.serve.request import RequestState


@pytest.fixture(scope="module")
def dense_setup():
    cfg = dataclasses.replace(configs.get_config("qwen3_8b", smoke=True),
                              compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@functools.lru_cache(maxsize=4)
def _dense_steps(cfg):
    """Jitted dense steps, shared across reference decodes so XLA's jit
    cache actually hits (a fresh jit wrapper per request recompiles)."""
    return (jax.jit(stepslib.make_prefill_step(cfg)),
            jax.jit(stepslib.make_decode_step(cfg)))


_REF_CACHE: dict = {}


def _sequential_reference(cfg, params, prompt, n_new):
    """Greedy decode of one request alone on the dense-cache path.
    Memoized: the chunk-size parametrizations replay the same trace."""
    key = (cfg.name, prompt.tobytes(), n_new)
    if key in _REF_CACHE:
        return _REF_CACHE[key]
    prefill, decode = _dense_steps(cfg)
    cache = model.init_cache(cfg, 1, len(prompt) + n_new,
                             dtype=jnp.float32)
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cache)
    out = [int(stepslib.greedy_sample(logits)[0])]
    for _ in range(n_new - 1):
        logits, cache = decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(stepslib.greedy_sample(logits)[0]))
    _REF_CACHE[key] = out
    return out


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


class TestPageAllocator:
    def test_no_aliasing_and_full_free(self):
        a = PageAllocator(n_pages=16, page_size=4)
        p1 = a.alloc(5, owner=1)
        p2 = a.alloc(5, owner=2)
        assert not (set(p1) & set(p2)), "pages aliased across requests"
        assert 0 not in p1 + p2, "trash page handed out"
        a.check_invariants()
        a.free(p1)
        a.check_invariants()
        p3 = a.alloc(5, owner=3)
        assert not (set(p3) & set(p2))
        a.free(p2)
        a.free(p3)
        a.check_invariants()
        assert a.n_used == 0 and a.n_free == 15

    def test_exhaustion_and_double_free(self):
        a = PageAllocator(n_pages=8, page_size=4)
        pages = a.alloc(7, owner=1)
        with pytest.raises(MemoryError):
            a.alloc(1, owner=2)
        a.free(pages)
        with pytest.raises(ValueError):
            a.free(pages)

    def test_random_op_sequence_keeps_invariants(self):
        rng = np.random.default_rng(0)
        a = PageAllocator(n_pages=32, page_size=4)
        live = {}
        for i in range(200):
            if live and (rng.random() < 0.4 or a.n_free < 4):
                rid = int(rng.choice(list(live)))
                a.free(live.pop(rid))
            else:
                n = int(rng.integers(1, 5))
                if a.can_alloc(n):
                    live[i] = a.alloc(n, owner=i)
            a.check_invariants()
        for pages in live.values():
            a.free(pages)
        a.check_invariants()
        assert a.n_used == 0

    def test_pad_to_page(self):
        assert pad_to_page(1, 8) == 8
        assert pad_to_page(8, 8) == 8
        assert pad_to_page(9, 8) == 16

    def test_refcount_share_and_last_owner_release(self):
        a = PageAllocator(n_pages=8, page_size=4)
        pages = a.alloc(2, owner=1)
        a.share(pages, owner=2)
        assert all(a.refcount(p) == 2 for p in pages)
        assert a.n_used == 2 and a.n_logical == 4
        a.check_invariants()
        released = a.free(pages, owner=1)
        assert released == []            # owner 2 still holds them
        assert a.n_used == 2 and all(a.refcount(p) == 1 for p in pages)
        a.check_invariants()
        released = a.free(pages, owner=2)
        assert sorted(released) == sorted(pages)   # last owner releases
        assert a.n_used == 0 and a.n_free == 7
        a.check_invariants()

    def test_share_and_free_error_cases(self):
        a = PageAllocator(n_pages=8, page_size=4)
        [p] = a.alloc(1, owner=1)
        with pytest.raises(ValueError, match="already owns"):
            a.share([p], owner=1)
        a.share([p], owner=2)
        with pytest.raises(ValueError, match="explicit owner"):
            a.free([p])                  # shared: owner is ambiguous
        with pytest.raises(ValueError, match="does not own"):
            a.free([p], owner=3)
        a.free([p], owner=2)
        a.free([p], owner=1)
        with pytest.raises(ValueError, match="double free"):
            a.free([p], owner=1)
        with pytest.raises(ValueError, match="share free page"):
            a.share([p], owner=1)
        a.check_invariants()

    def test_free_order_is_normalized(self):
        """Regression: free() used to append pages to the free list in
        caller order, so LIFO reuse silently depended on each call
        site's list ordering — with COW adding new free paths, reuse
        order must be a function of the page SET, not its ordering."""
        seqs = []
        for order in ([3, 5, 2], [5, 2, 3], [2, 3, 5]):
            a = PageAllocator(n_pages=8, page_size=4)
            a.alloc(6, owner=1)              # pages 1..6
            a.free(order, owner=1)
            seqs.append(a.alloc(3, owner=2))
            a.check_invariants()
        assert seqs[0] == seqs[1] == seqs[2], seqs
        assert seqs[0] == [2, 3, 5]          # descending append, LIFO pop


# ---------------------------------------------------------------------------
# prefix index
# ---------------------------------------------------------------------------


class TestPrefixIndex:
    def _index(self, ps=4):
        from repro.serve import PrefixIndex
        return PrefixIndex(page_size=ps)

    def test_full_page_chain_match(self):
        idx = self._index()
        prompt = np.arange(2, 14, dtype=np.int32)        # 12 tokens
        assert idx.match(prompt) == (0, [])
        assert idx.register(prompt[:4], page=5)
        assert idx.register(prompt[:8], page=7)
        m, pages = idx.match(prompt)
        assert (m, pages) == (8, [5, 7])
        # diverging second page stops the chain after page one
        other = prompt.copy()
        other[6] = 99
        m, pages = idx.match(other[:8])
        assert (m, pages) == (4, [5])
        # a different FIRST page means no match at all, even though the
        # second page's own tokens are identical (content depends on
        # the whole prefix, which the chain key encodes)
        shifted = prompt.copy()
        shifted[0] = 99
        assert idx.match(shifted) == (0, [])

    def test_partial_last_page_match(self):
        idx = self._index()
        prompt = np.arange(2, 10, dtype=np.int32)        # 8 tokens
        idx.register(prompt[:4], page=3)
        idx.register(prompt[:8], page=4)
        # a prompt ending mid-page shares the resident page that covers
        # its remainder — the trailing garbage is masked by seq_len
        m, pages = idx.match(prompt[:6])
        assert (m, pages) == (6, [3, 4])
        # remainder diverging from every resident run: full pages only
        other = prompt[:6].copy()
        other[5] = 99
        assert idx.match(other) == (4, [3])

    def test_first_writer_wins_and_forget(self):
        idx = self._index()
        prompt = np.arange(2, 10, dtype=np.int32)
        assert idx.register(prompt[:4], page=3)
        assert not idx.register(prompt[:4], page=6)   # same content
        assert not idx.register(prompt[:8], page=3)   # page reused
        assert idx.match(prompt[:4]) == (4, [3])
        idx.forget([3])
        assert idx.match(prompt[:4]) == (0, [])
        assert len(idx) == 0
        idx.forget([3])                               # idempotent
        assert idx.register(prompt[:4], page=6)       # key free again
        assert idx.match(prompt[:4]) == (4, [6])

    def test_register_validates_prefix_length(self):
        idx = self._index()
        with pytest.raises(ValueError, match="multiple"):
            idx.register(np.arange(3, dtype=np.int32), page=1)
        with pytest.raises(ValueError, match="multiple"):
            idx.register(np.zeros(0, np.int32), page=1)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 5),
                          st.integers(0, 5)),
                max_size=80))
def test_allocator_share_free_cow_interleavings(ops):
    """Property: any interleaving of alloc / share / free / COW-style
    fork-and-release keeps the allocator invariants (free + live
    partition the pool, refcounts >= 1 for live pages, shared pages
    counted once physically) and releases everything at the end."""
    a = PageAllocator(n_pages=10, page_size=4)
    held: dict[int, list[int]] = {}       # owner -> pages (may repeat
    #                                        across owners = sharing)
    for code, x, y in ops:
        owners = sorted(held)
        if code == 0 and a.can_alloc(y % 2 + 1):             # alloc
            held.setdefault(x, []).extend(a.alloc(y % 2 + 1, x))
        elif code == 1 and owners:                           # share
            src = owners[x % len(owners)]
            cands = [p for p in held[src]
                     if y not in a.owners_of(p)]
            if cands and y not in (src,):
                p = cands[x % len(cands)]
                a.share([p], y)
                held.setdefault(y, []).append(p)
        elif code == 2 and owners:                           # free one
            o = owners[x % len(owners)]
            p = held[o][y % len(held[o])]
            a.free([p], owner=o)
            held[o].remove(p)
            if not held[o]:
                del held[o]
        elif code == 3 and owners and a.can_alloc(1):        # COW fork
            o = owners[x % len(owners)]
            shared = [p for p in held[o] if a.refcount(p) > 1]
            if shared:
                p = shared[y % len(shared)]
                [new] = a.alloc(1, o)
                a.free([p], owner=o)
                held[o][held[o].index(p)] = new
        a.check_invariants()
        assert a.n_logical == sum(len(v) for v in held.values())
    for o in sorted(held):
        a.free(held[o], owner=o)
    a.check_invariants()
    assert a.n_used == 0 and a.n_free == 9


# ---------------------------------------------------------------------------
# paged forward vs dense reference
# ---------------------------------------------------------------------------


def test_paged_decode_logits_match_dense(dense_setup):
    cfg, params = dense_setup
    prompt = np.arange(2, 12, dtype=np.int32)          # 10 tokens
    page = 4
    cache = init_paged_cache(cfg, n_pages=16, page_size=page)
    s_pad = pad_to_page(len(prompt), page)
    pages = cache.allocator.alloc(s_pad // page, owner=0)

    prefill = make_paged_prefill(cfg)
    decode = make_paged_decode(cfg)
    tokens = np.zeros((1, s_pad), np.int32)
    tokens[0, :len(prompt)] = prompt
    logits_p, kv = prefill(params, jnp.asarray(tokens), cache.kv,
                           jnp.asarray(pages, jnp.int32))
    cache.kv = kv

    # dense reference
    dcache = model.init_cache(cfg, 1, len(prompt) + 4, dtype=jnp.float32)
    logits_d, dcache = stepslib.make_prefill_step(cfg)(
        params, {"tokens": jnp.asarray(prompt[None])}, dcache)
    np.testing.assert_allclose(
        np.asarray(logits_p[len(prompt) - 1]), np.asarray(logits_d[0]),
        rtol=1e-4, atol=1e-4)

    # three decode steps, logits compared each step
    nxt = int(jnp.argmax(logits_d[0]))
    seq_len = len(prompt)
    tables = np.zeros((2, 4), np.int32)                # max_batch 2 lanes
    for _ in range(3):
        if seq_len >= len(pages) * page:
            pages += cache.allocator.alloc(1, owner=0)
        tables[0, :len(pages)] = pages
        lp, kv = decode(
            params, jnp.asarray([[nxt], [0]], jnp.int32), cache.kv,
            jnp.asarray(tables), jnp.asarray([seq_len, 0], jnp.int32),
            jnp.asarray([True, False]))
        cache.kv = kv
        ld, dcache = stepslib.make_decode_step(cfg)(
            params, jnp.asarray([[nxt]], jnp.int32), dcache)
        np.testing.assert_allclose(np.asarray(lp[0]), np.asarray(ld[0]),
                                   rtol=1e-4, atol=1e-4)
        nxt = int(jnp.argmax(ld[0]))
        seq_len += 1


def test_chunked_prefill_logits_match_dense(dense_setup):
    """Chunk-by-chunk prefill over the paged pool reproduces the dense
    prefill's last-position logits — chunks straddle page boundaries
    (13 tokens, chunks of 8, pages of 4)."""
    cfg, params = dense_setup
    prompt = np.arange(2, 15, dtype=np.int32)          # 13 tokens
    page, chunk_c, b, pmax = 4, 8, 2, 6
    cache = init_paged_cache(cfg, n_pages=16, page_size=page)
    cp = make_paged_chunked_prefill(cfg)

    pages, pos, last = [], 0, None
    while pos < len(prompt):
        n = min(chunk_c, len(prompt) - pos)
        while len(pages) * page < pos + n:
            pages += cache.allocator.alloc(1, owner=0)
        tokens = np.zeros((b, chunk_c), np.int32)
        tokens[0, :n] = prompt[pos:pos + n]
        tables = np.full((b, pmax), TRASH_PAGE, np.int32)
        tables[0, :len(pages)] = pages
        start = np.array([pos, 0], np.int32)
        lens = np.array([n, 0], np.int32)
        active = np.array([True, False])
        wfrom = np.zeros((b,), np.int32)
        logits, kv = cp(params, jnp.asarray(tokens), cache.kv,
                        jnp.asarray(tables), jnp.asarray(start),
                        jnp.asarray(lens), jnp.asarray(active),
                        jnp.asarray(wfrom))
        cache.kv = kv
        last = np.asarray(logits[0, n - 1])
        pos += n

    dcache = model.init_cache(cfg, 1, len(prompt), dtype=jnp.float32)
    logits_d, _ = stepslib.make_prefill_step(cfg)(
        params, {"tokens": jnp.asarray(prompt[None])}, dcache)
    np.testing.assert_allclose(last, np.asarray(logits_d[0]),
                               rtol=1e-4, atol=1e-4)

    # write-skip rerun (the prefix-sharing path): rerun the last token
    # with its K/V write masked — logits must still match, because the
    # query reads its own position's K/V from the already-resident page
    tokens = np.zeros((b, chunk_c), np.int32)
    tokens[0, 0] = prompt[-1]
    tables = np.full((b, pmax), TRASH_PAGE, np.int32)
    tables[0, :len(pages)] = pages
    kv_before = cache.kv["k"]
    logits, kv = cp(params, jnp.asarray(tokens), cache.kv,
                    jnp.asarray(tables),
                    jnp.asarray([len(prompt) - 1, 0], np.int32),
                    jnp.asarray([1, 0], np.int32),
                    jnp.asarray([True, False]),
                    jnp.asarray([len(prompt), 0], np.int32))
    cache.kv = kv
    np.testing.assert_allclose(np.asarray(logits[0, 0]),
                               np.asarray(logits_d[0]),
                               rtol=1e-4, atol=1e-4)
    # the skipped write must not have touched the request's pages
    np.testing.assert_array_equal(
        np.asarray(kv["k"][:, pages]), np.asarray(kv_before[:, pages]))


def test_paged_model_rejects_recurrent_families():
    cfg = configs.get_config("rwkv6_3b", smoke=True)
    with pytest.raises(ValueError, match="dense/moe"):
        make_paged_decode(cfg)
    with pytest.raises(ValueError, match="dense/moe"):
        make_paged_chunked_prefill(cfg)
    with pytest.raises(ValueError, match="attention family"):
        init_paged_cache(cfg, 8, 4)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------


# chunk sizes that divide (4 | 8, 12, 16, 20), straddle (7), and
# exceed (32) the trace's prompt lengths (3..20)
@pytest.mark.parametrize("prefill_chunk", [4, 7, 32])
def test_engine_token_identical_to_sequential(dense_setup, prefill_chunk):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=3,
                        max_pages_per_seq=8,
                        prefill_chunk=prefill_chunk)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    trace = synth_trace(TrafficConfig(
        n_requests=5, arrival_rate=1e4, prompt_len_min=3,
        prompt_len_max=20, gen_len_min=2, gen_len_max=10,
        vocab_size=cfg.vocab_size, seed=1))
    eng.submit_trace(trace)
    eng.drain()
    got = eng.results()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert got[i].tolist() == ref, \
            f"request {i} diverged at chunk={prefill_chunk}"
    eng.backend.cache.allocator.check_invariants()
    assert eng.backend.cache.allocator.n_used == 0, "pages leaked after drain"


def test_engine_batched_prefill_shares_a_step(dense_setup):
    """Simultaneous arrivals prefill as ONE batched chunk step, not one
    request per step."""
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=3,
                        max_pages_per_seq=8, prefill_chunk=32)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    rng = np.random.default_rng(5)
    for plen in (6, 11, 17):
        eng.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=3)
    ev = eng.step()
    assert ev[0] == "prefill"
    assert sorted(rid for rid, _ in ev[1]) == [0, 1, 2]
    assert [n for _, n in sorted(ev[1])] == [6, 11, 17]
    eng.drain()
    for i, r in eng.results().items():
        assert len(r) == 3


def test_engine_preemption_under_cache_pressure(dense_setup):
    cfg, params = dense_setup
    # 9 usable pages of 4 tokens, simultaneous arrivals, chunked
    # prefill: forced eviction, including preemptions landing
    # MID-PREFILL (a half-prefilled request loses its pages, requeues,
    # and restarts its cursor from 0)
    ecfg = EngineConfig(page_size=4, n_pages=10, max_batch=3,
                        max_pages_per_seq=8, prefill_chunk=6,
                        observability="trace")
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    trace = synth_trace(TrafficConfig(
        n_requests=6, arrival_rate=1e9, prompt_len_min=3,
        prompt_len_max=12, gen_len_min=6, gen_len_max=16,
        vocab_size=cfg.vocab_size, seed=3))
    eng.submit_trace(trace)
    eng.drain()
    m = eng.metrics()
    assert m["n_preemptions"] > 0, "pressure scenario never preempted"
    assert any(e[0] == "preempt" and e[2] == "prefill"
               for e in eng.events), "no preemption landed mid-prefill"
    assert m["n_done"] == 6
    eng.backend.cache.allocator.check_invariants()
    assert eng.backend.cache.allocator.n_used == 0
    # recompute-style preemption keeps greedy outputs token-identical
    got = eng.results()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert got[i].tolist() == ref, f"request {i} diverged"


def test_engine_drain_survives_all_lanes_preempted(dense_setup):
    """Regression: when every lane is preempted in one step (page pool
    dry at a page boundary), step() must report ("preempt_all", ...)
    progress rather than None — the freed pages make the re-queued
    request immediately prefillable, so drain() must NOT raise."""
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=4, n_pages=4, max_batch=1,
                        max_pages_per_seq=3, prefill_chunk=8)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    prompt = np.arange(2, 6, dtype=np.int32)
    rid = eng.submit(prompt, max_new_tokens=6)
    ev = eng.step()
    assert ev[0] == "prefill"          # whole prompt in one chunk
    # external pressure: hog every free page so the decode lane's
    # page-boundary growth can only preempt the lane itself
    hog = eng.backend.cache.allocator.alloc(eng.backend.cache.allocator.n_free, owner=-1)
    ev = eng.step()
    assert ev is not None and ev[0] == "preempt_all", ev
    assert eng.requests[rid].state is RequestState.QUEUED
    eng.backend.cache.allocator.free(hog)
    eng.drain()                         # must not raise "drain stalled"
    assert eng.metrics()["n_done"] == 1
    ref = _sequential_reference(cfg, params, prompt, 6)
    assert eng.results()[rid].tolist() == ref


@pytest.mark.parametrize("scheduler", ["cost", "fcfs"])
def test_engine_unfundable_chunk_falls_back_to_decode(dense_setup,
                                                      scheduler):
    """Regression: a planned prefill chunk whose missing pages are held
    by OLDER requests (which eviction never touches) must not stall
    drain — the engine runs a decode round in its place so the holders
    keep progressing and eventually free the pages."""
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=4, n_pages=8, max_batch=3,
                        max_pages_per_seq=5, prefill_chunk=4,
                        scheduler=scheduler)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    reqs = [(np.arange(2, 10, dtype=np.int32), 8),    # A: 8 prompt / 8 gen
            (np.arange(2, 6, dtype=np.int32), 4),     # B: 4 / 4
            (np.arange(2, 14, dtype=np.int32), 2)]    # C: 12 / 2
    for prompt, glen in reqs:
        eng.submit(prompt, max_new_tokens=glen)
    eng.drain()                         # must not raise "drain stalled"
    assert eng.metrics()["n_done"] == 3
    eng.backend.cache.allocator.check_invariants()
    assert eng.backend.cache.allocator.n_used == 0
    for i, (prompt, glen) in enumerate(reqs):
        ref = _sequential_reference(cfg, params, prompt, glen)
        assert eng.results()[i].tolist() == ref, f"request {i} diverged"


@pytest.mark.parametrize("scheduler", ["cost", "fcfs"])
def test_engine_deterministic_under_fixed_trace(dense_setup, scheduler):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=2,
                        max_pages_per_seq=6, prefill_chunk=8,
                        scheduler=scheduler, observability="trace")
    trace = synth_trace(TrafficConfig(
        n_requests=4, arrival_rate=1e9, prompt_len_min=3,
        prompt_len_max=16, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=7))
    runs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params=params, ecfg=ecfg)
        eng.submit_trace(trace)
        eng.drain()
        runs.append((eng.events, eng.results()))
    assert runs[0][0] == runs[1][0], "scheduler event order diverged"
    for rid in runs[0][1]:
        np.testing.assert_array_equal(runs[0][1][rid], runs[1][1][rid])
    if scheduler == "cost":
        # the saturating trace must exercise mixed composition, and the
        # mixed event stream itself must be deterministic (asserted by
        # the event equality above)
        assert any(e[0] == "mixed" for e in runs[0][0]), \
            "cost policy never composed a mixed step"


def test_engine_chunked_cost_beats_unchunked_fcfs_ttft(dense_setup):
    """The head-of-line-blocking acceptance criterion: on a long-prompt
    trace, chunked prefill + mixed cost scheduling yields lower p99 and
    mean TTFT (virtual clock, deterministic) than the seed engine's
    behavior (whole-prompt prefill, prompt-first fcfs)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(0)
    long_p = rng.integers(2, cfg.vocab_size, 256).astype(np.int32)
    shorts = [rng.integers(2, cfg.vocab_size,
                           int(rng.integers(4, 10))).astype(np.int32)
              for _ in range(4)]
    ttft = {}
    for label, sched, chunk in (("chunked_cost", "cost", 64),
                                ("unchunked_fcfs", "fcfs", 256)):
        eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
            page_size=8, n_pages=64, max_batch=4, max_pages_per_seq=36,
            prefill_chunk=chunk, scheduler=sched), seed=0)
        eng.submit(long_p, max_new_tokens=4, arrival_time=0.0)
        for i, s in enumerate(shorts):
            eng.submit(s, max_new_tokens=6, arrival_time=1e-7 * (i + 1))
        eng.drain()
        m = eng.metrics()
        assert m["n_done"] == 5
        ttft[label] = (m["p99_ttft_s"], m["mean_ttft_s"])
    assert ttft["chunked_cost"][0] < ttft["unchunked_fcfs"][0], ttft
    assert ttft["chunked_cost"][1] < ttft["unchunked_fcfs"][1], ttft


def test_engine_moe_family_smoke():
    cfg = dataclasses.replace(
        configs.get_config("qwen2_moe_a2_7b", smoke=True),
        compute_dtype="float32")
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=2,
                        max_pages_per_seq=4)
    eng = ServeEngine(cfg, ecfg=ecfg)
    rng = np.random.default_rng(0)
    for plen, glen in ((5, 3), (9, 2)):
        eng.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=glen)
    eng.drain()
    res = eng.results()
    assert len(res[0]) == 3 and len(res[1]) == 2
    assert eng.backend.cache.allocator.n_used == 0


def test_engine_submit_validation(dense_setup):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=4, n_pages=8, max_batch=1,
                        max_pages_per_seq=4)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    with pytest.raises(ValueError, match="block table"):
        eng.submit(np.arange(2, 20, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.arange(2, 6, dtype=np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="prompt"):
        eng.submit(np.zeros(0, np.int32), max_new_tokens=2)


# ---------------------------------------------------------------------------
# prefix sharing / copy-on-write
# ---------------------------------------------------------------------------


def test_engine_prefix_sharing_cow_and_sharer_preemption(dense_setup):
    """The ISSUE acceptance pin: requests sharing a resident prompt
    prefix admit onto refcounted pages; a sharer whose prompt ends
    mid-page COW-forks the shared page on its first decode write;
    another sharer is preempted (releasing only its references) and
    re-prefilled — and every output stays token-identical to the
    sequential dense-cache decode."""
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=4,
                        max_pages_per_seq=8, prefill_chunk=32,
                        observability="trace")
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    rng = np.random.default_rng(11)
    prefix = rng.integers(2, cfg.vocab_size, 16).astype(np.int32)  # 2 pages
    prompts = [
        np.concatenate([prefix,
                        rng.integers(2, cfg.vocab_size, 5).astype(np.int32)]),
        np.concatenate([prefix,
                        rng.integers(2, cfg.vocab_size, 3).astype(np.int32)]),
        prefix.copy(),        # page-aligned full hit -> 1-token rerun
        prefix[:13].copy(),   # mid-page full hit -> decode COW-forks
    ]
    gens = [8, 10, 6, 8]
    for i, (p, g) in enumerate(zip(prompts, gens)):
        eng.submit(p, max_new_tokens=g,
                   arrival_time=0.0 if i == 0 else 1e-7 * i)
    # step until every sharer is admitted against request 0's pages
    for _ in range(200):
        if sum(1 for e in eng.events if e[0] == "share") >= 3:
            break
        assert eng.step() is not None, "drained before sharers admitted"
    shares = [e for e in eng.events if e[0] == "share"]
    assert [(e[1], e[2]) for e in shares] == [(1, 16), (2, 16), (3, 13)]
    alloc = eng.backend.cache.allocator
    assert any(alloc.refcount(p) > 1
               for p in eng.requests[0].mem.pages), \
        "no page is physically shared"
    # preempt sharer 1 mid-flight: co-owned pages must stay resident
    victim = eng.requests[1]
    assert victim.state is not RequestState.DONE
    shared_pages = [p for p in victim.mem.pages if alloc.refcount(p) > 1]
    eng._preempt(victim)
    assert victim.state is RequestState.QUEUED and victim.mem is None
    for p in shared_pages:
        assert alloc.refcount(p) >= 1, "preempting a sharer freed a page"
    eng.drain()
    m = eng.metrics()
    assert m["n_done"] == 4
    assert m["n_cow_forks"] >= 1
    assert any(e[0] == "cow" and e[1] == 3 for e in eng.events), \
        "the mid-page sharer never COW-forked"
    assert any(e[0] == "preempt" and e[1] == 1 for e in eng.events)
    assert m["n_prefix_hits"] >= 4    # incl. the re-admitted sharer
    assert m["prefix_hit_rate"] > 0
    eng.backend.cache.allocator.check_invariants()
    assert eng.backend.cache.allocator.n_used == 0, "pages leaked after drain"
    assert all(r.t_first_token is not None
               for r in eng.requests.values())
    for i, (p, g) in enumerate(zip(prompts, gens)):
        ref = _sequential_reference(cfg, params, p, g)
        assert eng.results()[i].tolist() == ref, f"request {i} diverged"


def test_engine_prefix_sharing_saves_physical_pages(dense_setup):
    """Under a shared-prefix trace (4 groups x ~2.5-page prefixes) the
    sharing engine reports a positive hit rate and allocates strictly
    fewer physical pages than the same engine with sharing disabled,
    with bit-identical outputs."""
    cfg, params = dense_setup
    trace = synth_trace(TrafficConfig(
        n_requests=10, arrival_rate=2e6, prompt_len_min=2,
        prompt_len_max=8, gen_len_min=2, gen_len_max=6,
        vocab_size=cfg.vocab_size, seed=9,
        n_prefix_groups=4, prefix_len=20))
    results, mets = [], []
    for sharing in (True, False):
        eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
            page_size=8, n_pages=96, max_batch=4, max_pages_per_seq=8,
            prefill_chunk=32, prefix_sharing=sharing))
        eng.submit_trace(trace)
        eng.drain()
        eng.backend.cache.allocator.check_invariants()
        assert eng.backend.cache.allocator.n_used == 0
        results.append(eng.results())
        mets.append(eng.metrics())
    m_share, m_none = mets
    assert m_share["n_prefix_hits"] > 0 and m_share["prefix_hit_rate"] > 0
    assert m_none["prefix_hit_rate"] == 0
    assert (m_share["physical_pages_allocated"]
            < m_none["physical_pages_allocated"]), (m_share, m_none)
    assert (m_share["logical_cache_utilization"]
            >= m_share["cache_utilization"])
    for rid in results[0]:
        np.testing.assert_array_equal(results[0][rid], results[1][rid])
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert results[0][i].tolist() == ref, f"request {i} diverged"


def test_engine_sole_owner_write_invalidates_index(dense_setup):
    """Regression: when the original writer finishes, a sharer can
    become the SOLE owner of a still-indexed page; its decode then
    writes into the page in place (no co-owner to protect), which
    diverges the content from what the index advertises. The write
    must drop the index entry, or a later admission with the original
    prompt would match stale K/V and decode garbage."""
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=3,
                        max_pages_per_seq=8, prefill_chunk=32,
                        observability="trace")
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    rng = np.random.default_rng(21)
    base = rng.integers(2, cfg.vocab_size, 16).astype(np.int32)
    ra = eng.submit(base, max_new_tokens=2)                  # writer
    ev = eng.step()
    assert ev[0] == "prefill"                # base's 2 pages registered
    rd = eng.submit(base[:13], max_new_tokens=6,
                    arrival_time=eng.now)                    # sharer
    for _ in range(50):                      # sharer admitted + shared
        if any(e[0] == "share" and e[1] == rd for e in eng.events):
            break
        assert eng.step() is not None
    d = eng.requests[rd]
    for _ in range(50):                      # writer done, refs dropped
        if eng.requests[ra].state is RequestState.DONE:
            break
        assert eng.step() is not None
    for _ in range(50):                      # sharer's first DECODE
        if len(d.generated) >= 2:            # write (pos 13, page j=1)
            break
        assert eng.step() is not None
    # sole-owner write: no COW fork, but the diverged page must be out
    # of the index — only the untouched first page still matches
    assert eng.metrics()["n_cow_forks"] == 0
    assert eng.backend.prefix.match(base)[0] == 8
    re_ = eng.submit(base, max_new_tokens=4,
                     arrival_time=eng.now)   # original prompt again
    eng.drain()
    eng.backend.cache.allocator.check_invariants()
    assert eng.backend.cache.allocator.n_used == 0
    for rid, prompt, glen in ((ra, base, 2), (rd, base[:13], 6),
                              (re_, base, 4)):
        ref = _sequential_reference(cfg, params, prompt, glen)
        assert eng.results()[rid].tolist() == ref, f"request {rid}"


def test_scheduler_prices_only_unshared_pages(dense_setup):
    """Admission budgeting with a prefix probe: a fully-resident prompt
    admits at ZERO page cost (only its last token reruns for logits), a
    half-resident prompt is charged only its unshared tail."""
    from repro.serve import PagedBudget, Request, Scheduler, SchedulerConfig
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    shared = {1: 16, 2: 8, 3: 0}
    sched = Scheduler(SchedulerConfig(policy="fcfs"), cm,
                      prefill_chunk=32)

    def budget(free_pages):
        return PagedBudget(8, free_pages, probe=lambda r: shared[r.rid])

    full = Request(rid=1, prompt=np.zeros(16, np.int32), max_new_tokens=2)
    part = Request(rid=2, prompt=np.zeros(12, np.int32), max_new_tokens=2)
    cold = Request(rid=3, prompt=np.zeros(12, np.int32), max_new_tokens=2)
    common = dict(next_arrival=None, prefilling=[], decoding=[])
    # zero free pages: only the fully-resident prompt can admit
    a = sched.decide([full], free_lanes=2, budget=budget(0), **common)
    assert a.kind == "prefill" and a.prefill == ((1, 1),)
    a = sched.decide([part], free_lanes=2, budget=budget(0), **common)
    assert a.kind == "idle"
    # one free page funds exactly the half-resident prompt's tail; the
    # cold request behind it is starved (strict FCFS)
    a = sched.decide([full, part, cold], free_lanes=3, budget=budget(1),
                     **common)
    assert a.prefill == ((1, 1), (2, 4))
    # without sharing the probe reports 0 and the old budgeting holds
    a = sched.decide([cold], free_lanes=3, budget=budget(2), **common)
    assert a.prefill == ((3, 12),)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_cost_model_price_per_token_is_u_shaped(dense_setup):
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    # token-based sharding amortizes the K/V ring broadcast: per-token
    # price falls with batch size over the decode-batch range ...
    prices = [cm.price_per_token(n) for n in (1, 4, 16, 64)]
    assert all(b <= a * 1.001 for a, b in zip(prices, prices[1:])), prices
    # ... then rises again once the O(N^2) attention terms dominate —
    # the crossover that lets the cost scheduler defer giant prefills
    assert cm.price_per_token(8192) > cm.price_per_token(8)
    assert cm.price(16) > 0


def _dummy_requests(n, plen=12, state=RequestState.DECODE):
    from repro.serve import Request
    reqs = []
    for i in range(n):
        r = Request(rid=100 + i, prompt=np.zeros(plen, np.int32),
                    max_new_tokens=4)
        r.state = state
        reqs.append(r)
    return reqs


def test_cost_policy_defers_unchunked_long_prefill_while_decoding(
        dense_setup):
    """With chunking DISABLED (chunk >= prompt) the original decision
    boundary survives: a multi-thousand-token prefill prices worse per
    token than a busy decode batch, so the cost policy runs decode
    first; fcfs stalls the lanes behind the prefill instead."""
    from repro.serve import PagedBudget, Request, Scheduler, SchedulerConfig
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    huge = Request(rid=0, prompt=np.zeros(8192, np.int32),
                   max_new_tokens=4)
    small = Request(rid=1, prompt=np.zeros(12, np.int32),
                    max_new_tokens=4)
    decoding = _dummy_requests(8)
    cost = Scheduler(SchedulerConfig(policy="cost"), cm,
                     prefill_chunk=8192)
    fcfs = Scheduler(SchedulerConfig(policy="fcfs"), cm,
                     prefill_chunk=8192)

    def common():
        return dict(next_arrival=None, prefilling=[], decoding=decoding,
                    free_lanes=2, budget=PagedBudget(8, 4096))

    assert cost.decide([huge], **common()).kind == "decode"
    assert fcfs.decide([huge], **common()).kind == "prefill"
    # short prompts ride the falling edge of the per-token price curve:
    # cost composes them WITH the decode batch; fcfs stays prompt-first
    a = cost.decide([small], **common())
    assert a.kind == "mixed" and a.prefill == ((1, 12),) and a.decode
    assert fcfs.decide([small], **common()).kind == "prefill"


def test_cost_policy_chunks_long_prefill_into_mixed_steps(dense_setup):
    """With chunking ON, the same long prompt no longer blocks: the
    scheduler plans one chunk and composes it with the decode batch."""
    from repro.serve import PagedBudget, Request, Scheduler, SchedulerConfig
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    huge = Request(rid=0, prompt=np.zeros(8192, np.int32),
                   max_new_tokens=4)
    sched = Scheduler(SchedulerConfig(policy="cost"), cm,
                      prefill_chunk=64)
    a = sched.decide([huge], next_arrival=None, prefilling=[],
                     decoding=_dummy_requests(8), free_lanes=2,
                     budget=PagedBudget(8, 4096))
    assert a.kind == "mixed" and a.prefill == ((0, 64),) and a.decode


def test_scheduler_plans_batched_and_continuing_chunks(dense_setup):
    """Chunk planning: mid-prefill requests continue first (oldest
    admission uncapped by the page budget), then FCFS admissions fill
    free lanes while the budget lasts."""
    from repro.serve import PagedBudget, Request, Scheduler, SchedulerConfig
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    sched = Scheduler(SchedulerConfig(policy="fcfs"), cm,
                      prefill_chunk=8)

    def budget(free_pages):
        return PagedBudget(4, free_pages)

    mid = Request(rid=0, prompt=np.zeros(20, np.int32), max_new_tokens=2)
    mid.state = RequestState.PREFILL
    mid.prefill_pos = 8
    q1 = Request(rid=1, prompt=np.zeros(6, np.int32), max_new_tokens=2)
    q2 = Request(rid=2, prompt=np.zeros(9, np.int32), max_new_tokens=2)
    a = sched.decide([q1, q2], next_arrival=None, prefilling=[mid],
                     decoding=[], free_lanes=2, budget=budget(100))
    assert a.kind == "prefill"
    assert a.prefill == ((0, 8), (1, 6), (2, 8))
    # tight page budget: 3 free pages — the continuing request is
    # planned anyway and charged 2 pages, the first admission is
    # clipped to the 1 remaining page (4 tokens), the second starved
    a = sched.decide([q1, q2], next_arrival=None, prefilling=[mid],
                     decoding=[], free_lanes=2, budget=budget(3))
    assert a.prefill == ((0, 8), (1, 4))
    # budget exhausted by the forced continuation -> no admissions
    a = sched.decide([q1, q2], next_arrival=None, prefilling=[mid],
                     decoding=[], free_lanes=2, budget=budget(1))
    assert a.prefill == ((0, 8),)
    # no lanes -> no admissions, continuation only
    a = sched.decide([q1, q2], next_arrival=None, prefilling=[mid],
                     decoding=[], free_lanes=0, budget=budget(100))
    assert a.prefill == ((0, 8),)


def test_percentile_nearest_rank():
    """Regression for the metrics off-by-one: int(p/100*n) indexed one
    element high at exact-multiple ranks (p50 of two latencies returned
    the LARGER one); nearest-rank is ceil(p/100*n)-1."""
    assert percentile([1.0, 2.0], 50) == 1.0
    assert percentile([1.0, 2.0], 100) == 2.0
    vals = [float(i) for i in range(1, 101)]
    assert percentile(vals, 99) == 99.0
    assert percentile(vals, 50) == 50.0
    assert percentile(vals, 1) == 1.0
    assert percentile([5.0], 99) == 5.0
    assert percentile([], 50) == 0.0
    assert percentile([3.0, 4.0, 5.0], 0) == 3.0   # clamps to first


def test_cost_model_rejects_empty_compositions(dense_setup):
    """Regression: _simulate used to clamp n_tokens=0 to a 1-token
    pass, silently pricing empty compositions a buggy scheduler should
    never have asked about."""
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    for n in (0, -3):
        for fn in (cm.price, cm.energy, cm.price_per_token,
                   cm.energy_per_token):
            with pytest.raises(ValueError, match="n_tokens"):
                fn(n)
    assert cm.price(1) > 0


def test_traffic_config_validation():
    """Bad traffic bounds used to fail deep inside np.random with
    confusing errors; they are rejected at construction now."""
    for bad in (dict(prompt_len_min=10, prompt_len_max=5),
                dict(prompt_len_min=0),
                dict(arrival_rate=0.0), dict(arrival_rate=-1.0),
                dict(n_requests=0),
                dict(gen_len_min=0), dict(gen_len_min=9, gen_len_max=2),
                dict(vocab_size=2),
                dict(n_prefix_groups=-1),
                dict(n_prefix_groups=2, prefix_len=0),
                dict(prefix_len=4)):
        with pytest.raises(ValueError):
            TrafficConfig(**bad)
    TrafficConfig()   # defaults stay valid


def test_shared_prefix_trace_structure():
    tc = TrafficConfig(n_requests=12, n_prefix_groups=3, prefix_len=9,
                       prompt_len_min=2, prompt_len_max=5, seed=4)
    items = synth_trace(tc)
    assert len(items) == 12
    groups = {}
    for it in items:
        assert 0 <= it.prefix_group < 3
        assert 9 + 2 <= len(it.prompt) <= 9 + 5
        groups.setdefault(it.prefix_group, []).append(it.prompt[:9])
    # every member of a group carries the identical prefix
    for prefs in groups.values():
        for p in prefs[1:]:
            np.testing.assert_array_equal(p, prefs[0])
    # independent mode keeps the old shape
    assert synth_trace(TrafficConfig(n_requests=3,
                                     seed=1))[0].prefix_group == -1


def test_engine_ttft_metrics_complete(dense_setup):
    """max_new_tokens < 1 is rejected at submit (pinned in
    test_engine_submit_validation), so every DONE request records a
    first-token time — including the gen=1 edge where the first token
    comes straight from the prefill chunk — and TTFT percentiles cover
    the full done set."""
    cfg, params = dense_setup
    eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
        page_size=8, n_pages=32, max_batch=2, max_pages_per_seq=4))
    rng = np.random.default_rng(2)
    for plen, glen in ((5, 1), (9, 3)):
        eng.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=glen)
    eng.drain()
    assert all(r.t_first_token is not None
               for r in eng.requests.values())
    m = eng.metrics()
    assert m["n_done"] == 2
    assert m["mean_ttft_s"] > 0 and m["p99_ttft_s"] > 0
    # defensive: a None first-token time (only possible by driving the
    # engine around submit()) must not crash the percentile sort
    eng.requests[0].t_first_token = None
    m2 = eng.metrics()
    assert m2["p99_ttft_s"] > 0


def test_engine_config_validation():
    for bad in (dict(page_size=0), dict(n_pages=1), dict(max_batch=0),
                dict(max_pages_per_seq=0), dict(prefill_chunk=0),
                dict(scheduler="lifo")):
        with pytest.raises(ValueError):
            EngineConfig(**bad)
    with pytest.raises(TypeError):
        EngineConfig(cache_dtype="not-a-dtype")
    EngineConfig()   # defaults stay valid
