"""Tests for the continuous-batching serving engine (repro.serve).

Covers the ISSUE acceptance points: paged-cache allocator invariants
(no aliasing, full free on completion), paged-attention decode
equivalence vs the dense-cache reference, scheduler determinism under a
fixed seed/trace, and the headline guarantee — engine-mode serving with
mixed prompt/gen lengths is token-identical to sequential
single-request dense decoding under greedy sampling, including through
cache-pressure preemptions.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch import steps as stepslib
from repro.models import model
from repro.serve import (
    ArtemisCostModel,
    EngineConfig,
    PageAllocator,
    ServeEngine,
    TrafficConfig,
    init_paged_cache,
    make_paged_decode,
    make_paged_prefill,
    pad_to_page,
    synth_trace,
)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = dataclasses.replace(configs.get_config("qwen3_8b", smoke=True),
                              compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


@functools.lru_cache(maxsize=4)
def _dense_steps(cfg):
    """Jitted dense steps, shared across reference decodes so XLA's jit
    cache actually hits (a fresh jit wrapper per request recompiles)."""
    return (jax.jit(stepslib.make_prefill_step(cfg)),
            jax.jit(stepslib.make_decode_step(cfg)))


def _sequential_reference(cfg, params, prompt, n_new):
    """Greedy decode of one request alone on the dense-cache path."""
    prefill, decode = _dense_steps(cfg)
    cache = model.init_cache(cfg, 1, len(prompt) + n_new,
                             dtype=jnp.float32)
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cache)
    out = [int(stepslib.greedy_sample(logits)[0])]
    for _ in range(n_new - 1):
        logits, cache = decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(stepslib.greedy_sample(logits)[0]))
    return out


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------


class TestPageAllocator:
    def test_no_aliasing_and_full_free(self):
        a = PageAllocator(n_pages=16, page_size=4)
        p1 = a.alloc(5, owner=1)
        p2 = a.alloc(5, owner=2)
        assert not (set(p1) & set(p2)), "pages aliased across requests"
        assert 0 not in p1 + p2, "trash page handed out"
        a.check_invariants()
        a.free(p1)
        a.check_invariants()
        p3 = a.alloc(5, owner=3)
        assert not (set(p3) & set(p2))
        a.free(p2)
        a.free(p3)
        a.check_invariants()
        assert a.n_used == 0 and a.n_free == 15

    def test_exhaustion_and_double_free(self):
        a = PageAllocator(n_pages=8, page_size=4)
        pages = a.alloc(7, owner=1)
        with pytest.raises(MemoryError):
            a.alloc(1, owner=2)
        a.free(pages)
        with pytest.raises(ValueError):
            a.free(pages)

    def test_random_op_sequence_keeps_invariants(self):
        rng = np.random.default_rng(0)
        a = PageAllocator(n_pages=32, page_size=4)
        live = {}
        for i in range(200):
            if live and (rng.random() < 0.4 or a.n_free < 4):
                rid = int(rng.choice(list(live)))
                a.free(live.pop(rid))
            else:
                n = int(rng.integers(1, 5))
                if a.can_alloc(n):
                    live[i] = a.alloc(n, owner=i)
            a.check_invariants()
        for pages in live.values():
            a.free(pages)
        a.check_invariants()
        assert a.n_used == 0

    def test_pad_to_page(self):
        assert pad_to_page(1, 8) == 8
        assert pad_to_page(8, 8) == 8
        assert pad_to_page(9, 8) == 16


# ---------------------------------------------------------------------------
# paged forward vs dense reference
# ---------------------------------------------------------------------------


def test_paged_decode_logits_match_dense(dense_setup):
    cfg, params = dense_setup
    prompt = np.arange(2, 12, dtype=np.int32)          # 10 tokens
    page = 4
    cache = init_paged_cache(cfg, n_pages=16, page_size=page)
    s_pad = pad_to_page(len(prompt), page)
    pages = cache.allocator.alloc(s_pad // page, owner=0)

    prefill = make_paged_prefill(cfg)
    decode = make_paged_decode(cfg)
    tokens = np.zeros((1, s_pad), np.int32)
    tokens[0, :len(prompt)] = prompt
    logits_p, kv = prefill(params, jnp.asarray(tokens), cache.kv,
                           jnp.asarray(pages, jnp.int32))
    cache.kv = kv

    # dense reference
    dcache = model.init_cache(cfg, 1, len(prompt) + 4, dtype=jnp.float32)
    logits_d, dcache = stepslib.make_prefill_step(cfg)(
        params, {"tokens": jnp.asarray(prompt[None])}, dcache)
    np.testing.assert_allclose(
        np.asarray(logits_p[len(prompt) - 1]), np.asarray(logits_d[0]),
        rtol=1e-4, atol=1e-4)

    # three decode steps, logits compared each step
    nxt = int(jnp.argmax(logits_d[0]))
    seq_len = len(prompt)
    tables = np.zeros((2, 4), np.int32)                # max_batch 2 lanes
    for _ in range(3):
        if seq_len >= len(pages) * page:
            pages += cache.allocator.alloc(1, owner=0)
        tables[0, :len(pages)] = pages
        lp, kv = decode(
            params, jnp.asarray([[nxt], [0]], jnp.int32), cache.kv,
            jnp.asarray(tables), jnp.asarray([seq_len, 0], jnp.int32),
            jnp.asarray([True, False]))
        cache.kv = kv
        ld, dcache = stepslib.make_decode_step(cfg)(
            params, jnp.asarray([[nxt]], jnp.int32), dcache)
        np.testing.assert_allclose(np.asarray(lp[0]), np.asarray(ld[0]),
                                   rtol=1e-4, atol=1e-4)
        nxt = int(jnp.argmax(ld[0]))
        seq_len += 1


def test_paged_model_rejects_recurrent_families():
    cfg = configs.get_config("rwkv6_3b", smoke=True)
    with pytest.raises(ValueError, match="dense/moe"):
        make_paged_decode(cfg)
    with pytest.raises(ValueError, match="attention family"):
        init_paged_cache(cfg, 8, 4)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------


def test_engine_token_identical_to_sequential(dense_setup):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=3,
                        max_pages_per_seq=8)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    trace = synth_trace(TrafficConfig(
        n_requests=5, arrival_rate=1e4, prompt_len_min=3,
        prompt_len_max=20, gen_len_min=2, gen_len_max=10,
        vocab_size=cfg.vocab_size, seed=1))
    eng.submit_trace(trace)
    eng.drain()
    got = eng.results()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert got[i].tolist() == ref, f"request {i} diverged"
    eng.cache.allocator.check_invariants()
    assert eng.cache.allocator.n_used == 0, "pages leaked after drain"


def test_engine_preemption_under_cache_pressure(dense_setup):
    cfg, params = dense_setup
    # 9 usable pages of 4 tokens, simultaneous arrivals: forced eviction
    ecfg = EngineConfig(page_size=4, n_pages=10, max_batch=3,
                        max_pages_per_seq=8)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    trace = synth_trace(TrafficConfig(
        n_requests=6, arrival_rate=1e9, prompt_len_min=3,
        prompt_len_max=12, gen_len_min=6, gen_len_max=16,
        vocab_size=cfg.vocab_size, seed=3))
    eng.submit_trace(trace)
    eng.drain()
    m = eng.metrics()
    assert m["n_preemptions"] > 0, "pressure scenario never preempted"
    assert m["n_done"] == 6
    eng.cache.allocator.check_invariants()
    assert eng.cache.allocator.n_used == 0
    # recompute-style preemption keeps greedy outputs token-identical
    got = eng.results()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert got[i].tolist() == ref, f"request {i} diverged"


@pytest.mark.parametrize("scheduler", ["cost", "fcfs"])
def test_engine_deterministic_under_fixed_trace(dense_setup, scheduler):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=2,
                        max_pages_per_seq=6, scheduler=scheduler)
    trace = synth_trace(TrafficConfig(
        n_requests=4, arrival_rate=1e5, prompt_len_min=3,
        prompt_len_max=16, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=7))
    runs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params=params, ecfg=ecfg)
        eng.submit_trace(trace)
        eng.drain()
        runs.append((eng.events, eng.results()))
    assert runs[0][0] == runs[1][0], "scheduler event order diverged"
    for rid in runs[0][1]:
        np.testing.assert_array_equal(runs[0][1][rid], runs[1][1][rid])


def test_engine_moe_family_smoke():
    cfg = dataclasses.replace(
        configs.get_config("qwen2_moe_a2_7b", smoke=True),
        compute_dtype="float32")
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=2,
                        max_pages_per_seq=4)
    eng = ServeEngine(cfg, ecfg=ecfg)
    rng = np.random.default_rng(0)
    for plen, glen in ((5, 3), (9, 2)):
        eng.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=glen)
    eng.drain()
    res = eng.results()
    assert len(res[0]) == 3 and len(res[1]) == 2
    assert eng.cache.allocator.n_used == 0


def test_engine_submit_validation(dense_setup):
    cfg, params = dense_setup
    ecfg = EngineConfig(page_size=4, n_pages=8, max_batch=1,
                        max_pages_per_seq=4)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg)
    with pytest.raises(ValueError, match="block table"):
        eng.submit(np.arange(2, 20, dtype=np.int32), max_new_tokens=8)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(np.arange(2, 6, dtype=np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="prompt"):
        eng.submit(np.zeros(0, np.int32), max_new_tokens=2)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def test_cost_model_price_per_token_is_u_shaped(dense_setup):
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    # token-based sharding amortizes the K/V ring broadcast: per-token
    # price falls with batch size over the decode-batch range ...
    prices = [cm.price_per_token(n) for n in (1, 4, 16, 64)]
    assert all(b <= a * 1.001 for a, b in zip(prices, prices[1:])), prices
    # ... then rises again once the O(N^2) attention terms dominate —
    # the crossover that lets the cost scheduler defer giant prefills
    assert cm.price_per_token(8192) > cm.price_per_token(8)
    assert cm.price(16) > 0


def test_cost_policy_defers_long_prefill_while_decoding(dense_setup):
    """The cost policy's real decision boundary: a multi-thousand-token
    prefill prices worse per token than a busy decode batch, so decode
    runs first; fcfs stalls the lanes behind the prefill instead."""
    from repro.serve import Request, Scheduler, SchedulerConfig
    cfg, _ = dense_setup
    cm = ArtemisCostModel(cfg)
    page = 8
    huge = Request(rid=0, prompt=np.zeros(8192, np.int32),
                   max_new_tokens=4)
    small = Request(rid=1, prompt=np.zeros(12, np.int32),
                    max_new_tokens=4)
    cost = Scheduler(SchedulerConfig(policy="cost"), cm, page)
    fcfs = Scheduler(SchedulerConfig(policy="fcfs"), cm, page)
    common = dict(next_arrival=None, n_decoding=8, free_lanes=2,
                  free_pages=4096)
    assert cost.decide([huge], **common).kind == "decode"
    assert fcfs.decide([huge], **common).kind == "prefill"
    # short prompts: both policies admit eagerly (prefill-first)
    assert cost.decide([small], **common).kind == "prefill"
    assert fcfs.decide([small], **common).kind == "prefill"
