"""Distribution-layer tests: ring attention, split-KV decode, compression,
sharding rules. Runs on 8 forced host devices (separate process group via
pytest-forked isn't available, so this file must NOT import before the
flag is set — conftest does not set it; we use a module-level guard)."""
import os
import sys

# must happen before jax initializes its backends; pytest imports this
# module before any other jax usage ONLY when run standalone — so guard:
if "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import model  # noqa: E402
from repro.parallel import (  # noqa: E402
    ShardingRules,
    batch_specs,
    cache_specs,
    compressed_psum,
    init_compression,
    param_specs,
    ring_attention,
    split_kv_attention,
)
from repro.parallel.ring_attention import layer_dataflow_attention  # noqa: E402

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (run standalone or first)")


def _mesh(shape, names):
    return jax.make_mesh(shape, names)


def _ref_attention(q, k, v, causal=True):
    b, s, h, d = q.shape
    scale = 1.0 / d**0.5
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        s_ = jnp.where(mask[None, None], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


class TestRingAttention:
    def test_matches_full_attention(self):
        mesh = _mesh((8,), ("sp",))
        b, s, h, d = 2, 64, 4, 16
        key = jax.random.PRNGKey(0)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
        k = jax.random.normal(kk, (b, s, h, d), jnp.float32)
        v = jax.random.normal(kv, (b, s, h, d), jnp.float32)

        ref = _ref_attention(q, k, v)

        fn = shard_map(
            lambda q, k, v: ring_attention(q, k, v, axis_name="sp"),
            mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_layer_dataflow_matches(self):
        mesh = _mesh((8,), ("sp",))
        b, s, h, d = 1, 64, 2, 8
        key = jax.random.PRNGKey(1)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
        k = jax.random.normal(kk, (b, s, h, d), jnp.float32)
        v = jax.random.normal(kv, (b, s, h, d), jnp.float32)
        ref = _ref_attention(q, k, v)
        fn = shard_map(
            lambda q, k, v: layer_dataflow_attention(q, k, v,
                                                     axis_name="sp"),
            mesh=mesh,
            in_specs=(P(None, "sp"), P(None, "sp"), P(None, "sp")),
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        mesh = _mesh((8,), ("sp",))
        b, s, h, d = 1, 32, 2, 8
        q, k, v = (jax.random.normal(jax.random.PRNGKey(i), (b, s, h, d))
                   for i in range(3))
        ref = _ref_attention(q, k, v, causal=False)
        fn = shard_map(
            lambda q, k, v: ring_attention(q, k, v, axis_name="sp",
                                           causal=False),
            mesh=mesh,
            in_specs=(P(None, "sp"),) * 3,
            out_specs=P(None, "sp"))
        out = jax.jit(fn)(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSplitKV:
    def test_decode_matches_full(self):
        mesh = _mesh((8,), ("kvs",))
        b, s_cache, h, d = 2, 64, 4, 16
        key = jax.random.PRNGKey(2)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, 1, h, d), jnp.float32)
        k = jax.random.normal(kk, (b, s_cache, h, d), jnp.float32)
        v = jax.random.normal(kv, (b, s_cache, h, d), jnp.float32)

        # reference: decode against full cache (query at position s_cache-1)
        scale = 1.0 / d**0.5
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        p = jax.nn.softmax(s_, axis=-1)
        ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)

        q_pos = jnp.full((b, 1), s_cache - 1, jnp.int32)
        kv_pos = jnp.broadcast_to(
            jnp.arange(s_cache, dtype=jnp.int32)[None], (b, s_cache))

        def f(q, k_loc, v_loc, kvp):
            return split_kv_attention(q, k_loc, v_loc, axis_name="kvs",
                                      q_positions=q_pos,
                                      kv_positions_local=kvp)

        fn = shard_map(
            f, mesh=mesh,
            in_specs=(P(), P(None, "kvs"), P(None, "kvs"), P(None, "kvs")),
            out_specs=P())
        out = jax.jit(fn)(q, k, v, kv_pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_empty_slots_masked(self):
        """Slots with position INT32_MAX (> query pos) must not contribute."""
        mesh = _mesh((8,), ("kvs",))
        b, s_cache, h, d = 1, 32, 2, 8
        q = jax.random.normal(jax.random.PRNGKey(3), (b, 1, h, d))
        k = jax.random.normal(jax.random.PRNGKey(4), (b, s_cache, h, d))
        v = jax.random.normal(jax.random.PRNGKey(5), (b, s_cache, h, d))
        valid = 17  # only the first 17 slots are real
        kv_pos = jnp.where(jnp.arange(s_cache) < valid,
                           jnp.arange(s_cache),
                           jnp.iinfo(jnp.int32).max)[None]
        q_pos = jnp.full((b, 1), valid - 1, jnp.int32)

        scale = 1.0 / d**0.5
        s_ = jnp.einsum("bqhd,bkhd->bhqk", q[:, :, :, :],
                        k[:, :valid]) * scale
        ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s_, -1),
                         v[:, :valid])

        fn = shard_map(
            lambda q, kl, vl, kp: split_kv_attention(
                q, kl, vl, axis_name="kvs", q_positions=q_pos,
                kv_positions_local=kp),
            mesh=mesh,
            in_specs=(P(), P(None, "kvs"), P(None, "kvs"), P(None, "kvs")),
            out_specs=P())
        out = jax.jit(fn)(q, k, v, jnp.broadcast_to(kv_pos, (b, s_cache)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestCompression:
    @pytest.mark.parametrize("mode", ["none", "bf16", "int8"])
    def test_psum_close_to_exact(self, mode):
        mesh = _mesh((8,), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64), jnp.float32)
        state = init_compression({"w": g[0]}, mode)

        def f(g):
            out, _ = compressed_psum({"w": g}, state, "dp")
            return out["w"]

        fn = shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P("dp"))
        out = jax.jit(fn)(g.reshape(8, 1, 64).reshape(8, 64))
        exact = jnp.mean(g, axis=0)
        tol = {"none": 1e-6, "bf16": 1e-2, "int8": 3e-2}[mode]
        err = float(jnp.max(jnp.abs(out[0] - exact)))
        scale = float(jnp.max(jnp.abs(exact))) + 1e-9
        assert err / scale < tol

    def test_error_feedback_cumulative_convergence(self):
        """EF guarantees the CUMULATIVE applied update tracks the true sum:
        sum_t out_t -> sum_t exact_t (the per-step dither cancels)."""
        mesh = _mesh((8,), ("dp",))
        key = jax.random.PRNGKey(7)
        # gradient with a tiny component that int8 alone would always round
        # away (magnitude << scale/127) — EF must recover it over steps
        g = jax.random.normal(key, (8, 128), jnp.float32)
        g = g.at[:, 0].set(10.0)       # forces a coarse quantization scale
        g = g.at[:, 1].set(0.01)       # far below one quantization step

        def f(gl, err):
            st = CompressionStateLike("int8", {"w": err})
            out, new_st = compressed_psum({"w": gl}, st, "dp")
            return out["w"], new_st.error["w"]

        from repro.parallel.compress import CompressionState as \
            CompressionStateLike
        fn = jax.jit(shard_map(f, mesh=mesh,
                               in_specs=(P("dp"), P("dp")),
                               out_specs=(P("dp"), P("dp"))))
        exact = jnp.mean(g, axis=0)
        n_steps = 20

        def run(use_ef):
            err = jnp.zeros_like(g)
            cum = jnp.zeros_like(exact)
            for _ in range(n_steps):
                out, new_err = fn(g, err)
                if use_ef:
                    err = new_err
                cum = cum + out[0]
            return float(jnp.abs(cum[1] / n_steps - exact[1]))

        with_ef = run(True)
        without_ef = run(False)
        assert with_ef < without_ef * 0.5 or with_ef < 1e-3, \
            (with_ef, without_ef)


class TestShardingRules:
    def test_param_specs_cover_all_leaves(self):
        mesh = _mesh((4, 2), ("data", "model"))
        for arch in ["qwen3_8b", "dbrx_132b", "rwkv6_3b", "zamba2_7b"]:
            cfg = configs.get_config(arch, smoke=True)
            shapes = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0), cfg))
            specs = param_specs(cfg, shapes, mesh)
            flat_shapes = jax.tree.leaves(shapes)
            flat_specs = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_shapes) == len(flat_specs)
            # every spec must be valid for its leaf's rank
            for sh, sp in zip(flat_shapes, flat_specs):
                assert len(sp) <= len(sh.shape), (sh.shape, sp)

    def test_big_leaves_are_sharded(self):
        mesh = _mesh((4, 2), ("data", "model"))
        cfg = configs.get_config("qwen3_8b", smoke=True)
        shapes = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0), cfg))
        specs = param_specs(cfg, shapes, mesh)
        flat = jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        sharded = {jax.tree_util.keystr(kp): sp for kp, sp in flat}
        # attention + ffn weights must be TP-sharded
        assert any("wq" in k and "model" in str(s)
                   for k, s in sharded.items())
        assert any("w_up" in k and "model" in str(s)
                   for k, s in sharded.items())

    def test_batch_and_cache_specs(self):
        mesh = _mesh((4, 2), ("data", "model"))
        cfg = configs.get_config("qwen3_8b", smoke=True)
        bs = batch_specs(cfg, mesh, batch=8)
        assert bs["tokens"] == P("data", None)
        cs = cache_specs(cfg, mesh, batch=8)
        assert cs["k"] == P(None, "data", "model", None, None)
        # degenerate batch=1: no batch sharding
        bs1 = batch_specs(cfg, mesh, batch=1)
        assert bs1["tokens"] == P(None, None)
