"""Tests for `repro.analysis` — the AST contract checker.

The fixture corpus under tests/analysis_fixtures/ is the rule
specification: one directory per case, each file carrying a
`# virtual-path:` header (so path-scoped rules see serve-layer paths)
and `# expect: rule-id` markers on exactly the lines a rule must flag.
The parametrized test below asserts the analyzer's findings equal the
marker set — both directions: no missed line, no extra line.

The fixture directory is EXCLUDED from real analysis runs
(`project.EXCLUDED_DIRS`) and from ruff (pyproject), because flagged
fixtures exist to violate the contracts on purpose.
"""
from __future__ import annotations

import json
import re
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (Baseline, Finding, Project, all_rules,
                            analyze_project)
from repro.analysis.cli import changed_files, main as cli_main
from repro.analysis.core import PARSE_ERROR_RULE
from repro.analysis.project import (EXCLUDED_DIRS, parse_suppressions,
                                    suppression_sites)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "analysis_fixtures"

_VPATH_RE = re.compile(r"^#\s*virtual-path:\s*(\S+)")
_EXPECT_RE = re.compile(
    r"#\s*expect:\s*([a-z][a-z\-]*(?:\s*,\s*[a-z][a-z\-]*)*)")

# fixture-directory slug -> the rule its flagged/clean pair pins
RULE_SLUGS = {
    "wall_clock": "wall-clock-in-serve",
    "rng": "rng-key-discipline",
    "host_sync": "host-sync-in-jit",
    "retrace": "retrace-hazard",
    "registry": "registry-namespace",
    "protocol": "backend-protocol",
    "mesh_discipline": "mesh-discipline",
    "donation": "donation-discipline",
    "allocator_refcount": "allocator-refcount",
    "shard_spec": "shard-spec-discipline",
}


def load_case(case_dir: Path):
    """({virtual_path: source}, {(rule, virtual_path, line), ...})."""
    sources: dict[str, str] = {}
    expected: set[tuple[str, str, int]] = set()
    for f in sorted(case_dir.glob("*.py")):
        text = f.read_text()
        m = _VPATH_RE.match(text.splitlines()[0])
        assert m, f"{f} lacks a `# virtual-path:` header"
        vpath = m.group(1)
        assert vpath not in sources, f"duplicate virtual path {vpath}"
        sources[vpath] = text
        for lineno, line in enumerate(text.splitlines(), 1):
            em = _EXPECT_RE.search(line)
            if em:
                for rid in em.group(1).split(","):
                    expected.add((rid.strip(), vpath, lineno))
    return sources, expected


CASES = sorted(p.name for p in FIXTURES.iterdir() if p.is_dir())


@pytest.mark.parametrize("case", CASES)
def test_fixture_findings_match_expect_markers(case):
    sources, expected = load_case(FIXTURES / case)
    result = analyze_project(Project.from_sources(sources))
    got = {(f.rule, f.path, f.line) for f in result.findings}
    assert got == expected, (
        f"case {case}: findings {sorted(got - expected)} not expected; "
        f"expected {sorted(expected - got)} not found")


def test_every_rule_has_flagged_and_clean_fixture():
    rule_ids = {r.id for r in all_rules()}
    assert set(RULE_SLUGS.values()) == rule_ids
    by_case = {c: load_case(FIXTURES / c)[1] for c in CASES}
    for slug, rule in RULE_SLUGS.items():
        flagged = [c for c in CASES if c.startswith(slug)
                   and any(e[0] == rule for e in by_case[c])]
        assert flagged, f"no flagged fixture for {rule}"
        clean = [c for c in CASES if c == f"{slug}_clean"]
        assert clean, f"no clean fixture for {rule}"
        assert not by_case[clean[0]], (
            f"clean fixture {clean[0]} has expect markers")


def test_registry_rule_has_backend_scoped_fixture():
    _, expected = load_case(FIXTURES / "registry_backend_flagged")
    assert {(r, p.rsplit("/", 1)[-1]) for r, p, _ in expected} == {
        ("registry-namespace", "backend_extra.py")}


# -- suppressions -------------------------------------------------------------


def test_suppressions_same_line_block_above_and_wildcard():
    sources, expected = load_case(FIXTURES / "suppression")
    assert not expected
    result = analyze_project(Project.from_sources(sources))
    assert result.findings == []
    assert [f.rule for f in result.suppressed] == \
        ["wall-clock-in-serve"] * 3


def test_parse_suppressions_comment_block_targets_next_code_line():
    src = ("x = 1\n"
           "# why: benchmark timing\n"
           "# repro: allow[wall-clock-in-serve, rng-key-discipline]\n"
           "# more commentary\n"
           "y = 2\n")
    sup = parse_suppressions(src)
    assert sup == {5: {"wall-clock-in-serve", "rng-key-discipline"}}


# -- baseline ratchet ---------------------------------------------------------


def _fd(rule="wall-clock-in-serve", path="src/x.py", line=3):
    return Finding(path=path, line=line, col=0, rule=rule, message="m")


def test_baseline_roundtrip_and_split(tmp_path):
    known = _fd(line=3)
    fixed = _fd(line=9)
    Baseline.save(tmp_path / "b.json", [known, fixed])
    bl = Baseline.load(tmp_path / "b.json")
    fresh = _fd(line=20)
    new, baselined, stale = bl.split([known, fresh])
    assert [f.key() for f in new] == [fresh.key()]
    assert [f.key() for f in baselined] == [known.key()]
    assert [e.key() for e in stale] == [fixed.key()]


def test_baseline_missing_file_is_empty(tmp_path):
    bl = Baseline.load(tmp_path / "nope.json")
    new, baselined, stale = bl.split([_fd()])
    assert len(new) == 1 and not baselined and not stale


def test_baseline_rejects_unknown_version(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError, match="version"):
        Baseline.load(p)


def test_unparseable_file_is_a_failing_finding():
    result = analyze_project(Project.from_sources(
        {"src/repro/serve/broken.py": "def f(:\n"}))
    assert [f.rule for f in result.findings] == [PARSE_ERROR_RULE]


# -- the real tree ------------------------------------------------------------


def test_analysis_package_analyzes_itself_cleanly():
    project = Project.from_paths(["src/repro/analysis"], root=REPO)
    result = analyze_project(project)
    assert result.findings == [] and result.suppressed == []


def test_serve_tree_has_zero_unsuppressed_findings():
    project = Project.from_paths(["src/repro/serve"], root=REPO)
    result = analyze_project(project)
    assert result.findings == []


def test_committed_baseline_has_no_serve_entries():
    bl = Baseline.load(REPO / "analysis-baseline.json")
    assert not [e for e in bl.entries if "repro/serve/" in e.path]


def test_fixture_corpus_is_excluded_from_real_runs():
    assert "analysis_fixtures" in EXCLUDED_DIRS


# -- CLI ----------------------------------------------------------------------


def test_cli_clean_run_json_report(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(REPO)
    out = tmp_path / "findings.json"
    rc = cli_main(["src/repro/analysis", "--format", "json",
                   "--out", str(out)])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["new"] == []
    assert {r["id"] for r in report["rules"]} >= set(RULE_SLUGS.values())
    assert json.loads(capsys.readouterr().out) == report


def test_cli_fails_on_new_finding(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "serve" / "hot.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    rc = cli_main([str(bad), "--baseline", str(tmp_path / "none.json")])
    assert rc == 1
    assert "wall-clock-in-serve" in capsys.readouterr().out


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULE_SLUGS.values():
        assert rule in out


def test_readme_rule_table_matches_list_rules():
    """The README 'Static analysis' table is the user-facing rule
    list; it must not drift from the registered rule set."""
    readme = (REPO / "README.md").read_text()
    rows = re.findall(r"^\| `([a-z][a-z\-]*)` \|", readme, flags=re.M)
    assert len(rows) == len(set(rows)), "duplicate rows in rule table"
    assert set(rows) == {r.id for r in all_rules()}


# -- suppression rationales ---------------------------------------------------


def test_suppression_sites_extract_rationales():
    src = ("t0 = t()  # repro: allow[wall-clock-in-serve] -- bench\n"
           "# why: the harness measures real seconds\n"
           "# repro: allow[wall-clock-in-serve]\n"
           "t1 = t()\n"
           "# repro: allow[rng-key-discipline]\n"
           "k = 1\n")
    sites = suppression_sites(src)
    assert [(s.line, s.target_line) for s in sites] == \
        [(1, 1), (3, 4), (5, 6)]
    assert sites[0].rules == ("wall-clock-in-serve",)
    assert sites[0].rationale == "bench"
    assert sites[1].rationale == "why: the harness measures real seconds"
    assert sites[2].rationale == ""


def test_cli_audit_suppressions_real_tree_all_have_rationale(
        monkeypatch, capsys):
    monkeypatch.chdir(REPO)
    rc = cli_main(["--audit-suppressions"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 without rationale" in out


def test_cli_audit_suppressions_fails_without_rationale(tmp_path,
                                                        capsys):
    bad = tmp_path / "src" / "repro" / "serve" / "hot.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\n\ndef f():\n"
                   "    return time.time()  "
                   "# repro: allow[wall-clock-in-serve]\n")
    rc = cli_main([str(bad), "--audit-suppressions"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "(no rationale)" in out


# -- sarif --------------------------------------------------------------------


def test_cli_sarif_report(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "serve" / "hot.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    out = tmp_path / "r.sarif"
    rc = cli_main([str(bad), "--format", "sarif",
                   "--baseline", str(tmp_path / "none.json"),
                   "--out", str(out)])
    assert rc == 1
    doc = json.loads(out.read_text())
    assert doc["version"] == "2.1.0"
    run = doc["runs"][0]
    driver = run["tool"]["driver"]
    assert driver["name"] == "repro.analysis"
    rule_ids = [r["id"] for r in driver["rules"]]
    assert set(rule_ids) == {r.id for r in all_rules()}
    res = run["results"]
    assert len(res) == 1 and res[0]["ruleId"] == "wall-clock-in-serve"
    assert res[0]["level"] == "error"
    assert res[0]["ruleIndex"] == rule_ids.index("wall-clock-in-serve")
    region = res[0]["locations"][0]["physicalLocation"]["region"]
    assert region["startLine"] == 5


def test_sarif_baselined_findings_are_notes(tmp_path):
    from repro.analysis.sarif import render_sarif
    doc = json.loads(render_sarif([], [_fd()], all_rules()))
    res = doc["runs"][0]["results"]
    assert len(res) == 1 and res[0]["level"] == "note"


# -- changed-only -------------------------------------------------------------


def test_changed_files_outside_git_is_none(tmp_path):
    assert changed_files(tmp_path) is None


def test_cli_changed_only_filters_to_changed_files(tmp_path, capsys,
                                                   monkeypatch):
    def git(*a):
        subprocess.run(["git", *a], cwd=tmp_path, check=True,
                       capture_output=True)
    git("init", "-q")
    git("config", "user.email", "ci@example.invalid")
    git("config", "user.name", "ci")
    old = tmp_path / "src" / "repro" / "serve" / "old.py"
    old.parent.mkdir(parents=True)
    old.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    git("add", ".")
    git("commit", "-q", "-m", "base")
    git("update-ref", "refs/remotes/origin/main", "HEAD")
    hot = old.with_name("hot.py")
    hot.write_text("import time\n\n\ndef g():\n    return time.time()\n")
    monkeypatch.chdir(tmp_path)
    rc = cli_main(["src", "--changed-only",
                   "--baseline", str(tmp_path / "none.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "hot.py" in out and "old.py" not in out


# -- stdlib-only guarantee ----------------------------------------------------


def test_analysis_imports_and_runs_without_jax():
    """The analyzer must work with jax/numpy unimportable — the CI
    `analyze` job installs no ML deps."""
    code = ("import sys\n"
            "for mod in ('jax', 'jaxlib', 'numpy'):\n"
            "    sys.modules[mod] = None\n"
            "import repro.analysis\n"
            "from repro.analysis.cli import main\n"
            "raise SystemExit(main(['--list-rules']))\n")
    import os
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 0, proc.stderr
    assert "host-sync-in-jit" in proc.stdout
