"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (brief (c)).

Every Pallas kernel runs in interpret mode on CPU; allclose against
ref.py over a grid of shapes, dtypes, modes — plus hypothesis property
tests on the kernels' invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    # hypothesis is a dev-only dep (requirements-dev.txt): without it
    # only the @given property tests skip — the deterministic sweeps in
    # this module still run.
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="property test needs hypothesis (requirements-dev.txt)")

    def settings(*a, **k):
        return lambda f: f

from repro.core import quantization as quantlib
from repro.core.policy import ArithmeticPolicy
from repro.core.quantization import SC_LEVELS
from repro.kernels import (
    attention_ref,
    flash_attention,
    sc_matmul,
    sc_matmul_ref,
)
from repro.kernels.sc_matmul.sc_matmul import sc_matmul_quantized


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class TestScMatmulSweep:
    @pytest.mark.parametrize("m,k,n", [
        (128, 160, 128),     # single block
        (256, 160, 128),     # M-tiled
        (128, 320, 256),     # K- and N-tiled
        (64, 100, 96),       # ragged -> padding path
        (1, 40, 16),         # tiny
    ])
    @pytest.mark.parametrize("mode", ["int8", "artemis", "artemis_mxu"])
    def test_matches_oracle(self, m, k, n, mode):
        ka, kb = jax.random.split(jax.random.PRNGKey(m * 7 + k + n), 2)
        a = _rand(ka, (m, k))
        b = _rand(kb, (k, n))
        pol = ArithmeticPolicy(mode=mode, ste=False)
        out = sc_matmul(a, b, pol)
        sa = quantlib.quant_scale(a, 8)
        sb = quantlib.quant_scale(b, 8)
        aq, bq = quantlib.quantize(a, sa), quantlib.quantize(b, sb)
        # oracle needs block-padded K for artemis groups
        pad = (-k) % (160 if mode == "artemis" else 256)
        if pad:
            aq = jnp.pad(aq, ((0, 0), (0, pad)))
            bq = jnp.pad(bq, ((0, pad), (0, 0)))
        ref = sc_matmul_ref(aq, bq, mode=mode).astype(jnp.float32)
        ref = ref * sa * sb * (1 if mode == "int8" else SC_LEVELS)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, in_dtype):
        a = _rand(jax.random.PRNGKey(0), (128, 160), in_dtype)
        b = _rand(jax.random.PRNGKey(1), (160, 128), in_dtype)
        out = sc_matmul(a, b, ArithmeticPolicy(mode="int8", ste=False))
        exact = a.astype(jnp.float32) @ b.astype(jnp.float32)
        rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
        assert rel < 0.05

    def test_int8_matches_quantized_dot_exactly(self):
        """int8 mode must be EXACT integer arithmetic (no approximation)."""
        key = jax.random.PRNGKey(2)
        aq = jax.random.randint(key, (128, 256), -127, 128, jnp.int32)
        bq = jax.random.randint(jax.random.fold_in(key, 1), (256, 128),
                                -127, 128, jnp.int32)
        out = sc_matmul_quantized(aq.astype(jnp.int8), bq.astype(jnp.int8),
                                  mode="int8", interpret=True)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(aq @ bq))

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 6), st.integers(1, 4))
    def test_property_artemis_error_bounded(self, mb, kb, nb):
        """Hypothesis: artemis output error vs exact int dot is bounded by
        the truncation + readout bound per K element."""
        m, k, n = mb * 32, kb * 40, nb * 32
        key = jax.random.PRNGKey(m + k + n)
        aq = jax.random.randint(key, (m, k), -127, 128, jnp.int32)
        bq = jax.random.randint(jax.random.fold_in(key, 1), (k, n),
                                -127, 128, jnp.int32)
        pad = (-k) % 160
        aqp = jnp.pad(aq, ((0, 0), (0, pad)))
        bqp = jnp.pad(bq, ((0, pad), (0, 0)))
        out = sc_matmul_ref(aqp.astype(jnp.int8), bqp.astype(jnp.int8),
                            mode="artemis")
        exact = (aq @ bq).astype(jnp.float32) / SC_LEVELS
        kp = k + pad
        # per product: <=1 unit floor truncation; per group: readout step
        groups = kp // 20
        bound = kp * 1.0 + groups * (20 * 127 / 255.0) + 1.0
        assert float(jnp.max(jnp.abs(out - exact))) <= bound


class TestFlashAttentionSweep:
    @pytest.mark.parametrize("b,hq,hkv,s,d", [
        (1, 4, 4, 128, 64),      # MHA single block
        (2, 8, 2, 256, 64),      # GQA 4:1
        (1, 4, 1, 256, 32),      # MQA
        (1, 2, 2, 200, 64),      # ragged seq -> padding
        (2, 4, 4, 384, 128),     # multi-block, wide head
    ])
    def test_matches_oracle(self, b, hq, hkv, s, d):
        key = jax.random.PRNGKey(b * 100 + hq + s)
        kq, kk, kv = jax.random.split(key, 3)
        q = _rand(kq, (b, hq, s, d))
        k = _rand(kk, (b, hkv, s, d))
        v = _rand(kv, (b, hkv, s, d))
        o, lse = flash_attention(q, k, v, causal=True, return_lse=True)
        o_ref, lse_ref = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        key = jax.random.PRNGKey(9)
        q, k, v = (_rand(jax.random.fold_in(key, i), (1, 2, 128, 64))
                   for i in range(3))
        o, _ = flash_attention(q, k, v, causal=False, return_lse=True)
        o_ref, _ = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16_inputs(self):
        key = jax.random.PRNGKey(10)
        q, k, v = (_rand(jax.random.fold_in(key, i), (1, 2, 128, 64),
                         jnp.bfloat16) for i in range(3))
        o, _ = flash_attention(q, k, v, causal=True, return_lse=True)
        o_ref, _ = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(o_ref, np.float32),
                                   rtol=2e-2, atol=2e-2)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 3), st.integers(0, 2), st.integers(1, 3))
    def test_property_lse_merge_associative(self, b, hp, sb):
        """Splitting the KV axis and LSE-merging partials == full attention
        (the invariant behind ring attention and split-KV decode)."""
        h, s, d = 2 ** hp, 64 * sb, 32
        key = jax.random.PRNGKey(b * 31 + h + s)
        kq, kk, kv = jax.random.split(key, 3)
        q = _rand(kq, (b, h, 64, d))
        k = _rand(kk, (b, h, s, d))
        v = _rand(kv, (b, h, s, d))
        o_full, lse_full = attention_ref(q, k, v, causal=False)
        # two halves merged via LSE
        half = s // 2
        if half == 0:
            return
        o1, l1 = attention_ref(q, k[:, :, :half], v[:, :, :half],
                               causal=False)
        o2, l2 = attention_ref(q, k[:, :, half:], v[:, :, half:],
                               causal=False)
        m = jnp.maximum(l1, l2)
        w1 = jnp.exp(l1 - m)[..., None]
        w2 = jnp.exp(l2 - m)[..., None]
        o = (o1 * w1 + o2 * w2) / (w1 + w2)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                                   rtol=1e-5, atol=1e-5)


class TestFlashWrapperFixes:
    """Regression pins for the kernel-wrapper bugfixes: shared
    interpret-mode resolution, the non-causal key-length mask (odd Sk
    through the padding wrapper), sliding-window masking, and the
    two-sided block-skip predicate (proved FLOP-free via the visited-
    block counter output)."""

    def _qkv(self, seed, b=1, hq=4, hkv=2, sq=16, sk=16, d=8):
        key = jax.random.PRNGKey(seed)
        kq, kk, kv = jax.random.split(key, 3)
        return (_rand(kq, (b, hq, sq, d)), _rand(kk, (b, hkv, sk, d)),
                _rand(kv, (b, hkv, sk, d)))

    def test_interpret_default_is_shared_and_matches_backend(self):
        import importlib
        fa = importlib.import_module(
            "repro.kernels.flash_attention.flash_attention")
        ops = importlib.import_module(
            "repro.kernels.flash_attention.ops")
        pa = importlib.import_module(
            "repro.kernels.paged_attention.paged_attention")
        assert fa._interpret_default() == (jax.default_backend() != "tpu")
        # single source of truth: ops and the paged kernel import THE
        # SAME probe, not private copies
        assert ops._interpret_default is fa._interpret_default
        assert pa._interpret_default is fa._interpret_default

    def test_raw_kernel_default_interpret_resolves(self):
        """flash_attention_kernel's default must resolve via the probe
        (compiled Mosaic would fail off-TPU, so running on CPU with no
        explicit interpret IS the pin that the default is no longer a
        hardwired constant)."""
        from repro.kernels.flash_attention import flash_attention_kernel
        q, k, v = self._qkv(0)
        o, lse = flash_attention_kernel(q, k, v, bq=8, bk=8)
        o_ref, lse_ref = attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("sk", [13, 97, 130])
    def test_non_causal_odd_sk_matches_ref(self, sk):
        """Non-causal Sk that is NOT a block multiple pads through the
        wrapper and must match the oracle exactly (previously raised
        NotImplementedError after already mutating K/V)."""
        q, k, v = self._qkv(sk, sq=5, sk=sk)
        o, lse = flash_attention(q, k, v, causal=False, return_lse=True)
        o_ref, lse_ref = attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [1, 3, 7, 100])
    def test_window_matches_ref(self, window):
        q, k, v = self._qkv(window, sq=24, sk=24)
        o = flash_attention(q, k, v, causal=True, window=window)
        o_ref, _ = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_window_requires_causal(self):
        q, k, v = self._qkv(1)
        with pytest.raises(ValueError, match="causal"):
            flash_attention(q, k, v, causal=False, window=4)

    def test_causal_block_skip_counts(self):
        """Above-diagonal K blocks never execute: with bq=bk=4 over
        sq=sk=16, q block qi executes exactly qi+1 K blocks."""
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_block_counts,
        )
        q, k, v = self._qkv(2)
        nvis = np.asarray(flash_attention_block_counts(
            q, k, v, causal=True, bq=4, bk=4))
        per_block = nvis[0, 0, ::4]
        np.testing.assert_array_equal(per_block, [1.0, 2.0, 3.0, 4.0])

    def test_window_block_skip_is_two_sided(self):
        """With a sliding window, K blocks entirely below every query
        row's window are skipped too — the counter proves no FLOPs
        issue from either side, while outputs still match the oracle."""
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_block_counts,
        )
        bq = bk = 4
        q, k, v = self._qkv(4, sq=32, sk=32)
        window = 4
        nvis = np.asarray(flash_attention_block_counts(
            q, k, v, causal=True, window=window, bq=bq, bk=bk))
        nk = 32 // bk
        for qi in range(32 // bq):
            visited = sum(
                1 for ki in range(nk)
                if ki * bk <= qi * bq + bq - 1             # causal side
                and ki * bk + bk - 1 > qi * bq - window)   # window side
            assert nvis[0, 0, qi * bq] == visited, (qi, visited)
        # every q block past the first visits exactly 2 of its <=qi+1
        # causally-visible blocks — the window skip is doing real work
        assert nvis[0, 0, -1] == 2 < nk
        o = flash_attention(q, k, v, causal=True, window=window)
        o_ref, _ = attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_kv_len_blocks_past_length_never_execute(self):
        """Non-causal padded Sk: K blocks entirely past the true key
        length are skipped, and padded keys carry zero weight."""
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_block_counts,
        )
        q, k, v = self._qkv(6, sq=8, sk=16)
        nvis = np.asarray(flash_attention_block_counts(
            q, k, v, causal=False, kv_len=6, bq=4, bk=4))
        # kv_len=6 spans blocks 0-1 of 4; blocks 2-3 must not run
        assert (nvis == 2.0).all()
        from repro.kernels.flash_attention import flash_attention_kernel
        o, lse = flash_attention_kernel(q, k, v, causal=False, kv_len=6,
                                        bq=4, bk=4)
        o_ref, lse_ref = attention_ref(q, k[:, :, :6], v[:, :, :6],
                                       causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                                   rtol=2e-4, atol=2e-4)
