# virtual-path: src/repro/serve/fixture_specs_ok.py
"""Clean: placement comes from the seam helpers; axis names ride on
the mesh value instead of string literals."""
import jax
from jax.experimental.shard_map import shard_map

from repro.serve.mesh import replicated_spec, seq_sharded_spec


def place(smesh):
    return replicated_spec(smesh), seq_sharded_spec(smesh)


def merge(smesh, x):
    return jax.lax.psum(x, smesh.axis)


def ring(smesh, f, x):
    return shard_map(f, mesh=smesh.handle, axis_name=smesh.axis)(x)
