# virtual-path: src/repro/launch/fixture_sweep.py
import jax
from jax.experimental import pallas as pl


def _step(x):
    return x + 1


def sweep(batches):
    outs = []
    for b in batches:
        f = jax.jit(_step)  # expect: retrace-hazard
        outs.append(f(b))
    fns = [jax.jit(_step) for _ in range(4)]  # expect: retrace-hazard
    k = None
    while batches:
        k = pl.pallas_call(_step, out_shape=None)  # expect: retrace-hazard
        batches = batches[:-1]
    return outs, fns, k
