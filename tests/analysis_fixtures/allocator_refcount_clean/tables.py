# virtual-path: src/repro/serve/fixture_alloc_ok.py
"""Clean: every handle freed, returned, stored into a field, or handed
to a callee on all paths out — exception edges included."""


def fund(tables, rid, pages):
    tables[rid] = pages


class Tables:
    def __init__(self, allocator):
        self.allocator = allocator
        self.tables = {}

    def alloc_and_store(self, rid, n):
        pages = self.allocator.alloc(n, rid)
        self.tables[rid] = pages

    def alloc_and_return(self, rid, n):
        return self.allocator.alloc(n, rid)

    def alloc_guarded(self, rid, n, budget):
        if n > budget:
            raise ValueError("over budget")
        pages = self.allocator.alloc(n, rid)
        self.tables[rid] = pages

    def alloc_try_finally(self, rid, n):
        pages = self.allocator.alloc(n, rid)
        try:
            self.tables[rid] = pages
        finally:
            self.allocator.free(pages)

    def alloc_handoff(self, rid, n):
        pages = self.allocator.alloc(n, rid)
        fund(self.tables, rid, pages)

    def alloc_branchy(self, rid, n, cow):
        alloc = self.allocator
        pages = alloc.alloc(n, rid)
        if cow:
            shared = alloc.share(pages, rid)
            self.tables[rid] = shared
        else:
            shared = pages
            self.tables[rid] = shared
        return pages
