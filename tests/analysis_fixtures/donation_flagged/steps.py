# virtual-path: src/repro/serve/fixture_donation.py
"""Flagged: buffers read after being passed at a donated position.

Covers every resolution path of the donation index: a decorated
module-level step, a factory returning jit locals (tuple-unpacked into
consumer locals), and an instance attribute bound from `jax.jit`.
"""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(1,))
def fused_update(params, pool):
    return pool


def caller(params, pool):
    out = fused_update(params, pool)
    return out, pool  # expect: donation-discipline


def make_steps(cfg):
    def prefill(params, tokens, pool):
        return tokens, pool

    def decode(params, tokens, pool):
        return tokens, pool

    prefill_j = jax.jit(prefill, donate_argnums=(2,))
    decode_j = jax.jit(decode, donate_argnums=(2,))
    return prefill_j, decode_j


def drain(params, tokens, pool):
    prefill, decode = make_steps(None)
    logits, new_pool = prefill(params, tokens, pool)
    stale = pool.sum()  # expect: donation-discipline
    return logits, new_pool, stale


def branch_read(params, tokens, pool, debug: bool):
    prefill, _ = make_steps(None)
    logits, new_pool = prefill(params, tokens, pool)
    if debug:
        logits = logits + pool.mean()  # expect: donation-discipline
    return logits, new_pool


class Backend:
    def __init__(self, step, pool):
        self._decode = jax.jit(step, donate_argnums=(2,))
        self._pool = pool

    def step(self, params, tokens):
        logits, pool = self._decode(params, tokens, self._pool)
        peak = self._pool.nbytes  # expect: donation-discipline
        self._pool = pool
        return logits, peak
