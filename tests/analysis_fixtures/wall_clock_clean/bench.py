# virtual-path: src/repro/hwsim/fixture_bench.py
import time


def wall(fn):
    t0 = time.time()
    fn()
    return time.time() - t0
