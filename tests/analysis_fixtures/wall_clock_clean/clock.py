# virtual-path: src/repro/serve/fixture_clock.py


def advance(engine, cost_s):
    engine.now += cost_s
    return engine.now
