# virtual-path: src/repro/serve/fixture_specs.py
"""Flagged: placement vocabulary constructed outside the seam —
PartitionSpec/NamedSharding calls and string axis-name literals."""
import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P


def place(mesh, x):
    spec = P(None, "model")  # expect: shard-spec-discipline
    return jax.device_put(x, NamedSharding(mesh, spec))  # expect: shard-spec-discipline


def merge(x, y):
    lo = jax.lax.psum(x, "model")  # expect: shard-spec-discipline
    hi = jax.lax.pmax(y, ("data", "model"))  # expect: shard-spec-discipline
    return lo, hi


def ring(mesh, f, x, perm):
    return shard_map(f, mesh=mesh, axis_name="model")(x, perm)  # expect: shard-spec-discipline
