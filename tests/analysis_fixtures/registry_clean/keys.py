# virtual-path: src/repro/serve/fixture_keys.py
N_TOKENS_KEY = "sampler/fixture_n_tokens"
