# virtual-path: src/repro/serve/fixture_metrics_ok.py
from repro.serve import fixture_keys


def publish(reg):
    reg.inc(fixture_keys.N_TOKENS_KEY)
    reg.observe("backend/fixture_util", 0.5)
