# virtual-path: src/repro/serve/sampler.py
import jax


def lane_key(seed, n):
    return jax.random.fold_in(jax.random.PRNGKey(seed), n)
