# virtual-path: src/repro/serve/fixture_consume.py
import jax


def sample(key, logits):
    k0, _k1 = jax.random.split(key)
    return jax.random.categorical(k0, logits)
