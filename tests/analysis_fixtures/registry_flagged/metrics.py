# virtual-path: src/repro/serve/fixture_metrics.py


def publish(reg, name):
    reg.inc("latency/total")  # expect: registry-namespace
    reg.observe("engine/" + name, 1.0)  # expect: registry-namespace
    reg.set_gauge(f"engine/{name}", 2)  # expect: registry-namespace
    reg.inc("engine/n_steps")
