# virtual-path: src/repro/serve/fixture_donation_ok.py
"""Clean: donated buffers rebound before any further read.

The idiomatic serve-loop shapes the rule must NOT flag: same-atom
read-then-rebind (`pool = step(..., pool)`), rebinding a prefix
(`self.cache = ...` refreshes `self.cache.kv`), reads BEFORE the
donating call, and donation killed on every path of a branch.
"""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(1,))
def fused_update(params, pool):
    return pool


def rebind_same_atom(params, pool):
    pool = fused_update(params, pool)
    pool = fused_update(params, pool)
    return pool


def read_before_call(params, pool):
    peak = pool.nbytes
    pool = fused_update(params, pool)
    return pool, peak


def make_steps(cfg):
    def decode(params, tokens, pool):
        return tokens, pool

    return jax.jit(decode, donate_argnums=(2,))


def rebind_on_every_path(params, tokens, pool, greedy: bool):
    decode = make_steps(None)
    if greedy:
        logits, pool = decode(params, tokens, pool)
    else:
        logits, pool = decode(params, tokens, pool)
        logits = logits * 2.0
    return logits, pool.shape


class Cache:
    def __init__(self, step, kv):
        self._decode = jax.jit(step, donate_argnums=(2,))
        self.kv = kv

    def step(self, params, tokens, cache):
        logits, new_kv = self._decode(params, tokens, cache.kv)
        cache = cache.replace(kv=new_kv)
        return logits, cache.kv
