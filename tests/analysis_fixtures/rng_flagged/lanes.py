# virtual-path: src/repro/serve/fixture_lanes.py
import jax


def resample(logits, seed):
    key = jax.random.PRNGKey(seed)  # expect: rng-key-discipline
    del key
    return jax.random.categorical(jax.random.PRNGKey(0), logits)  # expect: rng-key-discipline
