# virtual-path: src/repro/serve/fixture_backend_impls.py
import abc


class SequenceBackend(abc.ABC):
    @abc.abstractmethod
    def admit(self, request, budget):
        ...

    @abc.abstractmethod
    def release(self, seq_id):
        ...

    @abc.abstractmethod
    def utilization(self):
        ...


class BadBackend(SequenceBackend):  # expect: backend-protocol
    def admit(self, req, budget):  # expect: backend-protocol
        return True

    def release(self, seq_id, force):  # expect: backend-protocol
        del seq_id, force
