# virtual-path: src/repro/serve/fixture_topology.py
import jax
from jax.sharding import Mesh
from jax.experimental import mesh_utils


def pick_backend(cfg):
    n = jax.device_count()  # expect: mesh-discipline
    local = jax.local_device_count()  # expect: mesh-discipline
    inv = jax.devices()  # expect: mesh-discipline
    here = jax.local_devices()  # expect: mesh-discipline
    return n, local, inv, here


def build_topology(n):
    mesh = jax.make_mesh((n,), ("model",))  # expect: mesh-discipline
    devs = mesh_utils.create_device_mesh((n,))  # expect: mesh-discipline
    raw = Mesh(devs, ("model",))  # expect: mesh-discipline
    return mesh, raw
