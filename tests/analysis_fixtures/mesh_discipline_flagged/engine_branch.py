# virtual-path: src/repro/launch/fixture_deploy.py
"""A launch-layer module is governed too: topology questions belong to
the seam (repro/serve/mesh.py) or the suppressed launch mesh factory."""
import jax


def shard_count():
    return len(jax.devices())  # expect: mesh-discipline
