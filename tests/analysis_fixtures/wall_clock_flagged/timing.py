# virtual-path: src/repro/serve/fixture_timing.py
import random  # expect: wall-clock-in-serve
import time
from datetime import datetime


def step_clock(engine):
    t0 = time.time()  # expect: wall-clock-in-serve
    jitter = random.random()  # expect: wall-clock-in-serve
    stamp = datetime.now()  # expect: wall-clock-in-serve
    time.sleep(0.01)  # expect: wall-clock-in-serve
    return t0, jitter, stamp
