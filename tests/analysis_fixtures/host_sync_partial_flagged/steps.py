# virtual-path: src/repro/serve/fixture_partial.py
"""Flagged: the jit surface follows `functools.partial` chains and
instance-method references — host syncs inside are still caught."""
import functools

import jax


def step(params, tokens):
    n = float(tokens[0])  # expect: host-sync-in-jit
    return params, n


def build():
    bound = functools.partial(step, None)
    return jax.jit(bound)


def build_nested():
    inner = functools.partial(step, None)
    outer = functools.partial(inner)
    return jax.jit(outer)


class Engine:
    def _decode(self, params, tokens):
        return tokens.item()  # expect: host-sync-in-jit

    def compile(self):
        return jax.jit(functools.partial(self._decode))
