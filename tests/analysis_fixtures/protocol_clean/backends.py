# virtual-path: src/repro/serve/fixture_backend_ok.py
import abc


class SequenceBackend(abc.ABC):
    @abc.abstractmethod
    def admit(self, request, budget):
        ...

    @abc.abstractmethod
    def release(self, seq_id):
        ...


class _SharedRelease:
    def release(self, seq_id):
        del seq_id


class GoodBackend(_SharedRelease, SequenceBackend):
    def admit(self, request, budget, warm=True):
        del request, budget, warm
        return True


class ForwardingBackend(SequenceBackend):
    def admit(self, *args, **kwargs):
        del args, kwargs
        return True

    def release(self, *args):
        del args
