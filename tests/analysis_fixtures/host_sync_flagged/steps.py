# virtual-path: src/repro/kernels/fixture_steps.py
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def decode_step(x):
    s = _normalize(x)
    return s * float(x.mean())  # expect: host-sync-in-jit


def _normalize(x):
    peak = x.max()
    v = peak.item()  # expect: host-sync-in-jit
    arr = np.asarray(x)  # expect: host-sync-in-jit
    return x / jnp.maximum(peak, 1e-6) + arr.sum() * v


def make_step(cfg):
    def step(x):
        return int(x[0])  # expect: host-sync-in-jit
    return step


run = jax.jit(make_step(None))
