# virtual-path: src/repro/serve/fixture_consumer.py
"""Governed serve code that takes the mesh as a VALUE is clean — it
never queries the device inventory."""


def place(mesh, pool):
    if mesh is None:
        return pool
    return pool.reshape(mesh.n_shards, -1)
