# virtual-path: src/repro/parallel/fixture_collective.py
"""The parallel collectives layer is exempt: shard_map wrappers there
legitimately build meshes for their own tests and entry points."""
import jax


def eight_way():
    if jax.device_count() < 8:
        return None
    return jax.make_mesh((8,), ("sp",))
