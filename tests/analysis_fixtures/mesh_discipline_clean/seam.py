# virtual-path: src/repro/serve/mesh.py
"""The seam module itself is exempt: this is the ONE governed place
allowed to construct a mesh."""
import jax


def make_serve_mesh(n_shards):
    if n_shards == 1:
        return None
    return jax.make_mesh((n_shards,), ("model",))
