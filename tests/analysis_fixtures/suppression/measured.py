# virtual-path: src/repro/serve/fixture_suppressed.py
import time


def measure(engine):
    t0 = time.time()  # repro: allow[wall-clock-in-serve]
    # A comment-only suppression applies to the next non-comment
    # line, so an audit explanation can sit above the flagged call:
    # repro: allow[wall-clock-in-serve]
    t1 = time.time()
    t2 = time.time()  # repro: allow[*]
    return t0, t1, t2
