# virtual-path: src/repro/serve/backend_extra.py


def snapshot_metrics(registry):
    registry.inc("engine/n_events")  # expect: registry-namespace
    registry.inc("backend/pages_used")
