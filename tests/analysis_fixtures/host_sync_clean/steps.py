# virtual-path: src/repro/kernels/fixture_clean.py
import jax
import jax.numpy as jnp


@jax.jit
def decode_step(x, n_heads: int):
    b = x.shape[0]
    scale = float(b * n_heads)
    depth = float(len(x))
    return x * scale + depth + jnp.sum(x)


def _host_only(x):
    # .item() is fine here: this helper is never reached from a jit root
    return x.item()
