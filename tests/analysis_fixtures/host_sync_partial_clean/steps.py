# virtual-path: src/repro/serve/fixture_partial_ok.py
"""Clean: partial-wrapped steps whose host-side reads are static at
trace time — and a partial that is never jitted must NOT become a jit
root just because `functools.partial` wrapped it."""
import functools

import jax


def step(params, tokens):
    b = tokens.shape[0]
    return params, float(b)


def build():
    bound = functools.partial(step, None)
    return jax.jit(bound)


def host_helper(batch):
    return float(batch[0])


def schedule(batch):
    pick = functools.partial(host_helper)
    return pick(batch)
