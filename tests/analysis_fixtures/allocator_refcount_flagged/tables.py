# virtual-path: src/repro/serve/fixture_alloc.py
"""Flagged: page handles that can fall off the end of a function —
discarded outright, leaked on a normal exit, leaked only on the path
an exception takes, or stranded by rebinding their last carrier."""


class Tables:
    def __init__(self, allocator):
        self.allocator = allocator
        self.tables = {}

    def discard(self, rid):
        self.allocator.alloc(1, rid)  # expect: allocator-refcount

    def leak_on_exit(self, rid, n):
        pages = self.allocator.alloc(n, rid)  # expect: allocator-refcount
        return rid

    def leak_on_raise(self, rid, n, budget):
        pages = self.allocator.alloc(n, rid)  # expect: allocator-refcount
        if n > budget:
            raise ValueError("over budget")
        self.tables[rid] = pages

    def dead_rebind(self, rid, n):
        pages = self.allocator.alloc(n, rid)  # expect: allocator-refcount
        pages = []
        self.tables[rid] = pages

    def bare_share_leak(self, pages, rid, ok):
        alloc = self.allocator
        alloc.share(pages, rid)  # expect: allocator-refcount
        if not ok:
            raise RuntimeError("fork failed")
        self.tables[rid] = pages
