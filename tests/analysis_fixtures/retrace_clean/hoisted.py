# virtual-path: src/repro/launch/fixture_hoisted.py
import functools

import jax


def _step(x):
    return x + 1


run_step = jax.jit(_step)


@functools.lru_cache(maxsize=None)
def make_runner(chunk: int):
    # jitting inside an lru_cached factory is the sanctioned
    # compile-once idiom (serve.backend._paged_steps)
    del chunk
    return jax.jit(_step)


def sweep(batches):
    return [run_step(b) for b in batches]
