"""Backend-conformance suite for the sequence-memory API.

`repro.serve.backend.SequenceBackend` is the contract the engine and
scheduler program against; this module drives BOTH implementations —
the paged-KV backend (attention families) and the state-slot backend
(recurrent families) — through the same lifecycle, preemption,
budget-probe, and invariant checks, parametrized by family. The
recurrent-specific acceptance pin — rwkv6 engine decode token-identical
to the sequential static path — lives here too, alongside the
submit-validation and SamplingParams satellites, and the SAMPLED-MODE
conformance suite: a sampled request's token stream must be
bit-identical to decoding it alone, regardless of batch composition,
chunk size, scheduler policy, and forced recompute-style preemption
(the batch-invariant RNG-lane contract of repro.serve.sampler), while
greedy neighbors stay pinned to the static sequential reference.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    class _StrategyStub:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return pytest.mark.skip(
            reason="property test needs hypothesis (requirements-dev.txt)")

    def settings(*a, **k):
        return lambda f: f

from repro import configs
from repro.launch import steps as stepslib
from repro.models import model
from repro.serve import (
    EngineConfig,
    PagedKVBackend,
    SamplingParams,
    ServeEngine,
    ShardedPagedBackend,
    StateSlotBackend,
    Tracer,
    TrafficConfig,
    assemble_spans,
    make_backend,
    synth_trace,
)
from repro.serve.request import RequestState

# the conformance axis: one arch per backend, all fp32 so greedy
# token-identity is numerically comfortable. "sharded" serves the SAME
# arch as "paged" on a simulated 8-way TP mesh (conftest forces
# XLA_FLAGS=--xla_force_host_platform_device_count=8), so every
# conformance pin below — sequential token identity, preemption
# recovery, sampled batch invariance, span trees — runs against the
# tensor-parallel backend too.
BACKENDS = {
    "paged": ("qwen3_8b", PagedKVBackend, {}),
    "slot": ("rwkv6_3b", StateSlotBackend, {}),
    "sharded": ("qwen3_8b", ShardedPagedBackend, {"mesh_shards": 8}),
}

# hypothesis property suites stay on the single-device backends: each
# example drains a whole engine, and the sharded engine's per-step
# collective overhead on a simulated mesh would dominate the suite
# (the sharded backend shares all host-side logic with "paged" anyway;
# its device math is pinned by the parametrized tests)
PROPERTY_KINDS = ("paged", "slot")


@functools.lru_cache(maxsize=None)
def _setup(kind):
    arch = BACKENDS[kind][0]
    cfg = dataclasses.replace(configs.get_config(arch, smoke=True),
                              compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(kind, **overrides):
    cfg, params = _setup(kind)
    kw = dict(page_size=8, n_pages=64, max_batch=3, max_pages_per_seq=8,
              prefill_chunk=8, max_seq_len=64, cache_dtype="float32")
    kw.update(BACKENDS[kind][2])
    kw.update(overrides)
    if kw.get("mesh_shards", 1) > jax.device_count():
        pytest.skip(f"needs {kw['mesh_shards']} devices, have "
                    f"{jax.device_count()}")
    return ServeEngine(cfg, params=params, ecfg=EngineConfig(**kw))


@functools.lru_cache(maxsize=4)
def _dense_steps(cfg):
    return (jax.jit(stepslib.make_prefill_step(cfg)),
            jax.jit(stepslib.make_decode_step(cfg)))


_REF_CACHE: dict = {}


def _sequential_reference(cfg, params, prompt, n_new):
    """Greedy decode of one request alone on the static sequential
    path (whole-prompt prefill + per-token decode at batch=1)."""
    key = (cfg.name, prompt.tobytes(), n_new)
    if key in _REF_CACHE:
        return _REF_CACHE[key]
    prefill, decode = _dense_steps(cfg)
    cache = model.init_cache(cfg, 1, len(prompt) + n_new,
                             dtype=jnp.float32)
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt[None])},
                            cache)
    out = [int(stepslib.greedy_sample(logits)[0])]
    for _ in range(n_new - 1):
        logits, cache = decode(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache)
        out.append(int(stepslib.greedy_sample(logits)[0]))
    _REF_CACHE[key] = out
    return out


def _trace(cfg, n=4, seed=1, plo=3, phi=18, glo=2, ghi=8):
    # saturating arrivals: virtual step prices are ~ns, so the rate
    # must be high enough that requests actually overlap in-flight
    return synth_trace(TrafficConfig(
        n_requests=n, arrival_rate=1e8, prompt_len_min=plo,
        prompt_len_max=phi, gen_len_min=glo, gen_len_max=ghi,
        vocab_size=cfg.vocab_size, seed=seed))


# ---------------------------------------------------------------------------
# routing + protocol surface
# ---------------------------------------------------------------------------


def test_make_backend_routes_by_family():
    ecfg = EngineConfig()
    for kind, (arch, cls, _) in BACKENDS.items():
        eng = _engine(kind)
        assert isinstance(eng.backend, cls)
        assert eng.cfg.family in cls.families

    class _FakeCfg:
        family = "no_such_family"

    with pytest.raises(ValueError, match="no sequence backend"):
        make_backend(_FakeCfg(), ecfg, None, None,
                     obs=Tracer(), clock=lambda: 0.0)


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_engine_has_no_backend_internals(kind):
    """The api_redesign acceptance shape: the engine only ever holds
    backend state through `backend` and per-request `mem`."""
    eng = _engine(kind)
    for attr in ("cache", "prefix", "pool", "allocator"):
        assert not hasattr(eng, attr), \
            f"engine leaks backend internals via .{attr}"


# ---------------------------------------------------------------------------
# lifecycle conformance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_lifecycle_invariants_after_every_step(kind):
    """Drive a small trace step by step: backend invariants hold after
    EVERY engine step, request mem exists exactly while laned, and all
    memory is released at drain."""
    eng = _engine(kind)
    cfg, _ = _setup(kind)
    eng.submit_trace(_trace(cfg, n=4, seed=3))
    for _ in range(10_000):
        ev = eng.step()
        eng.backend.check_invariants()
        for r in eng.requests.values():
            if r.state in (RequestState.PREFILL, RequestState.DECODE):
                assert r.mem is not None and r.lane >= 0
            else:
                assert r.mem is None and r.lane == -1
        if ev is None:
            break
    m = eng.metrics()
    assert m["n_done"] == 4
    phys, logical = eng.backend.utilization()
    assert phys == 0.0 and logical == 0.0, "memory leaked after drain"


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_token_identity_vs_sequential(kind):
    """The anchor: engine-mode greedy outputs are token-identical to
    the static sequential path — for the slot backend this is the
    ISSUE acceptance pin (rwkv6 engine decode vs sequential static)."""
    cfg, params = _setup(kind)
    eng = _engine(kind)
    trace = _trace(cfg, n=5, seed=1, phi=20)
    eng.submit_trace(trace)
    eng.drain()
    got = eng.results()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert got[i].tolist() == ref, f"request {i} diverged ({kind})"


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_preemption_recovers_token_identically(kind):
    """Force-preempt a mid-flight request (both phases if possible):
    memory is released, the request requeues, and recompute-style
    recovery keeps greedy outputs token-identical."""
    cfg, params = _setup(kind)
    eng = _engine(kind)
    trace = _trace(cfg, n=3, seed=5, plo=6, phi=18, glo=4, ghi=8)
    eng.submit_trace(trace)
    preempted = set()
    for _ in range(400):
        laned = [r for r in eng.requests.values()
                 if r.state in (RequestState.PREFILL, RequestState.DECODE)]
        fresh = [r for r in laned if r.rid not in preempted]
        if fresh and len(preempted) < 2:
            victim = fresh[0]
            eng._preempt(victim)
            preempted.add(victim.rid)
            assert victim.mem is None
            assert victim.state is RequestState.QUEUED
            eng.backend.check_invariants()
        if eng.step() is None:
            break
    eng.drain()
    assert len(preempted) >= 1
    assert eng.metrics()["n_preemptions"] >= len(preempted)
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert eng.results()[i].tolist() == ref, \
            f"request {i} diverged after preemption ({kind})"
    eng.backend.check_invariants()


def test_sharded_drain_matches_single_device_paged():
    """The mesh tentpole's acceptance pin, stated directly: draining
    the SAME mixed greedy/sampled trace — with a forced mid-flight
    preemption — on the simulated 8-way ShardedPagedBackend produces
    byte-identical token streams to the single-device PagedKVBackend
    reference engine."""
    cfg, _ = _setup("paged")
    trace = synth_trace(TrafficConfig(
        n_requests=5, arrival_rate=1e8, prompt_len_min=3,
        prompt_len_max=18, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=61, sampled_fraction=0.5,
        temperature=0.9, top_k=24, top_p=0.95))

    def drain(kind):
        eng = _engine(kind)
        eng.submit_trace(trace)
        preempted = False
        for _ in range(600):
            if not preempted:
                decoding = [r for r in eng.requests.values()
                            if r.state is RequestState.DECODE]
                if decoding:
                    eng._preempt(decoding[0])
                    preempted = True
            if eng.step() is None:
                break
        eng.drain()
        assert preempted, "trace never reached a preemptable decode"
        eng.backend.check_invariants()
        return {i: eng.results()[i].tolist() for i in range(len(trace))}

    single = drain("paged")
    sharded = drain("sharded")
    assert sharded == single, (
        "sharded drain diverged from the single-device paged reference")


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_budget_probe_is_a_snapshot(kind):
    """Granting against a BudgetProbe must not touch real backend
    capacity, and can_fund stays read-only."""
    eng = _engine(kind)
    cfg, _ = _setup(kind)
    rid = eng.submit(np.arange(2, 12, dtype=np.int32), max_new_tokens=4)
    req = eng.requests[rid]
    before = eng.backend.utilization()
    probe = eng.backend.budget()
    granted = probe.grant_admit(req, 32)
    assert granted > 0
    assert eng.backend.can_fund(req, granted)
    assert eng.backend.utilization() == before, \
        "budget probe mutated backend state"
    # a second probe starts from the full free capacity again
    assert eng.backend.budget().grant_admit(req, 32) == granted
    eng.drain()


def test_slot_backend_admission_bounded_by_slots():
    """The state-slot pool is the admission bound: with fewer slots
    than lanes, concurrent in-flight requests never exceed the slots,
    and everything still drains (slots recycle)."""
    eng = _engine("slot", max_batch=3, n_slots=3)   # 2 usable slots
    cfg, _ = _setup("slot")
    eng.submit_trace(_trace(cfg, n=5, seed=7))
    peak = 0
    for _ in range(10_000):
        laned = sum(1 for r in eng.lanes if r is not None)
        peak = max(peak, laned)
        assert laned <= 2, "admitted more requests than state slots"
        eng.backend.check_invariants()
        if eng.step() is None:
            break
    assert eng.metrics()["n_done"] == 5
    assert peak == 2, "slot pool never reached its bound"


def test_slot_backend_validate_rejects_oversized():
    eng = _engine("slot", max_seq_len=16)
    with pytest.raises(ValueError, match="max_seq_len"):
        eng.submit(np.arange(2, 16, dtype=np.int32), max_new_tokens=8)
    eng.submit(np.arange(2, 10, dtype=np.int32), max_new_tokens=8)


def test_zamba2_engine_token_identity():
    """The hybrid recurrent family (Mamba2 backbone + shared-attention
    ring) rides the same state-slot backend: per-lane vmapped slots
    keep each lane's ring index independent, greedy outputs
    token-identical to the sequential path."""
    cfg = dataclasses.replace(configs.get_config("zamba2_7b", smoke=True),
                              compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
        max_batch=3, prefill_chunk=8, max_seq_len=64,
        cache_dtype="float32"))
    trace = _trace(cfg, n=3, seed=2)
    eng.submit_trace(trace)
    eng.drain()
    eng.backend.check_invariants()
    for i, it in enumerate(trace):
        ref = _sequential_reference(cfg, params, it.prompt,
                                    it.max_new_tokens)
        assert eng.results()[i].tolist() == ref, f"request {i} diverged"


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_engine_deterministic_per_backend(kind):
    cfg, params = _setup(kind)
    trace = _trace(cfg, n=4, seed=9)
    runs = []
    for _ in range(2):
        eng = _engine(kind, observability="trace")
        eng.submit_trace(trace)
        eng.drain()
        runs.append((eng.events, eng.results()))
    assert runs[0][0] == runs[1][0], "event order diverged"
    for rid in runs[0][1]:
        np.testing.assert_array_equal(runs[0][1][rid], runs[1][1][rid])


# ---------------------------------------------------------------------------
# hypothesis: random interleavings of submit / step / preempt
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 4)),
                min_size=4, max_size=24),
       st.sampled_from(PROPERTY_KINDS))
def test_backend_survives_random_interleavings(ops, kind):
    """Property: any interleaving of late submissions, engine steps,
    and forced preemptions keeps the backend invariants after every
    operation, drains completely, and stays token-identical."""
    cfg, params = _setup(kind)
    eng = _engine(kind, max_batch=2, n_pages=32, max_pages_per_seq=6)
    rng = np.random.default_rng(0)
    prompts = []

    def submit(plen, glen):
        p = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        prompts.append((p, glen))
        eng.submit(p, max_new_tokens=glen, arrival_time=eng.now)

    submit(5, 3)
    for code, x in ops:
        if code == 0 and len(prompts) < 6:
            submit(3 + x * 3, 2 + x)
        elif code == 1:
            laned = [r for r in eng.requests.values()
                     if r.state in (RequestState.PREFILL,
                                    RequestState.DECODE)]
            if laned:
                eng._preempt(laned[x % len(laned)])
        else:
            eng.step()
        eng.backend.check_invariants()
    eng.drain()
    eng.backend.check_invariants()
    phys, _ = eng.backend.utilization()
    assert phys == 0.0, "memory leaked after drain"
    for i, (p, glen) in enumerate(prompts):
        ref = _sequential_reference(cfg, params, p, glen)
        assert eng.results()[i].tolist() == ref, \
            f"request {i} diverged ({kind})"


# ---------------------------------------------------------------------------
# sampled decode: the batch-invariant RNG-lane contract
# ---------------------------------------------------------------------------


SAMPLED = SamplingParams(temperature=0.9, top_k=24, top_p=0.95, seed=1234)

_SOLO_CACHE: dict = {}


def _solo_reference(kind, prompt, n_new, sampling):
    """A request's stream decoded ALONE in a fresh engine — the
    reference the batch-invariance contract pins sampled streams to
    (greedy streams additionally match the static sequential path)."""
    key = (kind, prompt.tobytes(), n_new, sampling)
    if key not in _SOLO_CACHE:
        eng = _engine(kind)
        rid = eng.submit(prompt, max_new_tokens=n_new, sampling=sampling)
        eng.drain()
        _SOLO_CACHE[key] = eng.results()[rid].tolist()
    return _SOLO_CACHE[key]


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_sampled_batch_invariance(kind):
    """The tentpole acceptance pin: a sampled request emits the SAME
    tokens alone, packed with greedy and sampled neighbors, under a
    different chunk size, and under the fcfs scheduler — the RNG lane
    is keyed by (seed, position), never by batch composition."""
    cfg, _ = _setup(kind)
    rng = np.random.default_rng(41)
    prompt = rng.integers(2, cfg.vocab_size, 11).astype(np.int32)
    solo = _solo_reference(kind, prompt, 8, SAMPLED)

    def packed_run(**overrides):
        eng = _engine(kind, **overrides)
        rid = eng.submit(prompt, max_new_tokens=8, sampling=SAMPLED)
        other = np.random.default_rng(43)
        for i, sp in enumerate((SamplingParams(),
                                SamplingParams(temperature=1.2, seed=9))):
            eng.submit(other.integers(2, cfg.vocab_size,
                                      5 + 4 * i).astype(np.int32),
                       max_new_tokens=5, sampling=sp)
        eng.drain()
        assert eng.metrics()["n_sampled_tokens"] >= 8 + 5
        return eng.results()[rid].tolist()

    assert packed_run() == solo
    assert packed_run(prefill_chunk=3) == solo
    assert packed_run(scheduler="fcfs") == solo


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_sampled_preemption_replay(kind):
    """Forced recompute-style preemption of a SAMPLED request (caught
    in prefill and again in decode) replays bit-identically: the
    effective prompt re-prefills and position len(generated) re-draws
    on the same (seed, position) key it would have used un-preempted."""
    cfg, _ = _setup(kind)
    rng = np.random.default_rng(47)
    prompt = rng.integers(2, cfg.vocab_size, 13).astype(np.int32)
    solo = _solo_reference(kind, prompt, 8, SAMPLED)
    eng = _engine(kind)
    rid = eng.submit(prompt, max_new_tokens=8, sampling=SAMPLED)
    eng.submit(rng.integers(2, cfg.vocab_size, 6).astype(np.int32),
               max_new_tokens=4)
    hit = {RequestState.PREFILL: 0, RequestState.DECODE: 0}
    for _ in range(400):
        req = eng.requests[rid]
        if req.state in hit and not hit[req.state]:
            hit[req.state] = 1
            eng._preempt(req)
            eng.backend.check_invariants()
        if eng.step() is None:
            break
    eng.drain()
    n_hit = sum(hit.values())
    assert n_hit >= 1
    assert eng.requests[rid].n_preemptions == n_hit
    assert eng.results()[rid].tolist() == solo, \
        f"sampled stream diverged after preemption ({kind})"


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_sampled_trace_mixed_greedy_sampled_lanes(kind):
    """A synth_trace with sampled_fraction=0.5 drains with every
    request matching its solo reference: sampled neighbors do not
    perturb greedy requests (still pinned to the static sequential
    path) and vice versa."""
    cfg, params = _setup(kind)
    trace = synth_trace(TrafficConfig(
        n_requests=6, arrival_rate=1e8, prompt_len_min=3,
        prompt_len_max=14, gen_len_min=2, gen_len_max=6,
        vocab_size=cfg.vocab_size, seed=51, sampled_fraction=0.5,
        temperature=0.9, top_k=24, top_p=0.95))
    kinds = {it.sampling.greedy for it in trace}
    assert kinds == {True, False}, "trace should mix greedy + sampled"
    eng = _engine(kind)
    eng.submit_trace(trace)
    eng.drain()
    assert eng.metrics()["n_sampled_tokens"] > 0
    for i, it in enumerate(trace):
        got = eng.results()[i].tolist()
        assert got == _solo_reference(kind, it.prompt, it.max_new_tokens,
                                      it.sampling), \
            f"request {i} ({'greedy' if it.sampling.greedy else 'sampled'})" \
            f" diverged ({kind})"
        if it.sampling.greedy:
            assert got == _sequential_reference(cfg, params, it.prompt,
                                                it.max_new_tokens)


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_greedy_call_site_unaffected_by_sampler(kind):
    """Regression for the dropped greedy-only guard: a pre-PR call
    site — submit() with NO SamplingParams — still produces exactly
    the static sequential stream, and explicitly passing the default
    SamplingParams() is byte-for-byte the same submission."""
    cfg, params = _setup(kind)
    rng = np.random.default_rng(53)
    prompt = rng.integers(2, cfg.vocab_size, 9).astype(np.int32)
    streams = []
    for sampling in (None, SamplingParams()):
        eng = _engine(kind)
        rid = (eng.submit(prompt, max_new_tokens=6) if sampling is None
               else eng.submit(prompt, max_new_tokens=6, sampling=sampling))
        eng.drain()
        assert eng.metrics()["n_sampled_tokens"] == 0
        streams.append(eng.results()[rid].tolist())
    assert streams[0] == streams[1]
    assert streams[0] == _sequential_reference(cfg, params, prompt, 6)


@settings(max_examples=6, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 4)),
                min_size=4, max_size=20),
       st.sampled_from(PROPERTY_KINDS))
def test_mixed_lanes_survive_random_interleavings(ops, kind):
    """Property: random interleavings of greedy AND sampled
    submissions, engine steps, and forced preemptions keep every
    request's stream equal to its solo reference — the sampled twin
    of test_backend_survives_random_interleavings."""
    cfg, _ = _setup(kind)
    eng = _engine(kind, max_batch=2, n_pages=32, max_pages_per_seq=6)
    rng = np.random.default_rng(0)
    subs = []

    def submit(plen, glen):
        p = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        # alternate greedy / sampled lanes deterministically
        sp = SAMPLED if len(subs) % 2 else SamplingParams()
        subs.append((p, glen, sp))
        eng.submit(p, max_new_tokens=glen, arrival_time=eng.now,
                   sampling=sp)

    submit(5, 3)
    submit(4, 3)
    for code, x in ops:
        if code == 0 and len(subs) < 6:
            submit(3 + x * 2, 2 + x)
        elif code == 1:
            laned = [r for r in eng.requests.values()
                     if r.state in (RequestState.PREFILL,
                                    RequestState.DECODE)]
            if laned:
                eng._preempt(laned[x % len(laned)])
        else:
            eng.step()
        eng.backend.check_invariants()
    eng.drain()
    eng.backend.check_invariants()
    for i, (p, glen, sp) in enumerate(subs):
        assert eng.results()[i].tolist() == _solo_reference(
            kind, p, glen, sp), f"request {i} diverged ({kind})"


# ---------------------------------------------------------------------------
# submit() validation + SamplingParams satellites
# ---------------------------------------------------------------------------


class TestSubmitValidation:
    def test_list_prompt_accepted_and_identical(self):
        cfg, params = _setup("paged")
        outs = []
        for prompt in ([5, 6, 7, 8, 9], np.arange(5, 10, dtype=np.int64)):
            eng = _engine("paged")
            rid = eng.submit(prompt, max_new_tokens=3)
            eng.drain()
            outs.append(eng.results()[rid])
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_float_array_rejected(self):
        eng = _engine("paged")
        with pytest.raises(ValueError, match="integer dtype"):
            eng.submit(np.array([1.0, 2.0, 3.5]), max_new_tokens=2)

    def test_non_int_list_rejected(self):
        eng = _engine("paged")
        with pytest.raises(ValueError, match="only ints"):
            eng.submit([1, 2.5, 3], max_new_tokens=2)
        with pytest.raises(ValueError, match="only ints"):
            eng.submit([1, True, 3], max_new_tokens=2)
        with pytest.raises(TypeError, match="np.ndarray or a list"):
            eng.submit("1 2 3", max_new_tokens=2)

    def test_out_of_vocab_rejected(self):
        cfg, _ = _setup("paged")
        eng = _engine("paged")
        with pytest.raises(ValueError, match="vocab_size"):
            eng.submit([1, cfg.vocab_size], max_new_tokens=2)
        with pytest.raises(ValueError, match="vocab_size"):
            eng.submit(np.array([-1, 2], np.int32), max_new_tokens=2)
        # a wide-dtype token must not wrap into the valid range
        with pytest.raises(ValueError, match="vocab_size"):
            eng.submit(np.array([2 ** 32 + 5], np.int64),
                       max_new_tokens=2)

    def test_sampling_params_threaded_and_accepted(self):
        """The greedy-only NotImplementedError guard is gone: sampled
        params are accepted at submit() and generate a full stream."""
        eng = _engine("paged")
        sp = SamplingParams()
        assert sp.greedy
        rid = eng.submit([2, 3, 4], max_new_tokens=2, sampling=sp)
        assert eng.requests[rid].sampling is sp
        hot = SamplingParams(temperature=0.7, top_k=40, top_p=0.9, seed=3)
        rid2 = eng.submit([2, 3, 4], max_new_tokens=2, sampling=hot)
        assert eng.requests[rid2].sampling is hot
        eng.drain()
        assert len(eng.results()[rid2]) == 2
        assert eng.metrics()["n_sampled_tokens"] == 2

    def test_sampling_params_validation(self):
        with pytest.raises(ValueError, match="temperature"):
            SamplingParams(temperature=-0.1)
        with pytest.raises(ValueError, match="top_k"):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError, match="top_p"):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError, match="seed"):
            SamplingParams(seed=-1)

    def test_traffic_sampled_fraction_validation(self):
        with pytest.raises(ValueError, match="sampled_fraction"):
            TrafficConfig(sampled_fraction=1.5)
        with pytest.raises(ValueError, match="temperature"):
            TrafficConfig(sampled_fraction=0.5, temperature=0.0)
        with pytest.raises(ValueError, match="top_p"):
            TrafficConfig(sampled_fraction=0.5, top_p=2.0)
        # sampled_fraction == 0 keeps the trace stream byte-identical
        # to the pre-sampling generator (greedy suites replay unchanged)
        base = TrafficConfig(n_requests=4, seed=3)
        for a, b in zip(synth_trace(base),
                        synth_trace(dataclasses.replace(
                            base, temperature=0.5))):
            assert a.arrival_time == b.arrival_time
            np.testing.assert_array_equal(a.prompt, b.prompt)
            assert a.sampling == b.sampling == SamplingParams()

    def test_traffic_fixed_sample_seed_only_changes_lane_seeds(self):
        """--sample-seed pins every sampled request's RNG-lane seed
        WITHOUT shifting the trace rng stream: prompts, arrivals, and
        the greedy/sampled mask are identical to the per-request-seed
        trace; only the seeds differ."""
        base = TrafficConfig(n_requests=8, seed=5, sampled_fraction=0.5,
                             temperature=0.8)
        per_req = synth_trace(base)
        fixed = synth_trace(dataclasses.replace(base, sample_seed=7))
        assert any(not it.sampling.greedy for it in per_req)
        for a, b in zip(per_req, fixed):
            assert a.arrival_time == b.arrival_time
            np.testing.assert_array_equal(a.prompt, b.prompt)
            assert a.sampling.greedy == b.sampling.greedy
            if not b.sampling.greedy:
                assert b.sampling.seed == 7
                assert b.sampling == dataclasses.replace(
                    a.sampling, seed=7)

    def test_engine_config_slot_fields_validation(self):
        with pytest.raises(ValueError, match="n_slots"):
            EngineConfig(n_slots=1)
        with pytest.raises(ValueError, match="max_seq_len"):
            EngineConfig(max_seq_len=1)
        EngineConfig(n_slots=0)
        EngineConfig(n_slots=4)


# ---------------------------------------------------------------------------
# observability conformance: span trees + registry key surface
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_span_tree_well_formed_per_backend(kind):
    """Observability conformance: a full drain at level="trace" folds
    into a well-formed span tree for EVERY request on BOTH backends —
    assemble_spans validates (and raises on) unclosed attempts,
    out-of-attempt slices, and non-monotone per-request timestamps, so
    merely succeeding is most of the assertion."""
    cfg, params = _setup(kind)
    trace = _trace(cfg, n=4, seed=9)
    eng = _engine(kind, observability="trace")
    eng.submit_trace(trace)
    eng.drain()
    trees = assemble_spans(eng.events)
    assert sorted(trees) == sorted(eng.requests)
    for rid, tr in trees.items():
        assert tr.queued_at is not None, f"request {rid} never queued"
        assert tr.open_attempt_at is None, \
            f"request {rid} drained with an unclosed lifecycle attempt"
        assert tr.finished_at is not None
        assert tr.attempts and tr.attempts[-1].name == "completed"
        assert tr.slices, f"request {rid} executed no slices"
        # prefill slices cover the whole prompt (>= under preemption,
        # which re-prefills from scratch)
        n_pf = sum(dict(s.args)["tokens"] for s in tr.slices
                   if s.name == "prefill_chunk")
        assert n_pf >= len(trace[rid].prompt), \
            f"request {rid}: prefill slices cover {n_pf} of " \
            f"{len(trace[rid].prompt)} prompt tokens"


def test_metrics_registry_keys_backend_independent():
    """`backend/` is the ONLY registry namespace allowed to differ
    between sequence backends: after draining an equivalent trace,
    every other published key is identical across the paged-KV and
    state-slot backends (the contract documented in MetricsRegistry)."""
    keysets = {}
    for kind in BACKENDS:
        cfg, params = _setup(kind)
        eng = _engine(kind)
        eng.submit_trace(_trace(cfg, n=4, seed=9))
        eng.drain()
        keys = set(eng.obs.registry.keys())
        assert any(k.startswith("backend/") for k in keys), \
            f"{kind} backend published nothing under backend/"
        keysets[kind] = {k for k in keys if not k.startswith("backend/")}
    assert keysets["paged"] == keysets["slot"], (
        "non-backend registry keys diverged between backends:\n"
        f"  paged only: {sorted(keysets['paged'] - keysets['slot'])}\n"
        f"  slot only:  {sorted(keysets['slot'] - keysets['paged'])}")


@pytest.mark.parametrize("kind", list(BACKENDS))
def test_metrics_level_retains_no_events(kind):
    """The default metrics level must cost ~nothing: a full drain
    retains zero event objects while n_events still counts every
    legacy-kind step."""
    cfg, params = _setup(kind)
    eng = _engine(kind)     # default observability="metrics"
    eng.submit_trace(_trace(cfg, n=3, seed=4))
    eng.drain()
    assert eng.events == []
    assert eng.metrics()["n_events"] > 0
