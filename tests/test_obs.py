"""Tests for the serve-layer observability stack (repro.serve.obs).

Pins the PR's acceptance points: nearest-rank `percentile` edge cases
(the single shared implementation), streaming-histogram exactness under
the bin budget and graceful collapse past it, registry semantics, the
legacy-tuple compatibility of typed events, tracer level gating (the
default metrics level retains NO event objects), the bounded
ArtemisCostModel simulate memo (cached == uncached, LRU-bounded),
span-assembly well-formedness validation against hand-built malformed
logs, per-request energy attribution summing to the run's total
simulated energy, and the Chrome trace-event export: valid per
`validate_chrome_trace`, `json.loads`-round-trippable, byte-identical
across repeated exports of the same drain, and accepted by the
`python -m repro.serve.obs` CLI validator.
"""
import dataclasses
import json
import math

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import (
    ArtemisCostModel,
    EngineConfig,
    Histogram,
    MetricsRegistry,
    ServeEngine,
    Tracer,
    TrafficConfig,
    assemble_spans,
    dumps_chrome_trace,
    percentile,
    synth_trace,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.serve import obs as obslib
from repro.serve.obs import (
    AdmitEvent,
    AdvanceEvent,
    DecodeStepEvent,
    FinishEvent,
    MixedStepEvent,
    PrefillStepEvent,
    PreemptEvent,
    QueuedEvent,
    ShareEvent,
)


# ---------------------------------------------------------------------------
# percentile (the single shared implementation)
# ---------------------------------------------------------------------------


class TestPercentile:
    def test_empty_is_zero(self):
        assert percentile([], 50) == 0.0
        assert percentile([], 0) == 0.0
        assert percentile([], 100) == 0.0

    def test_single_element_every_p(self):
        for p in (0, 1, 50, 99, 100):
            assert percentile([7.0], p) == 7.0

    def test_nearest_rank_two_elements(self):
        # p50 of two values is the LOWER one (ceil(0.5*2) = rank 1)
        assert percentile([1.0, 9.0], 50) == 1.0
        assert percentile([1.0, 9.0], 51) == 9.0
        assert percentile([1.0, 9.0], 100) == 9.0

    def test_p0_clamps_to_min_p100_to_max(self):
        vals = [float(v) for v in range(1, 11)]
        assert percentile(vals, 0) == 1.0
        assert percentile(vals, 100) == 10.0
        # no off-by-one upward: p90 of 10 values is rank 9
        assert percentile(vals, 90) == 9.0
        assert percentile(vals, 91) == 10.0

    def test_matches_brute_force_nearest_rank(self):
        rng = np.random.default_rng(0)
        vals = sorted(rng.uniform(0, 1, 37).tolist())
        for p in (1, 10, 25, 50, 75, 90, 99):
            k = min(max(math.ceil(p / 100 * len(vals)), 1), len(vals))
            assert percentile(vals, p) == vals[k - 1]

    def test_engine_reexports_the_same_function(self):
        # the dedupe satellite: engine.percentile IS obs.percentile
        from repro.serve import engine as englib
        assert englib.percentile is obslib.percentile


# ---------------------------------------------------------------------------
# streaming histogram
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_exact_mode_matches_sorted_list(self):
        rng = np.random.default_rng(1)
        vals = rng.uniform(1e-6, 1e3, 200).tolist()
        h = Histogram()
        for v in vals:
            h.observe(v)
        assert h.exact
        assert h.values() == sorted(vals)
        for p in (1, 50, 90, 99, 100):
            assert h.percentile(p) == percentile(sorted(vals), p)
        assert h.mean() == pytest.approx(np.mean(vals), rel=1e-12)
        assert h.n == 200
        assert h.vmin == min(vals) and h.vmax == max(vals)

    def test_weighted_observation(self):
        h = Histogram()
        h.observe(3.0, n=5)
        h.observe(1.0, n=1)
        assert h.n == 6
        assert h.values() == [1.0, 3.0, 3.0, 3.0, 3.0, 3.0]
        assert h.percentile(50) == 3.0
        assert h.mean() == pytest.approx(16.0 / 6)

    def test_empty_snapshot(self):
        s = Histogram().snapshot()
        assert s == {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                     "p50": 0.0, "p90": 0.0, "p99": 0.0, "exact": True}

    def test_collapse_bounds_memory_keeps_exact_aggregates(self):
        rng = np.random.default_rng(2)
        vals = rng.uniform(1.0, 1e4, 500).tolist()   # all distinct
        h = Histogram(max_bins=64)
        for v in vals:
            h.observe(v)
        assert not h.exact, "500 distinct values must exceed 64 bins"
        # memory stays bounded: log-spaced bins over [1, 1e4] at
        # 64/decade can't exceed ~4 decades * 64 + slack
        assert len(h._counts) <= 64 * 5
        # count / sum / min / max survive the collapse exactly
        assert h.n == 500
        assert h.total == pytest.approx(sum(vals), rel=1e-12)
        assert h.vmin == min(vals) and h.vmax == max(vals)
        # percentiles degrade to bin-representative (~1.8% at 64/dec)
        for p in (50, 90, 99):
            exact = percentile(sorted(vals), p)
            assert h.percentile(p) == pytest.approx(exact, rel=0.05)
        with pytest.raises(RuntimeError, match="collapsed"):
            h.values()

    def test_collapse_preserves_sign_and_zero(self):
        h = Histogram(max_bins=4)
        for v in (-3.0, -1.0, 0.0, 1.0, 3.0, 7.0):
            h.observe(v)
        assert not h.exact
        assert h.vmin == -3.0 and h.vmax == 7.0
        assert h.percentile(1) < 0 < h.percentile(100)
        assert 0.0 in h._counts    # zero is kept exact, not log-binned

    def test_validation(self):
        with pytest.raises(ValueError, match="max_bins"):
            Histogram(max_bins=0)
        with pytest.raises(ValueError, match="bins_per_decade"):
            Histogram(bins_per_decade=0)
        with pytest.raises(ValueError, match="count"):
            Histogram().observe(1.0, n=0)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetricsRegistry:
    def test_counters_gauges_hists(self):
        reg = MetricsRegistry()
        reg.inc("a/n")
        reg.inc("a/n", 4)
        assert reg.count("a/n") == 5
        assert reg.count("missing") == 0
        assert reg.count("missing", default=-1) == -1
        reg.set_gauge("a/g", 0.25)
        assert reg.gauge("a/g") == 0.25
        assert reg.gauge("missing") == 0.0
        reg.observe("a/h", 2.0)
        reg.observe("a/h", 4.0)
        assert reg.hist("a/h").n == 2
        assert reg.hist("missing") is None
        assert reg.keys() == ["a/g", "a/h", "a/n"]
        snap = reg.snapshot()
        assert snap["a/n"] == 5 and snap["a/g"] == 0.25
        assert snap["a/h"]["count"] == 2
        assert list(snap) == sorted(snap)

    def test_int_counters_stay_int(self):
        # prefix_hit_rate et al. depend on int counters staying int
        reg = MetricsRegistry()
        reg.inc("n", 2)
        reg.inc("n", 3)
        assert isinstance(reg.count("n"), int)


# ---------------------------------------------------------------------------
# typed events: legacy-tuple compatibility + tracer gating
# ---------------------------------------------------------------------------


class TestEventsAndTracer:
    def test_events_index_and_unpack_like_legacy_tuples(self):
        sh = ShareEvent(ts=2.5, rid=3, matched=16)
        assert sh[0] == "share" and sh[1] == 3 and sh[2] == 16
        kind, rid, matched, ts = sh
        assert (kind, rid, matched, ts) == ("share", 3, 16, 2.5)
        assert len(sh) == 4
        pf = PrefillStepEvent(ts=1.0, chunks=((0, 8), (1, 4)),
                              n_tokens=12, dur_s=0.5)
        assert pf[0] == "prefill" and pf[1] == ((0, 8), (1, 4))
        assert pf.t_start == pytest.approx(0.5)
        mx = MixedStepEvent(ts=1.0, chunks=((0, 8),), decode_rids=(1, 2))
        assert (mx[0], mx[1], mx[2]) == ("mixed", ((0, 8),), (1, 2))
        adv = AdvanceEvent(ts=3.0)
        assert tuple(adv) == ("advance", 3.0)
        pre = PreemptEvent(ts=4.0, rid=1, phase="decode",
                           reason="decode_pressure")
        # legacy preempt tuple has NO reason field — length pinned
        assert tuple(pre) == ("preempt", 1, "decode", 4.0)

    def test_counted_kinds_match_legacy_log(self):
        # exactly the kinds the old tuple log retained bump n_events
        counted = {"advance", "preempt_all", "decode", "prefill",
                   "mixed", "preempt", "share", "cow"}
        uncounted = {"queued", "admit", "finish", "decision"}
        for cls in (AdvanceEvent, PreemptEvent, ShareEvent,
                    PrefillStepEvent, DecodeStepEvent, MixedStepEvent):
            assert cls.kind in counted and cls.counted
        for cls in (QueuedEvent, AdmitEvent, FinishEvent,
                    obslib.DecisionEvent):
            assert cls.kind in uncounted and not cls.counted

    def test_metrics_level_counts_but_does_not_retain(self):
        tr = Tracer()     # default level="metrics"
        assert not tr.tracing
        tr.emit(AdvanceEvent(ts=1.0))
        tr.emit(ShareEvent(ts=2.0, rid=0, matched=8))
        tr.emit(FinishEvent(ts=3.0, rid=0))      # not a counted kind
        assert tr.events == []
        assert tr.registry.count("engine/n_events") == 2

    def test_trace_level_retains_in_order(self):
        tr = Tracer(level="trace")
        a = tr.emit(AdvanceEvent(ts=1.0))
        b = tr.emit(FinishEvent(ts=2.0, rid=0))
        assert tr.events == [a, b]
        assert tr.registry.count("engine/n_events") == 1

    def test_level_validation(self):
        with pytest.raises(ValueError, match="observability level"):
            Tracer(level="verbose")
        with pytest.raises(ValueError, match="observability"):
            EngineConfig(observability="debug")


# ---------------------------------------------------------------------------
# bounded cost-model memo
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smoke_cfg():
    return dataclasses.replace(configs.get_config("qwen3_8b", smoke=True),
                               compute_dtype="float32")


class TestCostMemo:
    def test_cached_equals_uncached(self, smoke_cfg):
        warm = ArtemisCostModel(smoke_cfg)
        first = [(warm.price(n), warm.energy(n)) for n in (1, 7, 32)]
        again = [(warm.price(n), warm.energy(n)) for n in (1, 7, 32)]
        assert first == again, "memo hit changed the simulated price"
        cold = ArtemisCostModel(smoke_cfg)   # fresh memo
        assert [(cold.price(n), cold.energy(n))
                for n in (1, 7, 32)] == first

    def test_memo_is_bounded_lru(self, smoke_cfg):
        cm = ArtemisCostModel(smoke_cfg, memo_size=4)
        for n in range(1, 11):
            cm.price(n)
        assert len(cm._memo) == 4
        assert list(cm._memo) == [7, 8, 9, 10]
        cm.price(7)                  # touch 7 -> most recent
        cm.price(99)                 # evicts 8 (now least recent)
        assert list(cm._memo) == [9, 10, 7, 99]

    def test_validation(self, smoke_cfg):
        with pytest.raises(ValueError, match="memo_size"):
            ArtemisCostModel(smoke_cfg, memo_size=0)
        with pytest.raises(ValueError, match="n_tokens"):
            ArtemisCostModel(smoke_cfg).price(0)


# ---------------------------------------------------------------------------
# span assembly: malformed logs must be rejected
# ---------------------------------------------------------------------------


class TestSpanAssembly:
    def _good_log(self):
        return [
            QueuedEvent(ts=0.0, rid=0, prompt_len=8, max_new_tokens=2),
            AdmitEvent(ts=1.0, rid=0, lane=0),
            PrefillStepEvent(ts=2.0, chunks=((0, 8),), n_tokens=8,
                             dur_s=1.0),
            DecodeStepEvent(ts=3.0, decode_rids=(0,), n_tokens=1,
                            dur_s=1.0),
            FinishEvent(ts=3.0, rid=0, n_generated=2),
        ]

    def test_well_formed_log_assembles(self):
        trees = assemble_spans(self._good_log())
        tr = trees[0]
        assert tr.queued_at == 0.0 and tr.finished_at == 3.0
        assert tr.open_attempt_at is None
        assert [s.name for s in tr.attempts] == ["completed"]
        assert tr.attempts[0].t0 == 1.0 and tr.attempts[0].t1 == 3.0
        assert [s.name for s in tr.slices] == ["prefill_chunk", "decode"]

    def test_trailing_open_attempt_is_legal(self):
        trees = assemble_spans(self._good_log()[:3])   # mid-run export
        assert trees[0].open_attempt_at == 1.0
        assert trees[0].finished_at is None

    def test_finish_without_admit_rejected(self):
        with pytest.raises(ValueError, match="without an open admit"):
            assemble_spans([FinishEvent(ts=1.0, rid=0)])

    def test_double_admit_rejected(self):
        with pytest.raises(ValueError, match="still open"):
            assemble_spans([AdmitEvent(ts=1.0, rid=0, lane=0),
                            AdmitEvent(ts=2.0, rid=0, lane=1)])

    def test_slice_outside_attempt_rejected(self):
        with pytest.raises(ValueError, match="outside"):
            assemble_spans([DecodeStepEvent(ts=1.0, decode_rids=(0,),
                                            n_tokens=1, dur_s=0.5)])

    def test_non_monotone_timestamps_rejected(self):
        log = self._good_log()
        # finish stamped BEFORE the decode slice that produced it
        log[4] = dataclasses.replace(log[4], ts=2.5)
        with pytest.raises(ValueError, match="monotone"):
            assemble_spans(log)

    def test_admit_before_arrival_rejected(self):
        log = self._good_log()
        log[0] = dataclasses.replace(log[0], ts=1.5)
        with pytest.raises(ValueError, match="before its\n?.*arrival|"
                                             "precedes earlier"):
            assemble_spans(log)


# ---------------------------------------------------------------------------
# end-to-end: attribution + Chrome export over a real drain
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def drained_engine(smoke_cfg):
    cfg = smoke_cfg
    params = model.init(jax.random.PRNGKey(0), cfg)
    trace = synth_trace(TrafficConfig(
        n_requests=6, arrival_rate=1e8, prompt_len_min=3,
        prompt_len_max=18, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=9,
        sampled_fraction=0.4, temperature=0.8, top_k=20))
    def run():
        eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
            page_size=8, n_pages=48, max_batch=3, max_pages_per_seq=8,
            prefill_chunk=8, observability="trace"))
        eng.submit_trace(trace)
        eng.drain()
        return eng
    return run


class TestEndToEnd:
    def test_attribution_sums_to_total_energy(self, drained_engine):
        eng = drained_engine()
        m = eng.metrics()
        attr = eng.attribution()
        assert sorted(attr) == sorted(eng.requests)
        total = sum(a["total_energy_J"] for a in attr.values())
        assert total == pytest.approx(m["total_energy_J"], rel=1e-9)
        for phase in ("prefill", "decode", "sampling"):
            per_phase = sum(a["phases"][phase]["energy_J"]
                            for a in attr.values())
            assert per_phase == pytest.approx(
                m[f"{phase}_energy_J"], rel=1e-9, abs=1e-30)
        busy = sum(a["total_virtual_s"] for a in attr.values())
        assert busy == pytest.approx(m["busy_virtual_s"], rel=1e-9)
        # sampled tokens show up in the sampling phase at zero energy
        n_sampled = sum(a["phases"]["sampling"]["tokens"]
                        for a in attr.values())
        assert n_sampled == m["n_sampled_tokens"] > 0
        assert m["energy_per_token_J"] == pytest.approx(
            m["total_energy_J"] / m["n_generated_tokens"])

    def test_chrome_trace_valid_and_loads(self, drained_engine):
        eng = drained_engine()
        obj = to_chrome_trace(eng.events, metadata={"seed": 9})
        info = validate_chrome_trace(obj)
        assert info["n_spans"] > 0 and info["n_instants"] > 0
        # one engine track + one track per request
        assert info["n_tracks"] == len(eng.requests) + 1
        assert obj["metadata"]["seed"] == 9
        # round-trips through json
        assert json.loads(dumps_chrome_trace(obj)) == obj
        # every non-metadata event carries the required fields
        for e in obj["traceEvents"]:
            assert {"ph", "pid", "tid"} <= set(e)
            if e["ph"] != "M":
                assert isinstance(e["ts"], (int, float))
            if e["ph"] == "X":
                assert e["dur"] >= 0

    def test_chrome_export_byte_deterministic(self, drained_engine,
                                              tmp_path):
        # the golden-file pin: two independent drains of the same trace
        # export byte-identical files
        blobs = []
        for i in range(2):
            eng = drained_engine()
            path = tmp_path / f"trace_{i}.json"
            obslib.export_chrome_trace(eng.events, str(path),
                                       metadata={"seed": 9})
            blobs.append(path.read_bytes())
        assert blobs[0] == blobs[1], "export is not byte-deterministic"
        # and the CLI validator accepts the artifact
        assert obslib._main([str(tmp_path / "trace_0.json")]) == 0

    def test_cli_validator_rejects_corrupt_trace(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"traceEvents": [{"ph": "X"}]}))
        assert obslib._main([str(bad)]) == 1

    def test_validate_chrome_trace_rejections(self):
        with pytest.raises(ValueError, match="traceEvents"):
            validate_chrome_trace({})
        with pytest.raises(ValueError, match="non-empty"):
            validate_chrome_trace({"traceEvents": []})
        with pytest.raises(ValueError, match="missing 'ph'"):
            validate_chrome_trace({"traceEvents": [{"pid": 0, "tid": 0}]})
        with pytest.raises(ValueError, match="unknown ph"):
            validate_chrome_trace(
                {"traceEvents": [{"ph": "Z", "pid": 0, "tid": 0}]})
        with pytest.raises(ValueError, match="numeric 'ts'"):
            validate_chrome_trace(
                {"traceEvents": [{"ph": "i", "pid": 0, "tid": 0}]})
        with pytest.raises(ValueError, match="non-negative 'dur'"):
            validate_chrome_trace(
                {"traceEvents": [{"ph": "X", "pid": 0, "tid": 0,
                                  "ts": 1.0, "dur": -2.0}]})

    def test_span_tree_reconstructs_every_lifecycle(self, drained_engine):
        # the headline acceptance: a trace-level drain reconstructs
        # every request's lifecycle — queued wait, closed attempts,
        # execution slices, generated-token counts
        eng = drained_engine()
        trees = assemble_spans(eng.events)
        assert sorted(trees) == sorted(eng.requests)
        for rid, tr in trees.items():
            assert tr.queued_at is not None
            assert tr.finished_at is not None
            assert tr.open_attempt_at is None
            done = [s for s in tr.attempts if s.name == "completed"]
            assert len(done) == 1
            n_gen = dict(done[0].args)["n_generated"]
            assert n_gen == len(eng.results()[rid])
