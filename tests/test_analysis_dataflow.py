"""Unit tests for the dataflow framework under `repro.analysis` —
the CFG builder and forward solver the donation / allocator / host-sync
rules run on. Fixture-level behavior is pinned by
tests/analysis_fixtures; these tests pin the framework semantics the
rules assume: branch joins, loop fixpoints, exception edges (explicit
`raise`/`assert` only), try/finally routing, and flow-sensitive taint
laundering.
"""
from __future__ import annotations

import ast
import textwrap

from repro.analysis.cfg import build_cfg, shallow_walk
from repro.analysis.dataflow import (ForwardAnalysis, TaintAnalysis,
                                     atom_states, chain_str,
                                     exit_states, solve)


def _fn(src: str):
    return ast.parse(textwrap.dedent(src)).body[0]


def _taint_at_returns(src: str, params: set[str]) -> list[frozenset]:
    """In-state at every `return` atom, in source order."""
    fn = _fn(src)
    cfg = build_cfg(fn)
    analysis = TaintAnalysis(params)
    states = solve(cfg, analysis)
    out = []
    for atom, state in atom_states(cfg, analysis, states):
        if isinstance(atom, ast.Return):
            out.append((atom.lineno, state))
    return [s for _, s in sorted(out)]


class _GenNames(ForwardAnalysis):
    """Toy analysis: every assigned name becomes a fact, never killed
    — isolates edge structure from transfer subtleties."""

    def transfer(self, state, atom):
        if isinstance(atom, ast.Assign):
            names = {t.id for t in atom.targets
                     if isinstance(t, ast.Name)}
            return state | names
        return state


def test_chain_str():
    assert chain_str(ast.parse("self.cache.kv").body[0].value) \
        == "self.cache.kv"
    assert chain_str(ast.parse("pool").body[0].value) == "pool"
    assert chain_str(ast.parse("f(x).y").body[0].value) is None


def test_shallow_walk_stays_out_of_nested_scopes():
    stmt = ast.parse("x = [lambda: hidden, visible]").body[0]
    names = {n.id for n in shallow_walk(stmt)
             if isinstance(n, ast.Name)}
    assert "visible" in names and "hidden" not in names


def test_branch_join_is_union():
    states = _taint_at_returns("""
        def f(x, flag: bool):
            if flag:
                y = x + 1
            else:
                y = 0
            return y
    """, {"x"})
    # y MAY be tainted (then-branch): union join keeps it
    assert "y" in states[0]


def test_static_rebind_launders_taint():
    states = _taint_at_returns("""
        def f(x):
            y = x * 2
            y = x.shape[0]
            return y
    """, {"x"})
    assert "y" not in states[0]
    assert "x" in states[0]


def test_augassign_never_launders():
    states = _taint_at_returns("""
        def f(x, n: int):
            y = x * 2
            y += 1
            return y
    """, {"x"})
    assert "y" in states[0]


def test_loop_fixpoint_carries_taint_around_back_edge():
    states = _taint_at_returns("""
        def f(x, n: int):
            acc = 0
            for _ in range(n):
                acc = acc + x
            return acc
    """, {"x"})
    # taint acquired in iteration k is live at iteration k+1's header
    # and at the loop exit — requires the back-edge fixpoint
    assert "acc" in states[0]


def test_unreachable_code_keeps_empty_state():
    fn = _fn("""
        def f(x):
            return x
            y = x
    """)
    cfg = build_cfg(fn)
    analysis = TaintAnalysis({"x"})
    states = solve(cfg, analysis)
    dead = [state for atom, state in atom_states(cfg, analysis, states)
            if isinstance(atom, ast.Assign)]
    assert dead == [frozenset()]


def test_raise_reaches_raise_exit_not_exit():
    fn = _fn("""
        def f(cond):
            a = 1
            if cond:
                raise ValueError("boom")
            b = 2
            return b
    """)
    cfg = build_cfg(fn)
    analysis = _GenNames()
    states = solve(cfg, analysis)
    normal, exc = exit_states(cfg, analysis, states)
    assert "b" in normal
    assert "b" not in exc and "a" in exc


def test_except_handler_joins_state_from_every_try_point():
    fn = _fn("""
        def f():
            try:
                a = 1
                b = 2
            except RuntimeError:
                c = 3
            return c
    """)
    cfg = build_cfg(fn)
    analysis = _GenNames()
    states = solve(cfg, analysis)
    for atom, state in atom_states(cfg, analysis, states):
        if isinstance(atom, ast.Assign) and atom.targets[0].id == "c":
            # the exception may fire after `a` alone OR after both:
            # the handler's in-state is the union over all points
            assert "a" in state
    normal, _ = exit_states(cfg, analysis, states)
    assert {"a", "c"} <= normal or {"a", "b"} <= normal


def test_try_finally_without_except_routes_exception_through_finally():
    fn = _fn("""
        def f():
            a = 1
            try:
                raise ValueError("boom")
            finally:
                fin = 2
    """)
    cfg = build_cfg(fn)
    analysis = _GenNames()
    states = solve(cfg, analysis)
    fin_states = [state
                  for atom, state in atom_states(cfg, analysis, states)
                  if isinstance(atom, ast.Assign)
                  and atom.targets[0].id == "fin"]
    assert fin_states and all("a" in s for s in fin_states)
    _, exc = exit_states(cfg, analysis, states)
    # the uncaught exception still leaves the function, after finally
    assert "fin" in exc


def test_assert_creates_exception_edge():
    fn = _fn("""
        def f(n):
            a = 1
            assert n > 0
            b = 2
            return b
    """)
    cfg = build_cfg(fn)
    analysis = _GenNames()
    states = solve(cfg, analysis)
    normal, exc = exit_states(cfg, analysis, states)
    assert "a" in exc and "b" not in exc
    assert "b" in normal
