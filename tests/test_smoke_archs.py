"""Per-architecture smoke tests (brief requirement).

For each of the 10 assigned archs: instantiate the REDUCED (SMOKE) config,
run one forward pass + one train-style grad step + a prefill->decode
round-trip on CPU, asserting output shapes and no NaNs. FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.models import frontend, model

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 16


def _inputs(cfg, key, batch=B, seq=S):
    kt, kp = jax.random.split(key)
    tokens = jax.random.randint(
        kt, frontend.token_shape(cfg, batch, seq), 0, cfg.vocab_size,
        dtype=jnp.int32)
    inputs = {"tokens": tokens}
    if cfg.modality == "vlm":
        inputs["prefix_embeds"] = frontend.synth_prefix_embeds(
            kp, cfg, batch)[:, :4]  # tiny prefix for the smoke test
    return inputs


@pytest.fixture(scope="module", params=configs.ARCHS)
def arch_setup(request):
    name = request.param
    cfg = configs.get_config(name, smoke=True)
    params = model.init(jax.random.PRNGKey(0), cfg)
    return name, cfg, params


def test_forward_shapes_and_finite(arch_setup):
    name, cfg, params = arch_setup
    inputs = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux, cache = model.apply(params, cfg, inputs)
    prefix = 4 if cfg.modality == "vlm" else 0
    if cfg.modality == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, S + prefix, cfg.padded_vocab)
    assert cache is None
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


def test_train_grad_step(arch_setup):
    name, cfg, params = arch_setup
    inputs = _inputs(cfg, jax.random.PRNGKey(2))
    tokens = inputs["tokens"]

    def loss_fn(p):
        logits, aux, _ = model.apply(p, cfg, inputs)
        if cfg.modality == "vlm":
            logits = logits[:, -tokens.shape[1]:]
        return model.lm_loss(logits, tokens) + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat)
    # at least 99% of param leaves receive nonzero gradient signal
    nz = [float(jnp.max(jnp.abs(g))) > 0 for g in flat]
    assert np.mean(nz) > 0.9, f"{name}: too many dead grads"


def test_prefill_decode_consistency(arch_setup):
    """Prefill(S) then decode(1) must match a full forward at that position."""
    name, cfg, params = arch_setup
    inputs = _inputs(cfg, jax.random.PRNGKey(3))
    tokens = inputs["tokens"]
    max_len = S + 8

    full_logits, _, _ = model.apply(params, cfg, inputs)

    cache = model.init_cache(cfg, B, max_len, dtype=jnp.float32)
    pre_in = dict(inputs)
    pre_in["tokens"] = tokens[:, :-1] if cfg.modality != "audio" \
        else tokens[:, :-1, :]
    _, _, cache = model.apply(params, cfg, pre_in, cache=cache)

    last = tokens[:, -1:] if cfg.modality != "audio" else tokens[:, -1:, :]
    dec_logits, _, cache2 = model.apply(
        params, cfg, {"tokens": last}, cache=cache)

    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, -1], np.float32),
        rtol=2e-2, atol=2e-2)
    assert int(cache2["index"]) == S + (4 if cfg.modality == "vlm" else 0)


def test_artemis_policy_forward(arch_setup):
    """The paper's arithmetic must run through every arch (SC-MAC ladder)."""
    name, cfg, params = arch_setup
    inputs = _inputs(cfg, jax.random.PRNGKey(4))
    pol = ArithmeticPolicy(mode="artemis_mxu")
    logits, _, _ = model.apply(params, cfg, inputs, policy=pol)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # quantized forward should differ from exact but stay close
    exact, _, _ = model.apply(params, cfg, inputs)
    diff = float(jnp.mean(jnp.abs(
        logits.astype(jnp.float32) - exact.astype(jnp.float32))))
    scale = float(jnp.mean(jnp.abs(exact.astype(jnp.float32)))) + 1e-6
    assert 0.0 < diff / scale < 0.5, f"{name}: rel diff {diff/scale}"


def test_full_config_param_counts():
    """FULL configs match the assigned spec (layer/width/vocab sanity)."""
    expected = {
        "qwen3_14b": (40, 5120, 151936),
        "deepseek_coder_33b": (62, 7168, 32256),
        "qwen3_8b": (36, 4096, 151936),
        "gemma_2b": (18, 2048, 256000),
        "internvl2_1b": (24, 896, 151655),
        "musicgen_large": (48, 2048, 2048),
        "zamba2_7b": (81, 3584, 32000),
        "rwkv6_3b": (32, 2560, 65536),
        "dbrx_132b": (40, 6144, 100352),
        "qwen2_moe_a2_7b": (24, 2048, 151936),
    }
    for arch, (layers, d, v) in expected.items():
        cfg = configs.get_config(arch)
        assert cfg.n_layers == layers, arch
        assert cfg.d_model == d, arch
        assert cfg.vocab_size == v, arch


def test_cells_accounting():
    cells = configs.all_cells()
    assert len(cells) == 40
    runs = [c for c in cells if c[2] == "run"]
    skips = [c for c in cells if c[2] == "skip"]
    assert len(runs) == 32 and len(skips) == 8
    assert all(s == "long_500k" for _, s, st in skips if st == "skip")
