"""hwsim tests: device constants, geometry math, dataflow model claims."""
import dataclasses

import pytest

from repro.hwsim import (
    DEFAULT,
    DataflowConfig,
    DramGeometry,
    paper_models,
    simulate_breakdown,
    simulate_model,
)


class TestGeometry:
    def test_bank_counts(self):
        # Table I: 1 stack x 8 channels x 4 banks = 32 banks
        assert DEFAULT.n_banks == 32
        assert DEFAULT.active_subarrays_per_bank == 64

    def test_headline_mac_throughput(self):
        """Paper §II.E: 64 MACs in 48 ns per subarray — our geometry's
        sustained rate must be within 2x of that headline (the 48 ns is
        the paper's pipelined number; our model is the unpipelined round
        amortized per tile)."""
        geo = DramGeometry(DEFAULT)
        assert geo.macs_per_subarray == 64
        rate_paper = 64 / 48e-9
        round_ns = geo.mac_round_latency_ns()
        rate_ours = (geo.macs_per_subarray * DEFAULT.momcap_depth
                     * DEFAULT.caps_per_tile / 2) / (round_ns * 1e-9)
        assert rate_ours > rate_paper / 2

    def test_mul_latency_is_34ns(self):
        assert DEFAULT.t_mul_ns == 2 * DEFAULT.t_moc_ns == 34.0

    def test_power_budget_sane(self):
        """MAC energy at full throughput must be same order as the 60 W
        budget (not 100x over — the bank-level activate amortization)."""
        geo = DramGeometry(DEFAULT)
        macs_per_s = (geo.total_concurrent_macs
                      * DEFAULT.momcap_depth * DEFAULT.caps_per_tile
                      / (geo.mac_round_latency_ns() * 1e-9))
        w = geo.mac_energy_pj(int(macs_per_s)) * 1e-12
        assert w < 60 * 5, f"MAC power {w:.0f} W vastly over budget"


class TestDataflow:
    @pytest.fixture(scope="class")
    def results(self):
        out = {}
        for name, w in paper_models().items():
            out[name] = {
                s: simulate_model(w, DataflowConfig(scheme=s))
                for s in ("layer_NP", "layer_PP", "token_NP", "token_PP")}
        return out

    def test_token_beats_layer(self, results):
        for name, r in results.items():
            assert r["token_PP"].latency_ns < r["layer_NP"].latency_ns / 3

    def test_pipelining_helps(self, results):
        for name, r in results.items():
            assert r["layer_PP"].latency_ns <= r["layer_NP"].latency_ns
            assert r["token_PP"].latency_ns <= r["token_NP"].latency_ns
            assert r["token_PP"].energy_pj <= r["token_NP"].energy_pj

    def test_fig8_aggregates_near_paper(self, results):
        import statistics
        sp = [r["layer_NP"].latency_ns / r["token_NP"].latency_ns
              for r in results.values()]
        en = [r["layer_NP"].energy_pj / r["token_NP"].energy_pj
              for r in results.values()]
        assert 11.0 / 2 < statistics.mean(sp) < 11.0 * 2   # paper 11.0x
        assert 3.5 / 2 < statistics.mean(en) < 3.5 * 2     # paper 3.5x

    def test_energy_within_power_budget(self, results):
        """E/t must respect the 60 W envelope (soft: 2x, since latency
        is the pipelined critical path, not average occupancy)."""
        for name, r in results.items():
            t = r["token_PP"]
            watts = (t.energy_pj * 1e-12) / (t.latency_ns * 1e-9)
            assert watts < 120, f"{name}: {watts:.0f} W"

    def test_breakdown_matmul_dominates(self):
        for name, w in paper_models().items():
            b = simulate_breakdown(w)
            assert b["matmul"] > 0.9, (name, b)

    def test_stack_scaling_monotonic(self):
        w = dataclasses.replace(paper_models()["bert_base"],
                                n_tokens=2048)
        lats = [simulate_model(w, DataflowConfig(),
                               n_stacks=s).latency_ns for s in (1, 2, 4)]
        assert lats[0] > lats[1] > lats[2]
