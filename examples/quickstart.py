"""Quickstart: the ARTEMIS arithmetic ladder in 60 seconds.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import (
    ARTEMIS, EXACT, INT8, ArithmeticPolicy, artemis_matmul, artemis_softmax,
    sc_multiply, sc_multiply_bitstream,
)
from repro.models import model

# ---------------------------------------------------------------------------
# 1. The deterministic stochastic multiply (paper §III.A.1).
#    128-bit TCU streams; AND + popcount == floor(a*b/128).
# ---------------------------------------------------------------------------
a, b = jnp.int32(100), jnp.int32(90)
print("bitstream popcount :", sc_multiply_bitstream(a, b))
print("closed form        :", sc_multiply(a, b), "= floor(100*90/128)")

# ---------------------------------------------------------------------------
# 2. A matmul through the full ARTEMIS MAC pipeline: int8 quantization,
#    TCU floor-multiplies, MOMCAP group-of-20 analog accumulation,
#    quantizing A_to_B readout, NSC sign-split reduction.
# ---------------------------------------------------------------------------
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 64))
w = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
exact = x @ w
for name, policy in [("exact", EXACT), ("int8", INT8), ("artemis", ARTEMIS)]:
    out = artemis_matmul(x, w, policy)
    err = float(jnp.mean(jnp.abs(out - exact)) / jnp.mean(jnp.abs(exact)))
    print(f"{name:8s} mean rel err vs fp32: {err:.4f}")

# ---------------------------------------------------------------------------
# 3. The division-free LSE softmax with NSC LUT emulation (paper Eq. 5).
# ---------------------------------------------------------------------------
y = jax.random.normal(jax.random.PRNGKey(2), (4, 16)) * 3
ref = jax.nn.softmax(y, axis=-1)
lut = artemis_softmax(y, axis=-1)
print("LUT softmax max err:", float(jnp.max(jnp.abs(lut - ref))))

# ---------------------------------------------------------------------------
# 4. A full model forward under ARTEMIS arithmetic (qwen3-8b, smoke size).
# ---------------------------------------------------------------------------
cfg = configs.get_config("qwen3_8b", smoke=True)
params = model.init(jax.random.PRNGKey(3), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0,
                            cfg.vocab_size, dtype=jnp.int32)
logits_exact, _, _ = model.apply(params, cfg, {"tokens": tokens})
logits_artemis, _, _ = model.apply(
    params, cfg, {"tokens": tokens},
    policy=ArithmeticPolicy(mode="artemis_mxu"))
drift = float(jnp.mean(jnp.abs(
    logits_artemis.astype(jnp.float32) - logits_exact.astype(jnp.float32))))
print(f"model logits drift under ARTEMIS arithmetic: {drift:.4f}")
print("OK")
