"""Accuracy ablation across the arithmetic ladder (paper Table IV's shape).

Trains a tiny transformer on a learnable task (sequence copy), then
evaluates token accuracy under exact / int8 / artemis / artemis_mxu
inference arithmetic — the FP32 vs Q(8-bit) vs Q(8-bit)+SC comparison.

Run: PYTHONPATH=src python examples/accuracy_ablation.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.policy import ArithmeticPolicy
from repro.data.pipeline import synthetic_task_batch
from repro.launch.steps import make_train_step
from repro.models import model
from repro.optim import OptimizerConfig, adamw_init

TASK, N, VOCAB = "copy", 12, 64
STEPS, BATCH = 600, 64


def eval_accuracy(params, cfg, policy, n_batches=8):
    correct = total = 0
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(999), i)
        tokens, mask = synthetic_task_batch(key, TASK, BATCH, N, VOCAB)
        logits, _, _ = model.apply(params, cfg, {"tokens": tokens},
                                   policy=policy)
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        tgt = tokens[:, 1:]
        m = mask[:, 1:] > 0
        correct += int(jnp.sum((pred == tgt) & m))
        total += int(jnp.sum(m))
    return correct / total


def main():
    cfg = configs.get_config("qwen3_8b", smoke=True)
    cfg = type(cfg)(**{**cfg.__dict__, "vocab_size": VOCAB,
                       "vocab_round_to": 16, "name": "ablation-lm"})
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    opt_cfg = OptimizerConfig(lr=3e-3, total_steps=STEPS, warmup_steps=30,
                              weight_decay=0.01)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    for step in range(STEPS):
        key = jax.random.fold_in(jax.random.PRNGKey(0), step)
        tokens, mask = synthetic_task_batch(key, TASK, BATCH, N, VOCAB)
        batch = {"tokens": tokens,
                 "labels": jnp.concatenate(
                     [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], 1)}
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 50 == 0:
            print(f"step {step:4d} loss {float(metrics['loss']):.3f}")

    print(f"\n{'mode':12s} {'token accuracy':>14s}   (paper Table IV shape)")
    for mode in ("exact", "int8", "artemis_mxu"):
        acc = eval_accuracy(params, cfg, ArithmeticPolicy(mode=mode,
                                                          ste=False))
        label = {"exact": "FP32", "int8": "Q(8-bit)",
                 "artemis_mxu": "Q(8-bit)+SC"}[mode]
        print(f"{label:12s} {acc:14.1%}")


if __name__ == "__main__":
    main()
