"""End-to-end serving driver (the paper's regime is inference).

Serves a small model with batched requests: one prefill over the prompt
batch, then token-by-token decode with greedy sampling — with the
ARTEMIS arithmetic ladder applied to every matmul, and the KV cache
exercised exactly as the decode_32k dry-run cells lower it.

Run: PYTHONPATH=src python examples/serve_batched.py [--policy artemis_mxu]
"""
import argparse

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--policy", default="exact")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()

    print(f"serving {args.arch} (smoke config) with policy={args.policy}")
    out = serve(arch=args.arch, smoke=True, batch=args.batch,
                prompt_len=48, gen_len=args.gen_len,
                policy_mode=args.policy)
    print(f"prefill: {out['prefill_s']*1e3:7.1f} ms")
    print(f"decode : {out['decode_tok_per_s']:7.1f} tok/s "
          f"({args.batch} streams)")
    print(f"tokens : {out['generated'][0][:12].tolist()} ...")
    print(f"cache index after run: {out['cache_index']}")


if __name__ == "__main__":
    main()
