"""Continuous-batching engine walkthrough.

Submits a handful of mixed-length requests — greedy plus one
stochastic (temperature/top-k/top-p on its own RNG lane) — to the
`repro.serve` engine, steps it manually (so you can watch the
scheduler compose chunked prefill batches with decode into mixed
steps), then drains and prints the per-request outputs and engine
metrics.

Every family rides the same engine via the `SequenceBackend` API: the
default qwen3_8b arch serves over the paged-KV backend (watch for
"share" events — two of the requests share a resident prompt prefix
copy-on-write), while `--arch rwkv6_3b` (or zamba2_7b) serves over the
state-slot backend, where each request holds one fixed-size recurrent
state slot instead of growing KV pages.

Run: PYTHONPATH=src python examples/serve_engine.py
         [--arch rwkv6_3b] [--scheduler fcfs] [--prefill-chunk 8]
"""
import argparse
import dataclasses

import numpy as np

from repro import configs
from repro.serve import EngineConfig, SamplingParams, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--scheduler", default="cost",
                    choices=["cost", "fcfs"])
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens per prefill chunk (small, so "
                         "the 24-token prompt visibly spans steps)")
    args = ap.parse_args()

    cfg = dataclasses.replace(configs.get_config(args.arch, smoke=True),
                              compute_dtype="float32")
    # observability="trace" retains the structured event log this
    # walkthrough reads back (the default "metrics" level keeps only
    # counters/histograms and retains no events)
    eng = ServeEngine(cfg, ecfg=EngineConfig(
        page_size=8, n_pages=64, max_batch=3, max_pages_per_seq=8,
        max_seq_len=64, prefill_chunk=args.prefill_chunk,
        scheduler=args.scheduler, observability="trace"))
    print(f"arch {cfg.name} ({cfg.family}) served by "
          f"{type(eng.backend).__name__}")

    rng = np.random.default_rng(0)
    print(f"submitting 7 requests with mixed prompt/gen lengths "
          f"({args.scheduler} scheduler)")
    prefix = rng.integers(2, cfg.vocab_size, 17).astype(np.int32)
    for plen, glen in ((5, 8), (17, 4), (9, 12), (3, 6), (24, 5)):
        if plen == 17:
            prompt = prefix                # resident prefix for 5 and 6
        else:
            prompt = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        rid = eng.submit(prompt, max_new_tokens=glen)
        print(f"  request {rid}: prompt {plen} tokens, gen {glen}")
    # two late arrivals extend request 1's prompt: admission matches
    # its resident full pages and shares them copy-on-write (watch for
    # "share" events and a prefix hit rate > 0 in the metrics)
    for _ in range(2):
        prompt = np.concatenate(
            [prefix, rng.integers(2, cfg.vocab_size, 4).astype(np.int32)])
        rid = eng.submit(prompt, max_new_tokens=4, arrival_time=1e-6)
        print(f"  request {rid}: prompt {len(prompt)} tokens (shares "
              f"request 1's 17-token prompt as a prefix), gen 4")
    # one stochastic request rides the same batches on its own RNG
    # lane: its tokens are deterministic for (seed, prompt, params)
    # no matter how the scheduler packs it with the greedy lanes
    sampled = eng.submit(
        rng.integers(2, cfg.vocab_size, 7).astype(np.int32),
        max_new_tokens=6, arrival_time=1e-6,
        sampling=SamplingParams(temperature=0.9, top_k=40, top_p=0.95,
                                seed=7))
    print(f"  request {sampled}: prompt 7 tokens, gen 6 SAMPLED "
          f"(temperature 0.9, top-k 40, top-p 0.95, seed 7)")

    print("\nfirst 10 engine steps:")
    for _ in range(10):
        ev = eng.step()
        if ev is None:
            break
        kind = ev[0]
        if kind == "prefill":
            chunks = ", ".join(f"rid {r}+{n}t" for r, n in ev[1])
            print(f"  prefill  chunks [{chunks}]")
        elif kind == "decode":
            print(f"  decode   lanes={list(ev[1])}")
        elif kind == "mixed":
            chunks = ", ".join(f"rid {r}+{n}t" for r, n in ev[1])
            print(f"  mixed    chunks [{chunks}] + decode "
                  f"lanes={list(ev[2])}")
        else:
            print(f"  {kind}")
    eng.drain()

    shares = [e for e in eng.events if e[0] == "share"]
    if shares:
        print("\nprefix sharing (from the event log):")
        for _, rid, matched, _t in shares:
            print(f"  request {rid} admitted over {matched} resident "
                  f"prefix tokens (pages shared copy-on-write)")

    print("\nresults:")
    for rid, toks in eng.results().items():
        tag = "" if eng.requests[rid].sampling.greedy else "  (sampled)"
        print(f"  request {rid}: {toks[:10].tolist()}"
              f"{' ...' if len(toks) > 10 else ''}{tag}")
    m = eng.metrics()
    line = (f"\n{m['n_generated_tokens']} tokens | cache utilization "
            f"{m['cache_utilization']:.2f} (logical "
            f"{m['logical_cache_utilization']:.2f})")
    if "prefix_hit_rate" in m:      # paged-KV backend extras
        line += (f" | prefix hit rate {m['prefix_hit_rate']:.2f} | "
                 f"{m['n_cow_forks']} COW forks")
    if "n_state_slots" in m:        # state-slot backend extras
        line += f" | {m['n_state_slots']} state slots"
    print(line + f" | {m['n_sampled_tokens']} sampled tokens | "
          f"{m['n_preemptions']} preemptions | "
          f"{m['n_events']} engine events")
    print(f"energy: {m['total_energy_J']*1e6:.2f} uJ total "
          f"({m['energy_per_token_J']*1e9:.2f} nJ/token) — prefill "
          f"{m['prefill_energy_J']*1e6:.2f} uJ, decode "
          f"{m['decode_energy_J']*1e6:.2f} uJ")
    # per-request attribution: where each request's joules went
    print("per-request energy attribution (nJ):")
    for rid, a in eng.attribution().items():
        ph = a["phases"]
        print(f"  request {rid}: prefill "
              f"{ph['prefill']['energy_J']*1e9:8.1f} | decode "
              f"{ph['decode']['energy_J']*1e9:8.1f} | "
              f"{ph['sampling']['tokens']} sampled tokens")


if __name__ == "__main__":
    main()
