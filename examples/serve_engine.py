"""Continuous-batching engine walkthrough.

Submits a handful of mixed-length requests to the `repro.serve` engine,
steps it manually (so you can watch the scheduler interleave prefill
and decode over the paged KV cache), then drains and prints the
per-request outputs and engine metrics.

Run: PYTHONPATH=src python examples/serve_engine.py [--scheduler fcfs]
"""
import argparse
import dataclasses

import numpy as np

from repro import configs
from repro.serve import EngineConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--scheduler", default="cost",
                    choices=["cost", "fcfs"])
    args = ap.parse_args()

    cfg = dataclasses.replace(configs.get_config(args.arch, smoke=True),
                              compute_dtype="float32")
    eng = ServeEngine(cfg, ecfg=EngineConfig(
        page_size=8, n_pages=64, max_batch=3, max_pages_per_seq=8,
        scheduler=args.scheduler))

    rng = np.random.default_rng(0)
    print(f"submitting 5 requests with mixed prompt/gen lengths "
          f"({args.scheduler} scheduler)")
    for plen, glen in ((5, 8), (17, 4), (9, 12), (3, 6), (24, 5)):
        prompt = rng.integers(2, cfg.vocab_size, plen).astype(np.int32)
        rid = eng.submit(prompt, max_new_tokens=glen)
        print(f"  request {rid}: prompt {plen} tokens, gen {glen}")

    print("\nfirst 8 engine steps:")
    for _ in range(8):
        ev = eng.step()
        if ev is None:
            break
        kind = ev[0]
        if kind == "prefill":
            print(f"  prefill  rid={ev[1]} (padded to {ev[2]} tokens)")
        elif kind == "decode":
            print(f"  decode   lanes={list(ev[1])}")
        else:
            print(f"  {kind}")
    eng.drain()

    print("\nresults:")
    for rid, toks in eng.results().items():
        print(f"  request {rid}: {toks[:10].tolist()}"
              f"{' ...' if len(toks) > 10 else ''}")
    m = eng.metrics()
    print(f"\n{m['n_generated_tokens']} tokens | cache utilization "
          f"{m['cache_utilization']:.2f} | {m['n_preemptions']} "
          f"preemptions | {len(eng.events)} engine steps")


if __name__ == "__main__":
    main()
