"""Train a ~10M-param LM for a few hundred steps (end-to-end driver).

Demonstrates: deterministic data pipeline, AdamW + cosine schedule,
checkpoint/save/restore mid-run (the job literally restarts itself), and
loss decreasing under both exact and ARTEMIS arithmetic.

Run: PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--policy", default="exact")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        half = args.steps // 2
        print(f"=== phase 1: steps 0..{half} (then simulated preemption)")
        out1 = train(arch=args.arch, smoke=True, steps=half,
                     policy_mode=args.policy, ckpt_dir=ckpt,
                     save_every=max(half // 2, 10))
        print(f"\n=== phase 2: auto-resume -> step {args.steps}")
        out2 = train(arch=args.arch, smoke=True, steps=args.steps,
                     policy_mode=args.policy, ckpt_dir=ckpt,
                     save_every=max(half // 2, 10))
        print(f"\nloss: {out1['first_loss']:.3f} -> {out2['final_loss']:.3f}"
              f" (policy={args.policy})")
        assert out2["final_loss"] < out1["first_loss"], "loss did not drop"
        print("OK — trained through a checkpoint/restart boundary")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
