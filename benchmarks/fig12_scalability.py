"""Fig 12 reproduction: speedup vs input sequence length for growing HBM
stack counts. The paper's finding: larger configurations yield
near-linear gains on long sequences (more token groups fit -> fewer
remaps), so ARTEMIS scales to long-sequence workloads.
"""
from __future__ import annotations

import dataclasses

from repro.hwsim import DataflowConfig, paper_models, simulate_model

SEQ_LENS = (128, 512, 2048, 8192)
STACKS = (1, 2, 4, 8)


def run() -> list[dict]:
    rows = []
    base_model = paper_models()["bert_base"]
    print(f"{'seq':>6s}" + "".join(f" {s}-stack" for s in STACKS)
          + "   (speedup vs 1-stack @ same seq)")
    for seq in SEQ_LENS:
        w = dataclasses.replace(base_model, n_tokens=seq)
        lat1 = simulate_model(w, DataflowConfig(), n_stacks=1).latency_ns
        cells = []
        row = {"seq": seq}
        for s in STACKS:
            lat = simulate_model(w, DataflowConfig(), n_stacks=s).latency_ns
            sp = lat1 / lat
            row[f"stacks_{s}"] = sp
            cells.append(f"{sp:7.2f}x")
        print(f"{seq:6d}" + "".join(f" {c}" for c in cells))
        rows.append(row)
    # scaling efficiency on the longest sequence
    eff = rows[-1][f"stacks_{STACKS[-1]}"] / STACKS[-1]
    print(f"\n{STACKS[-1]}-stack scaling efficiency at seq "
          f"{SEQ_LENS[-1]}: {eff:.0%} (paper: 'approaching near-linear')")
    return rows


if __name__ == "__main__":
    run()
