"""Fig 8 reproduction: dataflow x pipelining sensitivity (hwsim).

Four schemes per workload; speedup + energy normalized to layer_NP.
Paper aggregates: token-vs-layer 11.0x speedup / 3.5x energy; pipelining
1.50x (layer) / 1.43x (token) speedup, 1.42x / 1.43x energy.
"""
from __future__ import annotations

import statistics

from repro.hwsim import DataflowConfig, paper_models, simulate_model

SCHEMES = ("layer_NP", "layer_PP", "token_NP", "token_PP")

PAPER_AGG = {"sp_tok": 11.0, "en_tok": 3.5, "sp_ppl": 1.50,
             "sp_ppt": 1.43, "en_ppl": 1.42, "en_ppt": 1.43}


def run() -> list[dict]:
    rows = []
    agg = {k: [] for k in PAPER_AGG}
    print(f"{'model':18s}" + "".join(f" {s:>16s}" for s in SCHEMES[1:]))
    for name, w in paper_models().items():
        res = {s: simulate_model(w, DataflowConfig(scheme=s))
               for s in SCHEMES}
        base = res["layer_NP"]
        row = {"model": name}
        cells = []
        for s in SCHEMES[1:]:
            sp = base.latency_ns / res[s].latency_ns
            en = base.energy_pj / res[s].energy_pj
            row[f"{s}_speedup"] = sp
            row[f"{s}_energy"] = en
            cells.append(f"{sp:6.1f}x/E{en:4.1f}x")
        print(f"{name:18s}" + "".join(f" {c:>16s}" for c in cells))
        rows.append(row)
        agg["sp_tok"].append(base.latency_ns / res["token_NP"].latency_ns)
        agg["en_tok"].append(base.energy_pj / res["token_NP"].energy_pj)
        agg["sp_ppl"].append(base.latency_ns / res["layer_PP"].latency_ns)
        agg["sp_ppt"].append(res["token_NP"].latency_ns
                             / res["token_PP"].latency_ns)
        agg["en_ppl"].append(base.energy_pj / res["layer_PP"].energy_pj)
        agg["en_ppt"].append(res["token_NP"].energy_pj
                             / res["token_PP"].energy_pj)
    print("\naggregate (ours vs paper):")
    for k, target in PAPER_AGG.items():
        ours = statistics.mean(agg[k])
        print(f"  {k:8s} {ours:6.2f} vs {target:5.2f}")
        rows.append({"metric": k, "ours": ours, "paper": target})
    return rows


if __name__ == "__main__":
    run()
