"""Serving-engine throughput benchmark — the perf trajectory anchor.

Drives the continuous-batching engine over a deterministic Poisson
trace and emits one BENCH JSON line (plus a sidecar file) with
wall-clock tok/s, virtual p50/p99 request latency, cache utilization
and preemption count, for both scheduler policies. Smoke mode (the
default) runs the qwen3-8b smoke config on CPU in seconds.

Run: PYTHONPATH=src python -m benchmarks.serve_throughput [--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax

from repro import configs
from repro.models import model
from repro.serve import EngineConfig, ServeEngine, TrafficConfig, synth_trace

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_PATH = os.path.join(HERE, "serve_throughput.json")


def _bench_one(cfg, params, scheduler: str, n_requests: int,
               seed: int) -> dict:
    ecfg = EngineConfig(page_size=8, n_pages=128, max_batch=4,
                        max_pages_per_seq=16, scheduler=scheduler)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
    trace = synth_trace(TrafficConfig(
        n_requests=n_requests, arrival_rate=1e6,   # saturating load
        prompt_len_min=4, prompt_len_max=40,
        gen_len_min=4, gen_len_max=24,
        vocab_size=cfg.vocab_size, seed=seed))
    eng.submit_trace(trace)
    t0 = time.time()
    eng.drain()
    wall = time.time() - t0
    m = eng.metrics()
    return {
        "scheduler": scheduler,
        "n_requests": m["n_done"],
        "n_tokens": m["n_generated_tokens"],
        "wall_s": wall,
        "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
        "virtual_tok_per_s": m["virtual_tok_per_s"],
        "p50_latency_s": m["p50_latency_s"],
        "p99_latency_s": m["p99_latency_s"],
        "mean_ttft_s": m["mean_ttft_s"],
        "cache_utilization": m["cache_utilization"],
        "n_preemptions": m["n_preemptions"],
        "n_engine_steps": len(eng.events),
    }


def run(smoke: bool = True, arch: str = "qwen3_8b",
        n_requests: int = 12, seed: int = 0) -> list[dict]:
    cfg = configs.get_config(arch, smoke=smoke)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(seed), cfg)
    rows = []
    for scheduler in ("cost", "fcfs"):
        row = _bench_one(cfg, params, scheduler, n_requests, seed)
        rows.append(row)
        print(f"  {scheduler:5s} | {row['tok_per_s']:8.1f} tok/s wall "
              f"| p50 {row['p50_latency_s']*1e3:8.3f} ms "
              f"| p99 {row['p99_latency_s']*1e3:8.3f} ms (virtual) "
              f"| util {row['cache_utilization']:.2f} "
              f"| {row['n_preemptions']} preempt")
    bench = {"bench": "serve_throughput", "arch": cfg.name,
             "smoke": smoke, "seed": seed, "rows": rows}
    with open(OUT_PATH, "w") as f:
        json.dump(bench, f, indent=2)
    print("BENCH " + json.dumps(bench))
    print(f"wrote {OUT_PATH}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--n-requests", type=int, default=12)
    args = ap.parse_args()
    run(smoke=not args.full, arch=args.arch, n_requests=args.n_requests)


if __name__ == "__main__":
    main()
