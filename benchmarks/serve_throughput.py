"""Serving-engine throughput benchmark — the perf trajectory anchor.

Drives the continuous-batching engine over a deterministic Poisson
trace and emits one BENCH JSON line (plus a sidecar file) with
wall-clock tok/s, virtual p50/p99 request latency and TTFT, cache
utilization and preemption count, for both scheduler policies — plus a
long-prompt head-of-line-blocking trace comparing the chunked+mixed
cost scheduler against the unchunked prompt-first baseline, and a
shared-prefix trace (N prefix groups x per-request suffixes) comparing
copy-on-write prefix sharing against the same engine with sharing
disabled: prefix hit rate, physical pages allocated, COW forks, and
physical vs logical cache utilization land in the JSON so CI captures
the hit-rate trajectory per PR — plus a RECURRENT trace (rwkv6 through
the state-slot backend) so the recurrent families' throughput and TTFT
are part of the per-run artifact now that every family routes through
the one engine — plus a SAMPLED-DECODE trace (half the requests on
stochastic temperature/top-k/top-p RNG lanes, half greedy) reporting
tok/s and TTFT against the all-greedy run of the same trace shape, so
the cost of the batched sampler rides the per-run artifact too — plus
a SHARDED-DECODE trace (mesh_shards=8 through the tensor-parallel
ShardedPagedBackend on a simulated host mesh, skipped with a reason
when fewer than 8 devices are visible) against the single-device run
of the same trace, reporting the tok/s / TTFT / energy-per-token
ratios so the sharded step's trajectory lands in the artifact too —
plus a FUSED-ATTENTION trace (attn_impl="fused", the Pallas paged
kernel that walks block tables in-kernel, vs the default gather path;
skipped with a reason on CPU-only runners where the kernel would run
interpreted) reporting wall and decode-phase tok/s, TTFT and
energy-per-token ratios.

Every trace row additionally reports `energy_per_token_J` — the
ARTEMIS cost model's total simulated energy for the drain divided by
generated tokens — so the perf trajectory captures efficiency, not
just tok/s (the per-phase split is in `engine.metrics()` and the
Chrome trace export; see repro.serve.obs).

Timing: an UNTIMED warmup drain (a throwaway engine over the same
compiled steps — they are shared per (cfg, policy), see
`repro.serve.backend._paged_steps` / `_slot_steps`) absorbs jit
compilation of the chunked-prefill and decode steps; `compile_s`
reports it separately so `tok_per_s` tracks steady-state throughput
across PRs instead of XLA compile time.

Wall-clock use here is intentional (this file MEASURES real tok/s and
compile seconds) and carries `repro: allow[wall-clock-in-serve]`
markers — the virtual-clock contract applies to serve-layer logic,
not to the harness timing it.

Run: PYTHONPATH=src python -m benchmarks.serve_throughput [--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro import configs
from repro.models import model
from repro.serve import EngineConfig, ServeEngine, TrafficConfig, synth_trace

HERE = os.path.dirname(os.path.abspath(__file__))
OUT_PATH = os.path.join(HERE, "serve_throughput.json")

ECFG = dict(page_size=8, n_pages=128, max_batch=4, max_pages_per_seq=16)


def _warmup(cfg, params, seed: int) -> float:
    """Untimed-by-the-rows warmup: drain a throwaway engine so the
    chunked-prefill and decode steps are compiled before any timed
    drain runs. Returns the wall seconds it absorbed (compile_s)."""
    eng = ServeEngine(cfg, params=params,
                      ecfg=EngineConfig(**ECFG, prefill_chunk=16),
                      seed=seed)
    rng = np.random.default_rng(seed)
    for plen, glen in ((20, 4), (7, 3)):
        eng.submit(rng.integers(2, cfg.vocab_size, plen).astype(np.int32),
                   max_new_tokens=glen)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    eng.drain()
    return time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result


def _bench_one(cfg, params, scheduler: str, n_requests: int,
               seed: int) -> dict:
    ecfg = EngineConfig(**ECFG, prefill_chunk=16, scheduler=scheduler)
    eng = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
    trace = synth_trace(TrafficConfig(
        n_requests=n_requests, arrival_rate=1e6,   # saturating load
        prompt_len_min=4, prompt_len_max=40,
        gen_len_min=4, gen_len_max=24,
        vocab_size=cfg.vocab_size, seed=seed))
    eng.submit_trace(trace)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    eng.drain()
    wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    m = eng.metrics()
    return {
        "scheduler": scheduler,
        "n_requests": m["n_done"],
        "n_tokens": m["n_generated_tokens"],
        "wall_s": wall,
        "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
        "virtual_tok_per_s": m["virtual_tok_per_s"],
        "p50_latency_s": m["p50_latency_s"],
        "p99_latency_s": m["p99_latency_s"],
        "mean_ttft_s": m["mean_ttft_s"],
        "p99_ttft_s": m["p99_ttft_s"],
        "cache_utilization": m["cache_utilization"],
        "n_preemptions": m["n_preemptions"],
        "n_engine_steps": m["n_events"],
        "energy_per_token_J": m["energy_per_token_J"],
        "total_energy_J": m["total_energy_J"],
    }


def _bench_long_prompt(cfg, params, seed: int) -> dict:
    """Head-of-line blocking trace: one long prompt arrives first, a
    burst of short prompts lands while it would still be prefilling.
    Chunked+mixed (cost) vs unchunked prompt-first (fcfs, chunk >=
    prompt — the seed engine's behavior). Virtual-clock TTFTs are
    deterministic, so the p99 gap is a stable trajectory signal. The
    chunk rides the per-token price minimum (~96 tokens under token_PP)
    while the 1024-token prompt sits in the superlinear O(N^2) regime,
    so chunking also speeds up the long request itself."""
    long_len, n_short = 1024, 6
    rng = np.random.default_rng(seed)
    reqs = [(rng.integers(2, cfg.vocab_size, long_len).astype(np.int32),
             4, 0.0)]
    for i in range(n_short):
        reqs.append((rng.integers(
            2, cfg.vocab_size, int(rng.integers(4, 12))).astype(np.int32),
            6, 1e-7 * (i + 1)))
    row = {"trace": "long_prompt", "long_len": long_len,
           "n_short": n_short}
    for label, scheduler, chunk in (("chunked_cost", "cost", 96),
                                    ("unchunked_fcfs", "fcfs", long_len)):
        eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
            page_size=8, n_pages=160, max_batch=4, max_pages_per_seq=132,
            prefill_chunk=chunk, scheduler=scheduler), seed=seed)
        for prompt, glen, at in reqs:
            eng.submit(prompt, max_new_tokens=glen, arrival_time=at)
        eng.drain()
        m = eng.metrics()
        row[label] = {"p99_ttft_s": m["p99_ttft_s"],
                      "mean_ttft_s": m["mean_ttft_s"],
                      "p99_latency_s": m["p99_latency_s"],
                      "energy_per_token_J": m["energy_per_token_J"]}
    row["p99_ttft_speedup"] = (row["unchunked_fcfs"]["p99_ttft_s"]
                               / max(row["chunked_cost"]["p99_ttft_s"],
                                     1e-12))
    return row


def _bench_shared_prefix(cfg, params, seed: int) -> dict:
    """Shared-prefix trace: 4 prefix groups, each prefix 20 tokens
    (2.5 pages of 8), per-request random suffixes — the few-shot /
    system-prompt traffic shape. COW prefix sharing should report a
    positive hit rate and allocate strictly fewer physical pages than
    the no-sharing baseline, at bit-identical outputs (the equality is
    pinned in tests; here both sides are reported for the trajectory)."""
    tcfg = TrafficConfig(
        n_requests=16, arrival_rate=2e6, prompt_len_min=2,
        prompt_len_max=10, gen_len_min=2, gen_len_max=8,
        vocab_size=cfg.vocab_size, seed=seed,
        n_prefix_groups=4, prefix_len=20)
    trace = synth_trace(tcfg)
    row = {"trace": "shared_prefix",
           "n_prefix_groups": tcfg.n_prefix_groups,
           "prefix_len": tcfg.prefix_len,
           "n_requests": tcfg.n_requests}
    for label, sharing in (("sharing", True), ("no_sharing", False)):
        eng = ServeEngine(cfg, params=params, ecfg=EngineConfig(
            **ECFG, prefill_chunk=16, prefix_sharing=sharing), seed=seed)
        eng.submit_trace(trace)
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng.drain()
        wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        m = eng.metrics()
        row[label] = {
            "wall_s": wall,
            "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
            "prefix_hit_rate": m["prefix_hit_rate"],
            "n_prefix_hits": m["n_prefix_hits"],
            "n_cow_forks": m["n_cow_forks"],
            "physical_pages_allocated": m["physical_pages_allocated"],
            "cache_utilization": m["cache_utilization"],
            "logical_cache_utilization": m["logical_cache_utilization"],
            "p99_ttft_s": m["p99_ttft_s"],
            "n_preemptions": m["n_preemptions"],
            "energy_per_token_J": m["energy_per_token_J"],
        }
    row["physical_pages_saved"] = (
        row["no_sharing"]["physical_pages_allocated"]
        - row["sharing"]["physical_pages_allocated"])
    return row


def _bench_sampled(cfg, params, seed: int) -> dict:
    """Sampled-decode trace: the same Poisson shape as the headline
    rows, but half the requests decode stochastically (temperature
    0.8, top-k 40, top-p 0.95, per-request seeds from the trace rng).
    Both sides share the already-warm compiled forwards; the sampled
    side additionally pays the batched sampler (compiled once at the
    (max_batch, vocab) shape), so the tok/s delta IS the sampler
    cost. Virtual TTFTs are deterministic per (trace, seed)."""
    row = {"trace": "sampled_decode", "n_requests": 12,
           "temperature": 0.8, "top_k": 40, "top_p": 0.95}
    for label, frac in (("greedy", 0.0), ("mixed_sampled", 0.5)):
        eng = ServeEngine(cfg, params=params,
                          ecfg=EngineConfig(**ECFG, prefill_chunk=16),
                          seed=seed)
        eng.submit_trace(synth_trace(TrafficConfig(
            n_requests=12, arrival_rate=1e6, prompt_len_min=4,
            prompt_len_max=40, gen_len_min=4, gen_len_max=24,
            vocab_size=cfg.vocab_size, seed=seed,
            sampled_fraction=frac, temperature=0.8, top_k=40,
            top_p=0.95)))
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng.drain()
        wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        m = eng.metrics()
        row[label] = {
            "wall_s": wall,
            "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
            "n_tokens": m["n_generated_tokens"],
            "n_sampled_tokens": m["n_sampled_tokens"],
            "mean_ttft_s": m["mean_ttft_s"],
            "p99_ttft_s": m["p99_ttft_s"],
            "p99_latency_s": m["p99_latency_s"],
            "n_preemptions": m["n_preemptions"],
            "energy_per_token_J": m["energy_per_token_J"],
        }
    return row


def _bench_sharded(cfg, params, seed: int) -> dict:
    """Tensor-parallel trace: the same Poisson shape as the headline
    rows served at mesh_shards=8 (the ShardedPagedBackend over a
    simulated 8-way mesh) against mesh_shards=1 (the plain paged
    backend). Outputs are token-identical (pinned in tests); the row
    captures what the sharding COSTS on a host-simulated mesh — wall
    tok/s ratio, virtual TTFT, and energy/token, where the energy side
    carries the cost model's per-shard duplication plus the priced ring
    all-reduce. On real multi-chip hardware the wall ratio flips to a
    speedup; the simulated-mesh trajectory still catches regressions in
    the sharded step itself."""
    n = 8
    if jax.device_count() < n:
        return {"trace": "sharded_decode", "skipped":
                f"needs {n} devices, have {jax.device_count()} (set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={n})"}
    row = {"trace": "sharded_decode", "mesh_shards": n, "n_requests": 12}
    tcfg = TrafficConfig(
        n_requests=12, arrival_rate=1e6, prompt_len_min=4,
        prompt_len_max=40, gen_len_min=4, gen_len_max=24,
        vocab_size=cfg.vocab_size, seed=seed)
    for label, shards in (("single_device", 1), ("sharded", n)):
        ecfg = EngineConfig(**ECFG, prefill_chunk=16, mesh_shards=shards)
        # per-side untimed warmup: the sharded steps compile separately
        warm = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
        warm.submit(np.arange(2, 22, dtype=np.int32), max_new_tokens=3)
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        warm.drain()
        compile_s = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
        eng.submit_trace(synth_trace(tcfg))
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng.drain()
        wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        m = eng.metrics()
        row[label] = {
            "mesh_shards": shards,
            "compile_s": compile_s,
            "wall_s": wall,
            "n_tokens": m["n_generated_tokens"],
            "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
            "virtual_tok_per_s": m["virtual_tok_per_s"],
            "mean_ttft_s": m["mean_ttft_s"],
            "p99_ttft_s": m["p99_ttft_s"],
            "p99_latency_s": m["p99_latency_s"],
            "cache_utilization": m["cache_utilization"],
            "n_preemptions": m["n_preemptions"],
            "energy_per_token_J": m["energy_per_token_J"],
        }
    row["tok_per_s_ratio"] = (row["sharded"]["tok_per_s"]
                              / max(row["single_device"]["tok_per_s"],
                                    1e-9))
    row["p99_ttft_ratio"] = (row["sharded"]["p99_ttft_s"]
                             / max(row["single_device"]["p99_ttft_s"],
                                   1e-12))
    row["energy_per_token_ratio"] = (
        row["sharded"]["energy_per_token_J"]
        / max(row["single_device"]["energy_per_token_J"], 1e-30))
    return row


def _bench_fused(cfg, params, seed: int) -> dict:
    """Fused-attention trace: the same Poisson shape as the headline
    rows served with `attn_impl="fused"` (the Pallas paged-attention
    kernel walking block tables in-kernel) against the default gather
    path. Outputs are token-identical (pinned in
    tests/test_paged_kernel.py); the row captures what the fused
    kernel BUYS — wall tok/s, decode-phase virtual tok/s, TTFT and
    energy/token ratios. On CPU-only runners the kernel would execute
    under the Pallas interpreter (pure Python per grid step), so the
    timing comparison against the compiled gather path is meaningless
    there — the row skips with a reason instead, mirroring the
    sharded-decode trace's device gate."""
    from repro.kernels.flash_attention.flash_attention import \
        _interpret_default
    if _interpret_default():
        return {"trace": "fused_attention", "skipped":
                f"fused kernel would run interpreted on "
                f"{jax.default_backend()!r} (no TPU), which is not a "
                f"meaningful timing baseline vs the compiled gather "
                f"path; parity/token-identity is pinned in "
                f"tests/test_paged_kernel.py"}
    row = {"trace": "fused_attention", "n_requests": 12}
    tcfg = TrafficConfig(
        n_requests=12, arrival_rate=1e6, prompt_len_min=4,
        prompt_len_max=40, gen_len_min=4, gen_len_max=24,
        vocab_size=cfg.vocab_size, seed=seed)
    for label in ("gather", "fused"):
        ecfg = EngineConfig(**ECFG, prefill_chunk=16, attn_impl=label)
        # per-side untimed warmup: the fused steps compile separately
        warm = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
        warm.submit(np.arange(2, 22, dtype=np.int32), max_new_tokens=3)
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        warm.drain()
        compile_s = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
        eng.submit_trace(synth_trace(tcfg))
        t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        eng.drain()
        wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
        m = eng.metrics()
        row[label] = {
            "attn_impl": label,
            "compile_s": compile_s,
            "wall_s": wall,
            "n_tokens": m["n_generated_tokens"],
            "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
            "virtual_tok_per_s": m["virtual_tok_per_s"],
            "decode_tok_per_s": (m["n_generated_tokens"]
                                 / max(m["decode_virtual_s"], 1e-12)),
            "mean_ttft_s": m["mean_ttft_s"],
            "p99_ttft_s": m["p99_ttft_s"],
            "p99_latency_s": m["p99_latency_s"],
            "cache_utilization": m["cache_utilization"],
            "n_preemptions": m["n_preemptions"],
            "energy_per_token_J": m["energy_per_token_J"],
        }
    row["tok_per_s_ratio"] = (row["fused"]["tok_per_s"]
                              / max(row["gather"]["tok_per_s"], 1e-9))
    row["decode_tok_per_s_ratio"] = (
        row["fused"]["decode_tok_per_s"]
        / max(row["gather"]["decode_tok_per_s"], 1e-9))
    row["p99_ttft_ratio"] = (row["fused"]["p99_ttft_s"]
                             / max(row["gather"]["p99_ttft_s"], 1e-12))
    row["energy_per_token_ratio"] = (
        row["fused"]["energy_per_token_J"]
        / max(row["gather"]["energy_per_token_J"], 1e-30))
    return row


def _bench_recurrent(seed: int) -> dict:
    """Recurrent-family trace: rwkv6 through the state-slot backend —
    fixed-size per-lane state slots instead of growing KV pages, same
    engine, same mixed-step cost scheduler. Reported so the recurrent
    path's throughput/TTFT trajectory lands in the per-run artifact."""
    cfg = configs.get_config("rwkv6_3b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(seed), cfg)
    ecfg = EngineConfig(max_batch=4, prefill_chunk=16, max_seq_len=96,
                        cache_dtype="float32")
    # warmup drain compiles the slot chunk/decode steps off the clock
    warm = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
    warm.submit(np.arange(2, 20, dtype=np.int32), max_new_tokens=3)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    warm.drain()
    compile_s = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    eng = ServeEngine(cfg, params=params, ecfg=ecfg, seed=seed)
    trace = synth_trace(TrafficConfig(
        n_requests=8, arrival_rate=1e6, prompt_len_min=4,
        prompt_len_max=32, gen_len_min=4, gen_len_max=16,
        vocab_size=cfg.vocab_size, seed=seed))
    eng.submit_trace(trace)
    t0 = time.time()  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    eng.drain()
    wall = time.time() - t0  # repro: allow[wall-clock-in-serve] -- measured throughput/compile wall time IS the result
    m = eng.metrics()
    return {
        "trace": "recurrent_rwkv6",
        "arch": cfg.name,
        "backend": "state_slot",
        "n_requests": m["n_done"],
        "n_tokens": m["n_generated_tokens"],
        "compile_s": compile_s,
        "wall_s": wall,
        "tok_per_s": m["n_generated_tokens"] / max(wall, 1e-9),
        "virtual_tok_per_s": m["virtual_tok_per_s"],
        "p50_latency_s": m["p50_latency_s"],
        "p99_latency_s": m["p99_latency_s"],
        "mean_ttft_s": m["mean_ttft_s"],
        "p99_ttft_s": m["p99_ttft_s"],
        "slot_utilization": m["cache_utilization"],
        "n_state_slots": m["n_state_slots"],
        "n_preemptions": m["n_preemptions"],
        "energy_per_token_J": m["energy_per_token_J"],
        "total_energy_J": m["total_energy_J"],
    }


def run(smoke: bool = True, arch: str = "qwen3_8b",
        n_requests: int = 12, seed: int = 0,
        out_path: str | None = None) -> list[dict]:
    cfg = configs.get_config(arch, smoke=smoke)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = model.init(jax.random.PRNGKey(seed), cfg)
    compile_s = _warmup(cfg, params, seed)
    print(f"  warmup (jit compile): {compile_s:.2f}s — excluded from rows")
    rows = []
    for scheduler in ("cost", "fcfs"):
        row = _bench_one(cfg, params, scheduler, n_requests, seed)
        rows.append(row)
        print(f"  {scheduler:5s} | {row['tok_per_s']:8.1f} tok/s wall "
              f"| p50 {row['p50_latency_s']*1e3:8.3f} ms "
              f"| p99 {row['p99_latency_s']*1e3:8.3f} ms (virtual) "
              f"| util {row['cache_utilization']:.2f} "
              f"| {row['n_preemptions']} preempt")
    lp = _bench_long_prompt(cfg, params, seed)
    print(f"  long-prompt p99 TTFT: chunked+cost "
          f"{lp['chunked_cost']['p99_ttft_s']*1e3:.3f} ms vs "
          f"unchunked+fcfs {lp['unchunked_fcfs']['p99_ttft_s']*1e3:.3f} ms "
          f"({lp['p99_ttft_speedup']:.2f}x)")
    sp = _bench_shared_prefix(cfg, params, seed)
    print(f"  shared-prefix: hit rate "
          f"{sp['sharing']['prefix_hit_rate']:.2f} | physical pages "
          f"{sp['sharing']['physical_pages_allocated']} vs "
          f"{sp['no_sharing']['physical_pages_allocated']} no-sharing "
          f"({sp['physical_pages_saved']} saved) | "
          f"{sp['sharing']['n_cow_forks']} COW forks")
    sd = _bench_sampled(cfg, params, seed)
    print(f"  sampled-decode: mixed "
          f"{sd['mixed_sampled']['tok_per_s']:8.1f} tok/s wall "
          f"({sd['mixed_sampled']['n_sampled_tokens']}/"
          f"{sd['mixed_sampled']['n_tokens']} tokens sampled) vs greedy "
          f"{sd['greedy']['tok_per_s']:8.1f} | p99-ttft "
          f"{sd['mixed_sampled']['p99_ttft_s']*1e3:.3f} ms vs "
          f"{sd['greedy']['p99_ttft_s']*1e3:.3f} ms (virtual)")
    sh = _bench_sharded(cfg, params, seed)
    if "skipped" in sh:
        print(f"  sharded-decode: skipped — {sh['skipped']}")
    else:
        print(f"  sharded-decode ({sh['mesh_shards']}-way, simulated): "
              f"{sh['sharded']['tok_per_s']:8.1f} tok/s wall vs "
              f"{sh['single_device']['tok_per_s']:8.1f} single "
              f"({sh['tok_per_s_ratio']:.2f}x) | energy/token "
              f"{sh['energy_per_token_ratio']:.2f}x | p99-ttft "
              f"{sh['sharded']['p99_ttft_s']*1e3:.3f} ms (virtual)")
    fu = _bench_fused(cfg, params, seed)
    if "skipped" in fu:
        print(f"  fused-attention: skipped — {fu['skipped']}")
    else:
        print(f"  fused-attention: {fu['fused']['tok_per_s']:8.1f} tok/s "
              f"wall vs {fu['gather']['tok_per_s']:8.1f} gather "
              f"({fu['tok_per_s_ratio']:.2f}x) | decode "
              f"{fu['decode_tok_per_s_ratio']:.2f}x (virtual) | "
              f"energy/token {fu['energy_per_token_ratio']:.2f}x | "
              f"p99-ttft {fu['fused']['p99_ttft_s']*1e3:.3f} ms")
    rec = _bench_recurrent(seed)
    print(f"  recurrent ({rec['arch']}, state-slot backend): "
          f"{rec['tok_per_s']:8.1f} tok/s wall | p99 "
          f"{rec['p99_latency_s']*1e3:8.3f} ms | p99-ttft "
          f"{rec['p99_ttft_s']*1e3:8.3f} ms (virtual) | slot util "
          f"{rec['slot_utilization']:.2f}")
    bench = {"bench": "serve_throughput", "arch": cfg.name,
             "smoke": smoke, "seed": seed, "compile_s": compile_s,
             "rows": rows, "long_prompt": lp, "shared_prefix": sp,
             "sampled_decode": sd, "sharded_decode": sh,
             "fused_attention": fu, "recurrent": rec}
    out_path = out_path or OUT_PATH
    with open(out_path, "w") as f:
        json.dump(bench, f, indent=2)
    print("BENCH " + json.dumps(bench))
    print(f"wrote {out_path}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--arch", default="qwen3_8b")
    ap.add_argument("--n-requests", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help=f"sidecar JSON path (default {OUT_PATH})")
    args = ap.parse_args()
    run(smoke=not args.full, arch=args.arch, n_requests=args.n_requests,
        seed=args.seed, out_path=args.out)


if __name__ == "__main__":
    main()
